//! Acceptance tests for the typed async job API (the api_redesign PR):
//!
//! 1. An f64 and a u64 batch each round-trip through `SortService` with
//!    autotuning enabled, producing **distinct dtype-tagged fingerprint
//!    classes** in the tuning cache.
//! 2. A streamed batch yields its first result **before the last job
//!    completes** — no whole-batch barrier.
//! 3. Mixed-dtype traffic through one service instance stays correct and
//!    fully accounted.

use std::time::{Duration, Instant};

use evosort::autotune::AutotunePolicy;
use evosort::coordinator::{JobResult, ServiceConfig, SortRequest, SortService};
use evosort::data::{generate_i64, Distribution};
use evosort::sort::Dtype;

fn floats_of(n: usize, seed: u64) -> Vec<f64> {
    generate_i64(n, Distribution::Uniform, seed, 2).into_iter().map(|x| x as f64).collect()
}

fn u64s_of(n: usize, seed: u64) -> Vec<u64> {
    generate_i64(n, Distribution::Uniform, seed, 2)
        .into_iter()
        .map(|x| x.wrapping_sub(i64::MIN) as u64)
        .collect()
}

#[test]
fn f64_and_u64_batches_autotune_into_distinct_dtype_classes() {
    // quick() = eager test policy: tiny observation thresholds, full CPU
    // share, no noise margin (deterministic adaptation is under test).
    let policy = AutotunePolicy { generations_per_cycle: 2, ..AutotunePolicy::quick() };
    let svc = SortService::new(ServiceConfig::sized(2, 2, 32).with_autotune(policy));
    let n = 30_000;
    let f64_label = SortService::fingerprint_label_for(&floats_of(n, 0));
    let u64_label = SortService::fingerprint_label_for(&u64s_of(n, 0));
    assert!(f64_label.ends_with(":f64"), "{f64_label}");
    assert!(u64_label.ends_with(":u64"), "{u64_label}");
    assert_ne!(f64_label, u64_label);
    assert!(svc.cache().get(n, &f64_label).is_none(), "f64 class starts cold");
    assert!(svc.cache().get(n, &u64_label).is_none(), "u64 class starts cold");

    // Alternate f64 and u64 batches of one shape each until the background
    // tuner publishes parameters for both dtype-tagged classes.
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut round = 0u64;
    while (svc.cache().get(n, &f64_label).is_none() || svc.cache().get(n, &u64_label).is_none())
        && Instant::now() < deadline
    {
        let mut requests: Vec<SortRequest> = Vec::new();
        for i in 0..4 {
            requests.push(SortRequest::new(floats_of(n, round * 8 + i)));
            requests.push(SortRequest::new(u64s_of(n, round * 8 + i)));
        }
        let report = svc.submit_batch_requests(requests).wait();
        assert_eq!(report.stats.invalid, 0);
        assert_eq!(report.stats.failed, 0);
        assert_eq!(report.stats.per_dtype.len(), 2, "both dtypes in every batch");
        round += 1;
    }

    assert!(svc.cache().get(n, &f64_label).is_some(), "tuner published the f64 class");
    assert!(svc.cache().get(n, &u64_label).is_some(), "tuner published the u64 class");
    // Both classes are live in the cache under their tagged keys.
    let tagged: Vec<String> = svc
        .cache()
        .entries()
        .into_iter()
        .map(|(k, _)| k.dist)
        .filter(|d| d.ends_with(":f64") || d.ends_with(":u64"))
        .collect();
    assert!(tagged.len() >= 2, "expected both dtype-tagged classes, got {tagged:?}");
    assert!(svc.metrics().counter("tuner.publishes") >= 2);

    // The tuned classes now serve cache hits to fresh same-shape traffic.
    let hits_before = svc.metrics().counter("params.cache_hit");
    let out = svc.submit_request(SortRequest::new(floats_of(n, 9999))).wait().unwrap();
    assert!(out.valid);
    let out = svc.submit_request(SortRequest::new(u64s_of(n, 9999))).wait().unwrap();
    assert!(out.valid);
    assert!(svc.metrics().counter("params.cache_hit") >= hits_before + 2);
}

#[test]
fn streamed_batch_yields_first_result_before_last_job_completes() {
    // One worker: jobs run in submission order, so the tiny first job is
    // done while the big tail is still sorting. The stream must hand the
    // first result over at that point — the whole point of streaming.
    let svc = SortService::new(ServiceConfig::sized(1, 2, 16));
    let total = 7u64;
    let mut requests = vec![SortRequest::new(generate_i64(500, Distribution::Uniform, 0, 2))];
    for seed in 1..total {
        let data = generate_i64(500_000, Distribution::Uniform, seed, 2);
        requests.push(SortRequest::new(data));
    }
    let mut stream = svc.submit_batch_requests(requests).stream();
    let first = stream.next().expect("stream yields").expect("first job ok");
    assert_eq!(first.len(), 500, "first yield is the first-submitted job");
    let completed = svc.metrics().counter("jobs.completed");
    assert!(
        completed < total,
        "first result must arrive before the batch finishes ({completed}/{total} done)"
    );
    // Draining the stream delivers the rest, in submission order.
    let rest: Vec<JobResult> = stream.collect();
    assert_eq!(rest.len(), (total - 1) as usize);
    assert!(rest.iter().all(|r| r.as_ref().map(|o| o.valid).unwrap_or(false)));
    assert_eq!(svc.metrics().counter("jobs.completed"), total);
}

#[test]
fn mixed_dtype_batch_round_trips_with_per_dtype_stats() {
    let svc = SortService::new(ServiceConfig::sized(2, 2, 16));
    let ints = generate_i64(40_000, Distribution::Zipf, 1, 2);
    let mut requests = vec![
        SortRequest::new(ints.clone()),
        SortRequest::new(floats_of(30_000, 2)),
        SortRequest::new(u64s_of(20_000, 3)),
    ];
    let i32s: Vec<i32> = ints.iter().map(|&x| x as i32).collect();
    requests.push(SortRequest::new(i32s.clone()));
    let report = svc.submit_batch_requests(requests).wait();
    assert_eq!(report.stats.jobs, 4);
    assert_eq!(report.stats.invalid, 0);
    assert_eq!(report.stats.failed, 0);
    assert_eq!(report.stats.per_dtype.len(), 4, "one stats row per dtype");
    let dtypes: Vec<Dtype> = report.stats.per_dtype.iter().map(|d| d.dtype).collect();
    assert_eq!(dtypes, vec![Dtype::I64, Dtype::I32, Dtype::U64, Dtype::F64]);

    // Spot-check each payload against its std-sort oracle.
    let mut want_i64 = ints;
    want_i64.sort_unstable();
    assert_eq!(report.output(0).data::<i64>().unwrap(), &want_i64[..]);
    let mut want_i32 = i32s;
    want_i32.sort_unstable();
    assert_eq!(report.output(3).data::<i32>().unwrap(), &want_i32[..]);
    let mut want_u64 = u64s_of(20_000, 3);
    want_u64.sort_unstable();
    assert_eq!(report.output(2).data::<u64>().unwrap(), &want_u64[..]);
    let mut want_f64 = floats_of(30_000, 2);
    want_f64.sort_by(|a, b| a.total_cmp(b));
    assert_eq!(report.output(1).data::<f64>().unwrap(), &want_f64[..]);
    // Per-dtype element accounting adds up.
    let total: u64 = report.stats.per_dtype.iter().map(|d| d.elements).sum();
    assert_eq!(total, report.stats.elements);
}

#[test]
fn dropping_a_result_stream_does_not_lose_the_jobs() {
    let svc = SortService::new(ServiceConfig::sized(2, 1, 16));
    let requests: Vec<SortRequest> = (0..6u64)
        .map(|s| SortRequest::new(generate_i64(20_000, Distribution::Uniform, s, 1)))
        .collect();
    let mut stream = svc.submit_batch_requests(requests).stream();
    let _first = stream.next().expect("one result").expect("job ok");
    drop(stream); // abandon the rest mid-flight
    svc.drain();
    assert_eq!(svc.metrics().counter("jobs.completed"), 6, "abandoned jobs still run");
    // The submitted/completed batch counter pair stays in lockstep even for
    // abandoned streams.
    assert_eq!(svc.metrics().counter("batch.submitted"), 1);
    assert_eq!(svc.metrics().counter("batch.completed"), 1);
}
