//! Property tests for workload fingerprinting (the tuning-cache key).
//!
//! Two families of guarantees:
//!
//! 1. **Stability** — for each of the nine paper distributions, different
//!    seeds (different realisations of the same workload) and nearby sizes
//!    in the same half-decade band map to the same fingerprint class; the
//!    cache would otherwise fragment and never warm up.
//! 2. **Discrimination + invariance** (via the `testkit` property runner) —
//!    sorted / reversed / duplicate-heavy inputs land in different classes,
//!    and for small inputs (fully scanned by the probe) the value features
//!    are exactly permutation-invariant.

use evosort::autotune::{DupLevel, Fingerprint, RunShape, SignMix};
use evosort::data::{generate_i64, Distribution};
use evosort::rng::Xoshiro256pp;
use evosort::testkit::{check, PropConfig};

#[test]
fn nine_paper_distributions_have_stable_classes() {
    // 1e5 and 1.25e5 share a half-decade band, and every distribution's
    // value span stays inside one radix-width byte bucket across the pair
    // (ramp-shaped workloads span ~n, so sizes straddling a power of 256
    // would legitimately change class).
    let n = 100_000;
    for &dist in Distribution::all() {
        let a = Fingerprint::of(&generate_i64(n, dist, 1, 2));
        let b = Fingerprint::of(&generate_i64(n, dist, 99, 2));
        assert_eq!(
            a.label(),
            b.label(),
            "{}: different seeds must land in the same class",
            dist.name()
        );
        // Nearby size in the same half-decade band: same class.
        let c = Fingerprint::of(&generate_i64(n + n / 4, dist, 1, 2));
        assert_eq!(
            a.label(),
            c.label(),
            "{}: sizes within one band must share a class",
            dist.name()
        );
    }
}

#[test]
fn shape_features_discriminate_the_interesting_workloads() {
    let n = 60_000;
    let fp = |d: Distribution| Fingerprint::of(&generate_i64(n, d, 5, 2));
    assert_eq!(fp(Distribution::Sorted).runs, RunShape::Ascending);
    assert_eq!(fp(Distribution::NearlySorted).runs, RunShape::Ascending);
    assert_eq!(fp(Distribution::Reverse).runs, RunShape::Descending);
    assert_eq!(fp(Distribution::Uniform).runs, RunShape::Mixed);
    assert_eq!(fp(Distribution::FewUnique).dups, DupLevel::Heavy);
    assert_eq!(fp(Distribution::Constant).dups, DupLevel::Heavy);
    assert_eq!(fp(Distribution::Uniform).dups, DupLevel::Distinct);
    assert_eq!(fp(Distribution::Uniform).signs, SignMix::Mixed);
    assert_eq!(fp(Distribution::Zipf).signs, SignMix::NonNegative);
    // The three workloads the dispatcher most needs to tell apart.
    let (s, r, f) = (
        fp(Distribution::Sorted).label(),
        fp(Distribution::Reverse).label(),
        fp(Distribution::FewUnique).label(),
    );
    assert_ne!(s, r);
    assert_ne!(s, f);
    assert_ne!(r, f);
}

#[test]
fn fingerprint_is_deterministic() {
    let r = check::<Vec<i64>>(PropConfig { cases: 200, ..Default::default() }, |v| {
        Fingerprint::of(v) == Fingerprint::of(v)
    });
    r.unwrap_ok();
}

#[test]
fn value_features_permutation_invariant_for_fully_probed_inputs() {
    // testkit vectors are <= 512 elements, below the probe cap, so the
    // probe sees the full multiset: duplicates, width and sign classes must
    // survive an arbitrary shuffle (run shape intentionally does not).
    let r = check::<Vec<i64>>(PropConfig { cases: 300, ..Default::default() }, |v| {
        let a = Fingerprint::of(v);
        let mut shuffled = v.clone();
        let mut rng = Xoshiro256pp::seeded(v.len() as u64 ^ 0xC0FFEE);
        rng.shuffle(&mut shuffled);
        let b = Fingerprint::of(&shuffled);
        a.size_band == b.size_band
            && a.dups == b.dups
            && a.width_bytes == b.width_bytes
            && a.signs == b.signs
    });
    r.unwrap_ok();
}

#[test]
fn sign_class_is_sound_for_fully_probed_inputs() {
    let r = check::<Vec<i64>>(PropConfig { cases: 300, ..Default::default() }, |v| {
        let fp = Fingerprint::of(v);
        let any_neg = v.iter().any(|&x| x < 0);
        let any_nonneg = v.iter().any(|&x| x >= 0);
        match fp.signs {
            SignMix::Mixed => any_neg && any_nonneg,
            SignMix::Negative => any_neg && !any_nonneg,
            SignMix::NonNegative => !any_neg,
        }
    });
    r.unwrap_ok();
}

#[test]
fn width_class_never_exceeds_eight_bytes_and_is_monotone_in_range() {
    let r = check::<Vec<i64>>(PropConfig { cases: 300, ..Default::default() }, |v| {
        Fingerprint::of(v).width_bytes <= 8
    });
    r.unwrap_ok();
    // Widening the value range can only widen (or keep) the width class.
    let narrow = Fingerprint::of(&[5, 6, 7, 8]);
    let wide = Fingerprint::of(&[5, 6, 7, i64::MAX]);
    assert!(wide.width_bytes >= narrow.width_bytes);
    assert_eq!(wide.width_bytes, 8);
}
