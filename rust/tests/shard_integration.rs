//! Cross-process sharding integration tests: real `evosort shard-worker`
//! child processes behind a [`ShardRouter`], driven through the same
//! `Ticket`/`BatchTicket`/`ResultStream` surface the in-process service
//! exposes.
//!
//! The worker binary is the crate's own CLI (`CARGO_BIN_EXE_evosort` — the
//! test harness binary is not it, so the spec overrides the spawn path).

#![cfg(unix)]

use std::path::PathBuf;
use std::time::{Duration, Instant};

use evosort::autotune::AutotunePolicy;
use evosort::coordinator::{JobResult, ShardRouter, ShardSpec, SortRequest};
use evosort::data::{generate_i64, Distribution};
use evosort::sort::{Dtype, SortPayload};

fn spec(shards: usize, workers_per_shard: usize) -> ShardSpec {
    ShardSpec {
        shards,
        workers_per_shard,
        sort_threads: 2,
        binary: Some(PathBuf::from(env!("CARGO_BIN_EXE_evosort"))),
        ..ShardSpec::default()
    }
}

fn wait_until(limit: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + limit;
    while Instant::now() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    cond()
}

#[test]
fn sharded_batch_sorts_mixed_dtypes_across_processes() {
    let router = ShardRouter::spawn(spec(2, 1)).expect("router up");

    // Really two separate OS processes serving us.
    let pids = router.shard_pids();
    assert_eq!(pids.len(), 2);
    let (a, b) = (pids[0].expect("shard 0 live"), pids[1].expect("shard 1 live"));
    assert_ne!(a, b, "distinct worker processes");
    assert_ne!(a, std::process::id());
    assert_ne!(b, std::process::id());

    // One mixed-dtype batch; expected outputs computed locally.
    let dtypes = Dtype::all();
    let requests: Vec<SortRequest> = (0..16u64)
        .map(|i| {
            let n = 10_000 + (i as usize * 911) % 15_000;
            let data = generate_i64(n, Distribution::Uniform, i, 2);
            let payload = SortPayload::from_i64_values(data, dtypes[i as usize % dtypes.len()]);
            SortRequest::from_payload(payload)
        })
        .collect();
    let report = router.submit_batch_requests(requests).wait();
    assert_eq!(report.stats.jobs, 16);
    assert_eq!(report.stats.failed, 0, "no job may fail");
    assert_eq!(report.stats.invalid, 0, "every output validates");
    assert_eq!(report.stats.per_dtype.len(), 4, "all four dtypes served");
    let ids: std::collections::HashSet<u64> = report.outputs().map(|o| o.id).collect();
    assert_eq!(ids.len(), 16, "router-level ids are unique");
    for out in report.outputs() {
        match out.dtype() {
            Dtype::I64 => {
                let v = out.data::<i64>().unwrap();
                assert!(v.windows(2).all(|w| w[0] <= w[1]));
            }
            Dtype::F64 => {
                let v = out.data::<f64>().unwrap();
                assert!(v.windows(2).all(|w| w[0] <= w[1]));
            }
            _ => {}
        }
    }

    // Both shards took part, and the metric pairs closed.
    let metrics = router.metrics();
    let shard0 = metrics.counter("shard.0.jobs.completed");
    let shard1 = metrics.counter("shard.1.jobs.completed");
    assert!(shard0 > 0, "shard 0 served no jobs");
    assert!(shard1 > 0, "shard 1 served no jobs");
    assert_eq!(shard0 + shard1, 16);
    assert_eq!(metrics.counter("jobs.submitted"), 16);
    assert_eq!(metrics.counter("jobs.completed"), 16);
    assert_eq!(metrics.counter("batch.submitted"), 1);
    assert_eq!(metrics.counter("batch.completed"), 1);

    // The single-request path rides the same transport.
    let data = generate_i64(5_000, Distribution::Zipf, 99, 2);
    let mut expect = data.clone();
    expect.sort_unstable();
    let out = router.submit_request(SortRequest::new(data)).wait().expect("single job ok");
    assert!(out.valid);
    assert_eq!(out.data::<i64>().unwrap(), &expect[..]);
}

#[test]
fn shard_failover_worker_lost_and_respawn() {
    // Kill a shard mid-batch: its in-flight jobs must resolve
    // Err(WorkerLost) (not hang), queued jobs must reroute to the survivor,
    // the batch counters must stay in lockstep, and after the respawn the
    // next batch must fully complete. The kill window is the duration of an
    // in-flight sort, so the scenario retries a few times rather than
    // relying on one race.
    let router = ShardRouter::spawn(spec(2, 1)).expect("router up");
    let metrics = std::sync::Arc::clone(router.metrics());
    let mut batches = 0u64;
    let mut observed_loss = false;

    for attempt in 0..3u64 {
        let requests: Vec<SortRequest> = (0..12u64)
            .map(|i| {
                let data = generate_i64(800_000, Distribution::Uniform, i ^ (attempt * 101), 2);
                SortRequest::new(data)
            })
            .collect();
        let stream = router.submit_batch_requests(requests).stream();
        batches += 1;

        // Wait for shard 0 to have work on its socket, then kill it.
        assert!(
            wait_until(Duration::from_secs(30), || router.inflight(0) > 0),
            "shard 0 never received work"
        );
        assert!(router.kill_shard(0), "kill must reach a live child");

        let results: Vec<JobResult> = stream.collect();
        assert_eq!(results.len(), 12, "the stream always yields every slot");
        let lost = results.iter().filter(|r| r.is_err()).count();
        let completed = results.len() - lost;
        assert!(completed >= 1, "the surviving shard completes the rest of the batch");
        for result in &results {
            if let Ok(out) = result {
                assert!(out.valid);
            }
        }
        assert!(
            lost <= 3,
            "only the in-flight window may be lost (window 2 + dispatch race), got {lost}"
        );
        if lost >= 1 {
            observed_loss = true;
            break;
        }
    }
    assert!(observed_loss, "killing a busy shard must surface Err(WorkerLost)");

    // The batch counter pair stays in lockstep across the failure.
    assert_eq!(metrics.counter("batch.submitted"), batches);
    assert_eq!(metrics.counter("batch.completed"), batches);
    assert!(metrics.counter("shard.jobs.lost") >= 1);
    assert!(metrics.counter("shard.deaths") >= 1);

    // The dead shard respawns and the next batch completes fully.
    assert!(
        wait_until(Duration::from_secs(30), || metrics.counter("shard.respawns") >= 1),
        "the killed shard must respawn"
    );
    let requests: Vec<SortRequest> = (0..8u64)
        .map(|i| SortRequest::new(generate_i64(20_000, Distribution::Uniform, 500 + i, 2)))
        .collect();
    let report = router.submit_batch_requests(requests).wait();
    assert_eq!(report.stats.failed, 0, "post-respawn batch completes fully");
    assert_eq!(report.stats.invalid, 0);
    assert_eq!(metrics.counter("batch.submitted"), batches + 1);
    assert_eq!(metrics.counter("batch.completed"), batches + 1);
}

#[test]
fn cross_shard_cache_broadcast_shares_tuned_classes() {
    // Every job in every round has the same workload shape, so both shards
    // accumulate observations of one fingerprint class. Whichever shard's
    // tuner publishes first, the router must merge the entry and broadcast
    // it — after which *both* shards' caches hold the class (observable
    // through the cache.entries telemetry gauge).
    let policy = AutotunePolicy {
        min_observations: 4,
        cooldown_observations: 2,
        retained_sample_cap: 4096,
        generations_per_cycle: 2,
        population: 6,
        max_cpu_share: 1.0,
        min_improvement_pct: 0.0,
        sample_every: 1,
        ..AutotunePolicy::default()
    };
    let spec = ShardSpec {
        autotune: Some(policy),
        publish_interval: Duration::from_millis(100),
        ..spec(2, 1)
    };
    let router = ShardRouter::spawn(spec).expect("router up");
    let metrics = std::sync::Arc::clone(router.metrics());

    let deadline = Instant::now() + Duration::from_secs(90);
    let mut round = 0u64;
    let synced = loop {
        let requests: Vec<SortRequest> = (0..8u64)
            .map(|i| {
                let data = generate_i64(20_000, Distribution::Uniform, round * 8 + i, 2);
                SortRequest::new(data)
            })
            .collect();
        let report = router.submit_batch_requests(requests).wait();
        assert_eq!(report.stats.failed, 0);
        round += 1;
        let broadcast = metrics.counter("shard.cache.broadcasts") >= 1;
        let shard0 = metrics.gauge("shard.0.local.cache.entries").unwrap_or(0.0) >= 1.0;
        let shard1 = metrics.gauge("shard.1.local.cache.entries").unwrap_or(0.0) >= 1.0;
        if broadcast && shard0 && shard1 {
            break true;
        }
        if Instant::now() > deadline {
            break false;
        }
        std::thread::sleep(Duration::from_millis(100));
    };
    assert!(
        synced,
        "no cross-shard cache sync after {round} rounds: broadcasts={} s0={:?} s1={:?}",
        metrics.counter("shard.cache.broadcasts"),
        metrics.gauge("shard.0.local.cache.entries"),
        metrics.gauge("shard.1.local.cache.entries"),
    );
    assert!(metrics.counter("shard.cache.publishes") >= 1);
    assert!(!router.cache().is_empty(), "the router holds the merged view");
}

#[test]
fn redial_budget_exhaustion_fails_jobs_instead_of_reviving_forever() {
    // Budget 0: the first death is final. In-flight jobs resolve
    // Err(WorkerLost), the queue drains as WorkerLost once every shard is
    // permanently down, later submissions resolve immediately — and no
    // revival is attempted (`shards.redials` stays 0).
    let spec = ShardSpec { max_redials_per_shard: 0, ..spec(2, 1) };
    let router = ShardRouter::spawn(spec).expect("router up");
    let metrics = std::sync::Arc::clone(router.metrics());

    let requests: Vec<SortRequest> = (0..12u64)
        .map(|i| SortRequest::new(generate_i64(800_000, Distribution::Uniform, i, 2)))
        .collect();
    let stream = router.submit_batch_requests(requests).stream();
    assert!(
        wait_until(Duration::from_secs(30), || router.inflight(0) > 0 && router.inflight(1) > 0),
        "both shards must be busy before the kills"
    );
    assert!(router.kill_shard(0));
    assert!(router.kill_shard(1));

    let results: Vec<JobResult> = stream.collect();
    assert_eq!(results.len(), 12, "every slot resolves — nothing hangs");
    assert!(
        results.iter().any(|r| r.is_err()),
        "a fleet with no redial budget must surface losses"
    );

    assert!(
        wait_until(Duration::from_secs(10), || metrics.counter("shard.deaths") >= 2),
        "both deaths observed"
    );
    assert_eq!(metrics.counter("shards.redials"), 0, "budget 0 means no revival");
    assert_eq!(metrics.counter("shard.respawns"), 0);

    // The router stays up and answers — with a typed loss, not a hang.
    let late = router
        .submit_request(SortRequest::new(generate_i64(1_000, Distribution::Uniform, 77, 2)))
        .wait();
    assert!(
        matches!(late, Err(evosort::coordinator::JobError::WorkerLost)),
        "post-exhaustion submissions resolve WorkerLost, got {late:?}"
    );
}

#[test]
fn saturated_router_sheds_with_typed_overloaded_error() {
    // One shard, one worker, in-flight window 1, and room for only 2
    // queued jobs: a burst must shed its tail as Err(Overloaded) at
    // admission — typed, immediate, and counted — while admitted jobs
    // still complete.
    let spec = ShardSpec {
        max_inflight_per_shard: 1,
        router_queue_capacity: 2,
        ..spec(1, 1)
    };
    let router = ShardRouter::spawn(spec).expect("router up");

    // Generate ahead of time so the burst itself is back-to-back enqueues,
    // not paced by data generation.
    let datasets: Vec<Vec<i64>> =
        (0..16u64).map(|i| generate_i64(400_000, Distribution::Uniform, i, 2)).collect();
    let tickets: Vec<_> = datasets
        .into_iter()
        .map(|data| router.submit_request(SortRequest::new(data)))
        .collect();
    let results: Vec<JobResult> = tickets.into_iter().map(|t| t.wait()).collect();
    assert_eq!(results.len(), 16, "every ticket resolves");
    let shed = results
        .iter()
        .filter(|r| matches!(r, Err(evosort::coordinator::JobError::Overloaded)))
        .count();
    let completed = results.iter().filter(|r| r.is_ok()).count();
    assert!(shed >= 1, "a 16-job burst against capacity 2 must shed");
    assert!(completed >= 2, "admitted jobs complete");
    assert_eq!(shed + completed, 16, "no third outcome in this scenario");

    let metrics = router.metrics();
    assert_eq!(metrics.counter("shards.shed") as usize, shed);
    assert_eq!(metrics.counter("jobs.completed") as usize, completed);
    assert_eq!(metrics.counter("jobs.submitted"), 16, "shed jobs still count as submitted");

    // Pressure gone, the same router admits everything again.
    let report = router
        .submit_batch_requests(
            (0..2u64)
                .map(|i| SortRequest::new(generate_i64(5_000, Distribution::Uniform, 50 + i, 2)))
                .collect(),
        )
        .wait();
    assert_eq!(report.stats.failed, 0, "admission recovers once the queue drains");
}

#[test]
fn round_robin_keeps_a_small_client_ahead_of_a_bulk_client() {
    // One serialized shard (1 worker, window 1). Client 1 floods the queue
    // with slow jobs; client 2 then submits one tiny job. Round-robin must
    // dispatch client 2's job after at most one more client-1 job — FIFO
    // would run it last.
    let spec = ShardSpec { max_inflight_per_shard: 1, ..spec(1, 1) };
    let router = ShardRouter::spawn(spec).expect("router up");
    let metrics = std::sync::Arc::clone(router.metrics());

    let bulk: Vec<_> = (0..10u64)
        .map(|i| {
            router.submit_request_as(
                1,
                SortRequest::new(generate_i64(800_000, Distribution::Uniform, i, 2)),
            )
        })
        .collect();
    let small = router
        .submit_request_as(2, SortRequest::new(generate_i64(1_000, Distribution::Uniform, 99, 2)));

    let out = small.wait().expect("small job completes");
    assert!(out.valid);
    // At the moment the small job resolved, the bulk client cannot have
    // finished: with round-robin it waits behind at most ~2 bulk jobs
    // (one in flight at submission + one round), not all 10.
    let bulk_done_then = metrics.counter("jobs.completed").saturating_sub(1);
    assert!(
        bulk_done_then < 10,
        "small client finished after the whole bulk burst — starved, not round-robined"
    );

    for t in bulk {
        let out = t.wait().expect("bulk job completes");
        assert!(out.valid);
    }
    assert_eq!(metrics.counter("client.1.dispatched"), 10);
    assert_eq!(metrics.counter("client.2.dispatched"), 1);
    assert_eq!(metrics.counter("jobs.completed"), 11);
}
