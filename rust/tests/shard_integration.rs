//! Cross-process sharding integration tests: real `evosort shard-worker`
//! child processes behind a [`ShardRouter`], driven through the same
//! `Ticket`/`BatchTicket`/`ResultStream` surface the in-process service
//! exposes.
//!
//! The worker binary is the crate's own CLI (`CARGO_BIN_EXE_evosort` — the
//! test harness binary is not it, so the spec overrides the spawn path).

#![cfg(unix)]

use std::path::PathBuf;
use std::time::{Duration, Instant};

use evosort::autotune::AutotunePolicy;
use evosort::coordinator::{JobResult, ShardRouter, ShardSpec, SortRequest};
use evosort::data::{generate_i64, Distribution};
use evosort::sort::{Dtype, SortPayload};

fn spec(shards: usize, workers_per_shard: usize) -> ShardSpec {
    ShardSpec {
        shards,
        workers_per_shard,
        sort_threads: 2,
        binary: Some(PathBuf::from(env!("CARGO_BIN_EXE_evosort"))),
        ..ShardSpec::default()
    }
}

fn wait_until(limit: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + limit;
    while Instant::now() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    cond()
}

#[test]
fn sharded_batch_sorts_mixed_dtypes_across_processes() {
    let router = ShardRouter::spawn(spec(2, 1)).expect("router up");

    // Really two separate OS processes serving us.
    let pids = router.shard_pids();
    assert_eq!(pids.len(), 2);
    let (a, b) = (pids[0].expect("shard 0 live"), pids[1].expect("shard 1 live"));
    assert_ne!(a, b, "distinct worker processes");
    assert_ne!(a, std::process::id());
    assert_ne!(b, std::process::id());

    // One mixed-dtype batch; expected outputs computed locally.
    let dtypes = Dtype::all();
    let requests: Vec<SortRequest> = (0..16u64)
        .map(|i| {
            let n = 10_000 + (i as usize * 911) % 15_000;
            let data = generate_i64(n, Distribution::Uniform, i, 2);
            let payload = SortPayload::from_i64_values(data, dtypes[i as usize % dtypes.len()]);
            SortRequest::from_payload(payload)
        })
        .collect();
    let report = router.submit_batch_requests(requests).wait();
    assert_eq!(report.stats.jobs, 16);
    assert_eq!(report.stats.failed, 0, "no job may fail");
    assert_eq!(report.stats.invalid, 0, "every output validates");
    assert_eq!(report.stats.per_dtype.len(), 4, "all four dtypes served");
    let ids: std::collections::HashSet<u64> = report.outputs().map(|o| o.id).collect();
    assert_eq!(ids.len(), 16, "router-level ids are unique");
    for out in report.outputs() {
        match out.dtype() {
            Dtype::I64 => {
                let v = out.data::<i64>().unwrap();
                assert!(v.windows(2).all(|w| w[0] <= w[1]));
            }
            Dtype::F64 => {
                let v = out.data::<f64>().unwrap();
                assert!(v.windows(2).all(|w| w[0] <= w[1]));
            }
            _ => {}
        }
    }

    // Both shards took part, and the metric pairs closed.
    let metrics = router.metrics();
    let shard0 = metrics.counter("shard.0.jobs.completed");
    let shard1 = metrics.counter("shard.1.jobs.completed");
    assert!(shard0 > 0, "shard 0 served no jobs");
    assert!(shard1 > 0, "shard 1 served no jobs");
    assert_eq!(shard0 + shard1, 16);
    assert_eq!(metrics.counter("jobs.submitted"), 16);
    assert_eq!(metrics.counter("jobs.completed"), 16);
    assert_eq!(metrics.counter("batch.submitted"), 1);
    assert_eq!(metrics.counter("batch.completed"), 1);

    // The single-request path rides the same transport.
    let data = generate_i64(5_000, Distribution::Zipf, 99, 2);
    let mut expect = data.clone();
    expect.sort_unstable();
    let out = router.submit_request(SortRequest::new(data)).wait().expect("single job ok");
    assert!(out.valid);
    assert_eq!(out.data::<i64>().unwrap(), &expect[..]);
}

#[test]
fn shard_failover_worker_lost_and_respawn() {
    // Kill a shard mid-batch: its in-flight jobs must resolve
    // Err(WorkerLost) (not hang), queued jobs must reroute to the survivor,
    // the batch counters must stay in lockstep, and after the respawn the
    // next batch must fully complete. The kill window is the duration of an
    // in-flight sort, so the scenario retries a few times rather than
    // relying on one race.
    let router = ShardRouter::spawn(spec(2, 1)).expect("router up");
    let metrics = std::sync::Arc::clone(router.metrics());
    let mut batches = 0u64;
    let mut observed_loss = false;

    for attempt in 0..3u64 {
        let requests: Vec<SortRequest> = (0..12u64)
            .map(|i| {
                let data = generate_i64(800_000, Distribution::Uniform, i ^ (attempt * 101), 2);
                SortRequest::new(data)
            })
            .collect();
        let stream = router.submit_batch_requests(requests).stream();
        batches += 1;

        // Wait for shard 0 to have work on its socket, then kill it.
        assert!(
            wait_until(Duration::from_secs(30), || router.inflight(0) > 0),
            "shard 0 never received work"
        );
        assert!(router.kill_shard(0), "kill must reach a live child");

        let results: Vec<JobResult> = stream.collect();
        assert_eq!(results.len(), 12, "the stream always yields every slot");
        let lost = results.iter().filter(|r| r.is_err()).count();
        let completed = results.len() - lost;
        assert!(completed >= 1, "the surviving shard completes the rest of the batch");
        for result in &results {
            if let Ok(out) = result {
                assert!(out.valid);
            }
        }
        assert!(
            lost <= 3,
            "only the in-flight window may be lost (window 2 + dispatch race), got {lost}"
        );
        if lost >= 1 {
            observed_loss = true;
            break;
        }
    }
    assert!(observed_loss, "killing a busy shard must surface Err(WorkerLost)");

    // The batch counter pair stays in lockstep across the failure.
    assert_eq!(metrics.counter("batch.submitted"), batches);
    assert_eq!(metrics.counter("batch.completed"), batches);
    assert!(metrics.counter("shard.jobs.lost") >= 1);
    assert!(metrics.counter("shard.deaths") >= 1);

    // The dead shard respawns and the next batch completes fully.
    assert!(
        wait_until(Duration::from_secs(30), || metrics.counter("shard.respawns") >= 1),
        "the killed shard must respawn"
    );
    let requests: Vec<SortRequest> = (0..8u64)
        .map(|i| SortRequest::new(generate_i64(20_000, Distribution::Uniform, 500 + i, 2)))
        .collect();
    let report = router.submit_batch_requests(requests).wait();
    assert_eq!(report.stats.failed, 0, "post-respawn batch completes fully");
    assert_eq!(report.stats.invalid, 0);
    assert_eq!(metrics.counter("batch.submitted"), batches + 1);
    assert_eq!(metrics.counter("batch.completed"), batches + 1);
}

#[test]
fn cross_shard_cache_broadcast_shares_tuned_classes() {
    // Every job in every round has the same workload shape, so both shards
    // accumulate observations of one fingerprint class. Whichever shard's
    // tuner publishes first, the router must merge the entry and broadcast
    // it — after which *both* shards' caches hold the class (observable
    // through the cache.entries telemetry gauge).
    let policy = AutotunePolicy {
        min_observations: 4,
        cooldown_observations: 2,
        retained_sample_cap: 4096,
        generations_per_cycle: 2,
        population: 6,
        max_cpu_share: 1.0,
        min_improvement_pct: 0.0,
        sample_every: 1,
        ..AutotunePolicy::default()
    };
    let spec = ShardSpec {
        autotune: Some(policy),
        publish_interval: Duration::from_millis(100),
        ..spec(2, 1)
    };
    let router = ShardRouter::spawn(spec).expect("router up");
    let metrics = std::sync::Arc::clone(router.metrics());

    let deadline = Instant::now() + Duration::from_secs(90);
    let mut round = 0u64;
    let synced = loop {
        let requests: Vec<SortRequest> = (0..8u64)
            .map(|i| {
                let data = generate_i64(20_000, Distribution::Uniform, round * 8 + i, 2);
                SortRequest::new(data)
            })
            .collect();
        let report = router.submit_batch_requests(requests).wait();
        assert_eq!(report.stats.failed, 0);
        round += 1;
        let broadcast = metrics.counter("shard.cache.broadcasts") >= 1;
        let shard0 = metrics.gauge("shard.0.local.cache.entries").unwrap_or(0.0) >= 1.0;
        let shard1 = metrics.gauge("shard.1.local.cache.entries").unwrap_or(0.0) >= 1.0;
        if broadcast && shard0 && shard1 {
            break true;
        }
        if Instant::now() > deadline {
            break false;
        }
        std::thread::sleep(Duration::from_millis(100));
    };
    assert!(
        synced,
        "no cross-shard cache sync after {round} rounds: broadcasts={} s0={:?} s1={:?}",
        metrics.counter("shard.cache.broadcasts"),
        metrics.gauge("shard.0.local.cache.entries"),
        metrics.gauge("shard.1.local.cache.entries"),
    );
    assert!(metrics.counter("shard.cache.publishes") >= 1);
    assert!(!router.cache().is_empty(), "the router holds the merged view");
}
