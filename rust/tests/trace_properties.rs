//! End-to-end tracing invariants: every submitted job's span chain ends in
//! **exactly one** terminal event — across normal completion, a
//! cancel-before-dispatch, an overload shed, and a chaos-killed shard — and
//! a flooded trace ring drops events (counted) without ever stalling a
//! sort.
//!
//! The sharded tests spawn real `evosort shard-worker` child processes
//! (the spec overrides the spawn path with `CARGO_BIN_EXE_evosort`, same as
//! `shard_integration.rs`).

use std::sync::Arc;
use std::time::{Duration, Instant};

use evosort::coordinator::{
    JobError, JobResult, ServiceConfig, ShardRouter, ShardSpec, SortRequest, SortService,
};
use evosort::data::{generate_i64, Distribution};
use evosort::obs::{report, EventKind, FailReason, TraceEvent, TraceHub, Tracer, ROUTER_SHARD};

fn wait_until(limit: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + limit;
    while Instant::now() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    cond()
}

#[cfg(unix)]
fn traced_spec(shards: usize) -> ShardSpec {
    ShardSpec {
        shards,
        workers_per_shard: 1,
        sort_threads: 2,
        binary: Some(std::path::PathBuf::from(env!("CARGO_BIN_EXE_evosort"))),
        publish_interval: Duration::from_millis(50),
        trace: true,
        ..ShardSpec::default()
    }
}

/// Flush the hub and wait until the retained timeline passes the
/// span-chain check (worker batches arrive on telemetry ticks, so the
/// timeline converges shortly after the jobs resolve).
fn settled_snapshot(hub: &TraceHub, extra: impl Fn(&[TraceEvent]) -> bool) -> Vec<TraceEvent> {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        hub.flush();
        let snapshot = hub.snapshot();
        if report::check(&snapshot).is_empty() && extra(&snapshot) {
            return snapshot;
        }
        if Instant::now() > deadline {
            return snapshot;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
}

fn terminals_for(events: &[TraceEvent], trace: u64) -> Vec<&TraceEvent> {
    events.iter().filter(|e| e.trace_id == trace && e.kind.is_terminal()).collect()
}

#[test]
fn traced_batch_yields_exactly_one_terminal_per_job() {
    let tracer = Tracer::enabled(1 << 14, 0);
    let svc = SortService::new_traced(
        ServiceConfig::sized(2, 2, 64),
        tracer.clone(),
    );
    let hub = TraceHub::new(tracer, None, Some(Arc::clone(svc.metrics()))).unwrap();

    let jobs = 24usize;
    let requests: Vec<SortRequest> = (0..jobs)
        .map(|i| SortRequest::new(generate_i64(150_000, Distribution::Uniform, i as u64, 2)))
        .collect();
    let report_stats = svc.submit_batch_requests(requests).wait();
    assert_eq!(report_stats.stats.failed, 0);
    assert_eq!(report_stats.stats.jobs, jobs);

    let snapshot = settled_snapshot(&hub, |evs| {
        evs.iter().filter(|e| e.kind.is_terminal()).count() >= jobs
    });
    let problems = report::check(&snapshot);
    assert!(problems.is_empty(), "span chains incomplete: {problems:?}");
    let summary = report::summarize(&snapshot);
    assert_eq!(summary.traces, jobs);
    assert_eq!(summary.completed, jobs);
    assert_eq!(summary.failed, 0);
    assert_eq!(
        summary.completed_with_phases, jobs,
        "every traced 150k-element sort must record kernel phases"
    );
    assert!(!summary.phase_stats.is_empty());
    assert_eq!(hub.dropped(), 0, "a 16k ring absorbs a 24-job batch");
}

#[test]
fn cancel_before_dispatch_terminates_as_exactly_one_cancelled() {
    // One pool worker: while it sorts the big job, the small one is still
    // queued — a cancel then must land before dispatch, and the trace must
    // end in exactly one Failed{cancelled} with no Dispatched span.
    let tracer = Tracer::enabled(1 << 12, 0);
    let svc = SortService::new_traced(
        ServiceConfig::sized(1, 2, 32),
        tracer.clone(),
    );
    let big = svc.submit_request(SortRequest::new(generate_i64(
        2_000_000,
        Distribution::Uniform,
        1,
        2,
    )));
    let small =
        svc.submit_request(SortRequest::new(generate_i64(1_000, Distribution::Uniform, 2, 2)));
    assert!(small.cancel(), "cancel must land while the job is queued");
    let cancelled_id = small.id();
    assert_eq!(small.wait(), Err(JobError::Cancelled));
    assert!(big.wait().is_ok());

    // The terminal event is emitted when the worker honours the cancel,
    // which can trail the ticket resolving — accumulate until it shows.
    let mut events: Vec<TraceEvent> = Vec::new();
    assert!(
        wait_until(Duration::from_secs(5), || {
            tracer.drain_into(&mut events);
            events.iter().any(|e| {
                e.trace_id == cancelled_id
                    && e.kind == EventKind::Failed { reason: FailReason::Cancelled }
            })
        }),
        "the cancelled job must emit its Failed{{cancelled}} terminal"
    );
    let problems = report::check(&events);
    assert!(problems.is_empty(), "{problems:?}");
    assert_eq!(terminals_for(&events, cancelled_id).len(), 1, "exactly one terminal");
    assert!(
        !events
            .iter()
            .any(|e| e.trace_id == cancelled_id
                && matches!(e.kind, EventKind::Dispatched { .. })),
        "a cancel-before-dispatch must never reach a Dispatched span"
    );
}

#[test]
fn flooded_tiny_ring_drops_events_but_never_stalls_sorts() {
    // An 8-slot ring cannot hold even one job's span chain — every sort
    // must still complete, and the overflow must surface as a drop count,
    // not as blocking.
    let tracer = Tracer::enabled(8, 0);
    let svc = SortService::new_traced(
        ServiceConfig::sized(2, 2, 64),
        tracer.clone(),
    );
    let requests: Vec<SortRequest> = (0..40u64)
        .map(|i| SortRequest::new(generate_i64(50_000, Distribution::Uniform, i, 2)))
        .collect();
    let report_stats = svc.submit_batch_requests(requests).wait();
    assert_eq!(report_stats.stats.failed, 0, "drops must not fail sorts");
    assert_eq!(report_stats.stats.invalid, 0);
    assert!(
        tracer.dropped() > 0,
        "40 undrained span chains must overflow an 8-slot ring"
    );
}

#[cfg(unix)]
#[test]
fn overload_shed_jobs_get_exactly_one_overloaded_terminal() {
    // Saturate a 1-shard fleet (window 1, router queue 2) like
    // `shard_integration::saturated_router_sheds_…`, with tracing on: shed
    // jobs must trace Submitted → Failed{overloaded} on the router stream,
    // with no Dispatched span and no second terminal.
    let spec = ShardSpec {
        max_inflight_per_shard: 1,
        router_queue_capacity: 2,
        ..traced_spec(1)
    };
    let router = ShardRouter::spawn(spec).expect("router up");
    let hub = router.trace_hub().expect("tracing was requested");

    // Generate ahead of time so the burst is back-to-back enqueues.
    let datasets: Vec<Vec<i64>> =
        (0..16u64).map(|i| generate_i64(400_000, Distribution::Uniform, i, 2)).collect();
    let tickets: Vec<_> = datasets
        .into_iter()
        .map(|data| router.submit_request(SortRequest::new(data)))
        .collect();
    let results: Vec<(u64, JobResult)> =
        tickets.into_iter().map(|t| (t.id(), t.wait())).collect();
    let shed: Vec<u64> = results
        .iter()
        .filter(|(_, r)| matches!(r, Err(JobError::Overloaded)))
        .map(|(id, _)| *id)
        .collect();
    assert!(!shed.is_empty(), "a 16-job burst against capacity 2 must shed");

    let snapshot = settled_snapshot(hub, |evs| {
        evs.iter().filter(|e| e.kind.is_terminal() && e.shard == ROUTER_SHARD).count() >= 16
    });
    let problems = report::check(&snapshot);
    assert!(problems.is_empty(), "span chains incomplete: {problems:?}");
    let summary = report::summarize(&snapshot);
    assert_eq!(summary.traces, 16);
    assert_eq!(summary.failed, shed.len());
    assert_eq!(summary.failures_by_reason.get("overloaded"), Some(&shed.len()));
    for id in &shed {
        assert_eq!(terminals_for(&snapshot, *id).len(), 1, "trace {id}");
        assert!(
            !snapshot
                .iter()
                .any(|e| e.trace_id == *id && matches!(e.kind, EventKind::Dispatched { .. })),
            "shed trace {id} must never dispatch"
        );
    }
}

#[cfg(unix)]
#[test]
fn chaos_killed_shard_still_resolves_every_trace_on_the_router_stream() {
    // Kill a busy shard mid-batch. The dead worker's own ring dies with it
    // (its in-flight terminals are stranded in the killed process), but the
    // router's stream must stay invariant-complete: every submission ends
    // in exactly one terminal — Completed on the survivor, or
    // Failed{worker_lost} for the lost window.
    let router = ShardRouter::spawn(traced_spec(2)).expect("router up");
    let hub = router.trace_hub().expect("tracing was requested");
    let mut lost_any = false;

    for attempt in 0..3u64 {
        let requests: Vec<SortRequest> = (0..12u64)
            .map(|i| {
                SortRequest::new(generate_i64(800_000, Distribution::Uniform, i ^ (attempt * 7), 2))
            })
            .collect();
        let stream = router.submit_batch_requests(requests).stream();
        assert!(
            wait_until(Duration::from_secs(30), || router.inflight(0) > 0),
            "shard 0 never received work"
        );
        assert!(router.kill_shard(0), "kill must reach a live child");
        let results: Vec<JobResult> = stream.collect();
        assert_eq!(results.len(), 12, "every slot resolves");
        if results.iter().any(|r| r.is_err()) {
            lost_any = true;
            break;
        }
    }
    assert!(lost_any, "killing a busy shard must surface Err(WorkerLost)");

    // The fleet-wide check would flag the killed worker's stranded stream;
    // the invariant that must hold regardless of SIGKILL timing is the
    // router's own stream.
    let deadline = Instant::now() + Duration::from_secs(10);
    let router_events = loop {
        hub.flush();
        let evs: Vec<TraceEvent> =
            hub.snapshot().into_iter().filter(|e| e.shard == ROUTER_SHARD).collect();
        if report::check(&evs).is_empty() || Instant::now() > deadline {
            break evs;
        }
        std::thread::sleep(Duration::from_millis(25));
    };
    let problems = report::check(&router_events);
    assert!(problems.is_empty(), "router span chains incomplete: {problems:?}");
    let summary = report::summarize(&router_events);
    assert!(
        summary.failures_by_reason.get("worker_lost").copied().unwrap_or(0) >= 1,
        "the lost window must trace as Failed{{worker_lost}}: {:?}",
        summary.failures_by_reason
    );
    for ev in router_events.iter().filter(|e| e.kind == EventKind::Submitted) {
        assert_eq!(
            terminals_for(&router_events, ev.trace_id).len(),
            1,
            "trace {} on the router stream",
            ev.trace_id
        );
    }
}

#[cfg(unix)]
#[test]
fn fleet_traces_carry_shard_attribution_end_to_end() {
    // A clean 2-shard batch: every trace's chain must span both the router
    // stream and exactly the worker shard the router dispatched it to, and
    // carry that worker's kernel phases.
    let router = ShardRouter::spawn(traced_spec(2)).expect("router up");
    let hub = router.trace_hub().expect("tracing was requested");

    let jobs = 12u64;
    let requests: Vec<SortRequest> = (0..jobs)
        .map(|i| SortRequest::new(generate_i64(150_000, Distribution::Uniform, i, 2)))
        .collect();
    let report_stats = router.submit_batch_requests(requests).wait();
    assert_eq!(report_stats.stats.failed, 0);

    let snapshot = settled_snapshot(hub, |evs| {
        let worker_terminals =
            evs.iter().filter(|e| e.kind.is_terminal() && e.shard != ROUTER_SHARD).count();
        worker_terminals >= jobs as usize
    });
    let problems = report::check(&snapshot);
    assert!(problems.is_empty(), "span chains incomplete: {problems:?}");
    let summary = report::summarize(&snapshot);
    assert_eq!(summary.completed, jobs as usize);
    assert_eq!(
        summary.completed_with_phases, jobs as usize,
        "every trace must carry the executing worker's kernel phases"
    );

    let trace_ids: std::collections::BTreeSet<u64> =
        snapshot.iter().map(|e| e.trace_id).collect();
    let mut shards_seen = std::collections::BTreeSet::new();
    for id in trace_ids {
        let chain: Vec<&TraceEvent> =
            snapshot.iter().filter(|e| e.trace_id == id).collect();
        let target = chain
            .iter()
            .find_map(|e| match e.kind {
                EventKind::Dispatched { shard } if e.shard == ROUTER_SHARD => Some(shard),
                _ => None,
            })
            .expect("the router records where it dispatched");
        let worker_shards: std::collections::BTreeSet<u32> =
            chain.iter().map(|e| e.shard).filter(|s| *s != ROUTER_SHARD).collect();
        assert_eq!(
            worker_shards,
            std::collections::BTreeSet::from([target]),
            "trace {id}: worker events must come from the dispatched shard"
        );
        shards_seen.insert(target);
    }
    assert_eq!(shards_seen.len(), 2, "both shards took part in the batch");
}
