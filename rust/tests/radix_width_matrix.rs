//! Three-phase radix kernel matrix: the count → scan → scatter rewrite must
//! match the std-sort oracle for every dtype × distribution × digit width ×
//! thread count combination the adaptive dispatcher can reach. The digit
//! width is a GA gene (`W_radix` ∈ {6, 8, 11}), so every width is a live
//! production configuration, not a debug knob.
//!
//! The in-crate Miri job runs `--lib` only; the Miri-sized companions of
//! these sweeps live in `sort/radix.rs` (`digit_widths_small_n_all_dtypes`).

use evosort::data::{self, Distribution};
use evosort::params::{ACode, RadixWidth, SortParams};
use evosort::sort::AdaptiveSorter;

/// Forced-radix parameters: a zero fallback threshold sends every size into
/// the kernel instead of `sort_unstable`.
fn radix_params(width: RadixWidth) -> SortParams {
    SortParams {
        algorithm: ACode::Radix,
        fallback_threshold: 0,
        radix_width: width,
        ..SortParams::paper_1e7()
    }
}

const WIDTHS: [RadixWidth; 3] = [RadixWidth::W6, RadixWidth::W8, RadixWidth::W11];
const THREADS: [usize; 3] = [1, 3, 8];

/// Run the full 4-dtype × 9-distribution × 3-width × 3-thread matrix at
/// size `n`; each dtype derives its workload from the same i64 draw so a
/// failure pins one (dist, width, threads, dtype) cell.
fn run_matrix(n: usize) {
    for &dist in Distribution::all() {
        for threads in THREADS {
            let sorter = AdaptiveSorter::new(threads);
            let i64s = data::generate_i64(n, dist, 61, threads);
            let i32s = data::generate_i32(n, dist, 61, threads);
            let u64s: Vec<u64> = i64s.iter().map(|&x| x as u64).collect();
            let f64s: Vec<f64> = i64s.iter().map(|&x| x as f64).collect();
            for width in WIDTHS {
                let p = radix_params(width);
                let ctx = format!("{} {width:?} t{threads} n{n}", dist.name());

                let mut got = i64s.clone();
                sorter.sort_i64(&mut got, &p);
                let mut expect = i64s.clone();
                expect.sort_unstable();
                assert_eq!(got, expect, "i64 {ctx}");

                let mut got = i32s.clone();
                sorter.sort_i32(&mut got, &p);
                let mut expect = i32s.clone();
                expect.sort_unstable();
                assert_eq!(got, expect, "i32 {ctx}");

                let mut got = u64s.clone();
                sorter.sort_u64(&mut got, &p);
                let mut expect = u64s.clone();
                expect.sort_unstable();
                assert_eq!(got, expect, "u64 {ctx}");

                let mut got = f64s.clone();
                sorter.sort_f64(&mut got, &p);
                let mut expect = f64s.clone();
                expect.sort_by(f64::total_cmp);
                let same = got.len() == expect.len()
                    && got.iter().zip(&expect).all(|(a, b)| a.total_cmp(b).is_eq());
                assert!(same, "f64 {ctx}");
            }
        }
    }
}

#[test]
#[cfg_attr(miri, ignore = "minutes-slow under Miri; lib small-n variants cover the kernel")]
fn radix_width_matrix_matches_std_sort() {
    run_matrix(6_000);
}

#[test]
#[cfg_attr(miri, ignore = "integration tests are not part of the Miri job")]
fn radix_width_matrix_small_n() {
    // Small enough that per-thread blocks collapse to one worker and the
    // narrow-range skip fires on the clustered distributions — the geometry
    // edge cases the big sweep's sizes never hit.
    run_matrix(96);
}
