//! PR-5 acceptance: after warmup, the steady-state service sort path
//! performs **zero thread spawns** and **zero scratch allocations**.
//!
//! The service below is built the default way — with a *disabled* tracer —
//! so the flat-alloc assertions double as the observability guarantee that
//! tracing off means no hot-path cost: no ring, no clock reads, no trace
//! buffers growing (asserted explicitly at the end).
//!
//! This file deliberately holds a single `#[test]`: the spawn counter is
//! process-global (`exec::thread_spawn_count`), so the assertions are only
//! race-free when nothing else in the same test binary constructs executors
//! or services concurrently. Integration test binaries run one at a time,
//! and within this binary there is exactly one test.

use evosort::coordinator::{ServiceConfig, SortRequest, SortService};
use evosort::data::{generate_i64, Distribution};
use evosort::exec;
use evosort::params::{ACode, SortParams};
use evosort::sort::{AdaptiveSorter, SortKey, SortScratch};

const N: usize = 120_000;

fn batch(svc: &SortService, jobs: usize) {
    let requests: Vec<SortRequest> = (0..jobs)
        .map(|i| SortRequest::new(generate_i64(N, Distribution::Uniform, i as u64, 2)))
        .collect();
    let report = svc.submit_batch_requests(requests).wait();
    assert_eq!(report.stats.failed, 0);
    assert_eq!(report.stats.invalid, 0);
    assert_eq!(report.stats.jobs, jobs);
}

#[test]
fn steady_state_sort_path_is_spawn_free_and_alloc_free() {
    // --- Service level: spawn counter + arena-growth metric across a
    // 100-job batch, flat after warmup. A single pool worker makes the
    // arena assertion deterministic: with several workers, one could sleep
    // through the whole warmup batch (its queue shard drains first) and
    // first-grow its thread-local arena mid-measurement. ----------------
    let svc = SortService::new(ServiceConfig::sized(1, 2, 32));
    assert!(!svc.tracer().is_enabled(), "the default service must not trace");
    // Warmup: first-sizes the worker's scratch arena and forces the
    // lazily-built global executor (data generation runs on it).
    batch(&svc, 8);
    let grows_before = svc.metrics().counter("scratch.grows");
    assert!(grows_before > 0, "warmup must have sized the arena");
    let spawns_before = exec::thread_spawn_count();

    // The 100-job steady-state batch the acceptance criterion names.
    batch(&svc, 100);

    assert_eq!(
        exec::thread_spawn_count(),
        spawns_before,
        "steady-state batch must not spawn a single OS thread"
    );
    assert_eq!(
        svc.metrics().counter("scratch.grows"),
        grows_before,
        "steady-state batch must not grow any worker's scratch arena"
    );

    // The single-job path reuses the same per-worker arenas and parked
    // pool: still flat.
    for seed in 200..205u64 {
        let data = generate_i64(N, Distribution::Uniform, seed, 2);
        let out = svc.submit_request(SortRequest::new(data)).wait().expect("job ok");
        assert!(out.valid);
    }
    assert_eq!(exec::thread_spawn_count(), spawns_before, "single-job path spawns nothing");
    assert_eq!(
        svc.metrics().counter("scratch.grows"),
        grows_before,
        "single-job path reuses the warm arenas"
    );
    // Tracing-disabled means fully inert: no events buffered, none dropped,
    // and no kernel-phase sample windows accumulating behind the scenes.
    assert_eq!(svc.tracer().dropped(), 0);
    assert_eq!(svc.metrics().counter("trace.dropped"), 0);
    for p in evosort::obs::Phase::all() {
        assert!(
            svc.metrics().percentile(p.metric_name(), 50.0).is_none(),
            "{}: untraced sorts must not record phase samples",
            p.metric_name()
        );
    }

    // --- Sorter level: every Algorithm-6 kernel keeps one arena warm
    // across 100 same-shape jobs. -------------------------------------
    for algo in [ACode::Radix, ACode::Merge, ACode::Sample] {
        let sorter = AdaptiveSorter::new(2);
        let mut scratch = SortScratch::new();
        let p = SortParams { algorithm: algo, fallback_threshold: 100, ..Default::default() };
        let base = generate_i64(N, Distribution::Uniform, 7, 2);
        let mut expect = base.clone();
        expect.sort_unstable();

        let mut data = base.clone();
        <i64 as SortKey>::sort_with(&sorter, &mut data, &p, &mut scratch);
        assert_eq!(data, expect, "{algo:?} warmup");
        let grows_after_first = scratch.grows();
        assert!(grows_after_first > 0, "{algo:?}: the first job sizes the arena");

        for _ in 0..99 {
            let mut data = base.clone();
            <i64 as SortKey>::sort_with(&sorter, &mut data, &p, &mut scratch);
            assert_eq!(data, expect);
        }
        assert_eq!(
            scratch.grows(),
            grows_after_first,
            "{algo:?}: jobs 2..=100 must not allocate scratch"
        );
    }
}
