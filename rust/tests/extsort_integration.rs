//! Out-of-core integration: the service's spill path end to end.
//!
//! A `SortService` with a memory budget must
//!
//! 1. escalate every beyond-budget job — across all four dtypes and all
//!    nine distributions — through spill-to-disk runs and still pass the
//!    service's multiset + sortedness validation,
//! 2. stream sorted chunks whose concatenation is exactly the sorted
//!    payload, for every dtype,
//! 3. keep the tracked sort-path working set within the byte budget, and
//! 4. tune the spill genes online under the beyond-memory (`:xm`)
//!    fingerprint class,
//!
//! while never leaving spill files behind.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use evosort::coordinator::{ServiceConfig, SortRequest, SortService};
use evosort::data::{self, Distribution};
use evosort::extsort::{ExtKey, ExternalConfig};
use evosort::sort::{Dtype, SortPayload};

fn spill_root(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("evosort-xint-{}-{}", std::process::id(), tag));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn spill_dirs_left(root: &Path) -> usize {
    std::fs::read_dir(root).map(|it| it.filter_map(|e| e.ok()).count()).unwrap_or(0)
}

fn external_service(budget: usize, root: &Path) -> SortService {
    SortService::new(
        ServiceConfig::sized(2, 2, 64)
            .with_external(ExternalConfig::new(budget).with_spill_dir(root.to_path_buf())),
    )
}

#[test]
fn every_dtype_and_distribution_survives_the_spill_path() {
    let root = spill_root("matrix");
    // 128 KiB budget: a 60k-element job spills >= 4 runs at i32 width and
    // 8 at i64 width — every cell of the matrix genuinely goes out of core.
    let budget = 128 * 1024;
    let svc = external_service(budget, &root);
    let n = 60_000;
    assert_eq!(Distribution::all().len(), 9, "the full distribution matrix");
    let mut jobs = 0u64;
    for (i, &dist) in Distribution::all().iter().enumerate() {
        for (j, &dtype) in Dtype::all().iter().enumerate() {
            let raw = data::generate_i64(n, dist, (i * 16 + j) as u64, 2);
            let payload = SortPayload::from_i64_values(raw, dtype);
            let out = svc
                .submit_request(SortRequest::from_payload(payload).with_dist(dist.name()))
                .wait()
                .expect("job completed");
            // `validate: true` makes the service itself check multiset
            // equality (fingerprint) and sortedness of the spilled result.
            assert!(out.valid, "{dtype} {} failed spill-path validation", dist.name());
            jobs += 1;
        }
    }
    svc.drain();
    assert_eq!(svc.metrics().counter("extsort.jobs"), jobs, "every job escalated");
    assert!(
        svc.metrics().counter("extsort.runs_spilled") >= jobs * 3,
        "each job must spill at least 3 runs"
    );
    assert_eq!(svc.metrics().counter("jobs.invalid"), 0);
    assert_eq!(spill_dirs_left(&root), 0, "spill root must be clean after the matrix");
    let _ = std::fs::remove_dir_all(&root);
}

/// Drive one payload through the chunk-streaming surface and require the
/// in-order chunk concatenation to equal `expect`.
fn stream_and_check<K: ExtKey + PartialEq + std::fmt::Debug>(
    svc: &SortService,
    payload: SortPayload,
    expect: Vec<K>,
) {
    let dtype = payload.dtype();
    let ticket = svc.submit_external_streaming(SortRequest::from_payload(payload));
    let total = ticket.len();
    assert!(total > 1, "{dtype}: a spilled job streams more than one chunk");
    let mut got: Vec<K> = Vec::with_capacity(expect.len());
    let mut chunks = 0usize;
    for r in ticket.stream() {
        let out = r.expect("chunk delivered");
        got.extend_from_slice(out.data::<K>().expect("chunk carries the request dtype"));
        chunks += 1;
    }
    assert_eq!(chunks, total, "{dtype}: ticket length is the chunk-count contract");
    assert_eq!(got, expect, "{dtype}: chunk concatenation must be the sorted payload");
}

#[test]
fn streaming_chunks_reassemble_for_every_dtype() {
    let root = spill_root("stream-dtypes");
    let svc = external_service(1 << 20, &root);
    let n = 220_000;
    for (j, &dtype) in Dtype::all().iter().enumerate() {
        let raw = data::generate_i64(n, Distribution::Zipf, j as u64, 2);
        let payload = SortPayload::from_i64_values(raw, dtype);
        match payload.clone() {
            SortPayload::I64(mut v) => {
                v.sort_unstable();
                stream_and_check(&svc, payload, v);
            }
            SortPayload::I32(mut v) => {
                v.sort_unstable();
                stream_and_check(&svc, payload, v);
            }
            SortPayload::U64(mut v) => {
                v.sort_unstable();
                stream_and_check(&svc, payload, v);
            }
            SortPayload::F64(mut v) => {
                v.sort_unstable_by(f64::total_cmp);
                stream_and_check(&svc, payload, v);
            }
        }
    }
    svc.drain();
    assert_eq!(svc.metrics().counter("jobs.completed"), Dtype::all().len() as u64);
    assert_eq!(spill_dirs_left(&root), 0);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn tracked_peak_working_set_honours_the_budget() {
    let root = spill_root("peak");
    let budget = 1 << 20;
    let svc = external_service(budget, &root);
    // 3.2 MiB of i64 against a 1 MiB budget.
    let data = data::generate_i64(400_000, Distribution::Gaussian, 7, 2);
    let out = svc.submit_request(SortRequest::new(data)).wait().expect("job completed");
    assert!(out.valid);
    svc.drain();
    let peak = svc.metrics().gauge("extsort.last_peak_bytes").expect("gauge published") as usize;
    assert!(peak > 0, "the external sort must report its working set");
    assert!(
        peak <= budget,
        "tracked sort-path working set ({peak} bytes) exceeds the {budget}-byte budget"
    );
    assert_eq!(spill_dirs_left(&root), 0);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn spill_genes_tune_under_the_beyond_memory_class() {
    use evosort::autotune::fingerprint::beyond_memory_label;
    use evosort::autotune::AutotunePolicy;
    use evosort::extsort::ExtParams;
    use evosort::params::SortParams;

    let root = spill_root("xm-tune");
    let budget = 512 * 1024;
    // quick() = eager test policy (tiny observation thresholds, no
    // noise margin), as in the in-RAM adaptation test.
    let policy = AutotunePolicy { generations_per_cycle: 2, ..AutotunePolicy::quick() };
    let svc = SortService::new(
        ServiceConfig::sized(2, 2, 32)
            .with_autotune(policy)
            .with_external(ExternalConfig::new(budget).with_spill_dir(root.clone())),
    );
    let n = 120_000; // 960 KiB of i64 — every job escalates
    let dist = Distribution::Uniform;
    let xm = beyond_memory_label(&SortService::fingerprint_label(&data::generate_i64(n, dist, 0, 2)));
    assert!(xm.ends_with(":xm"), "escalated jobs key the beyond-memory class: {xm}");

    // Seed deliberately degenerate genes (1k-element runs, fan-in 2) so the
    // hill-climb has obvious room and any publish visibly replaces them.
    let awful = ExtParams { run_size: 1024, merge_fan_in: 2, spill_threshold: 0 };
    svc.cache().put_ext_with_fitness(n, &xm, SortParams::paper_1e8(), awful, f64::NAN);
    assert_eq!(svc.cache().get_ext(n, &xm), Some(awful));

    let deadline = Instant::now() + Duration::from_secs(120);
    let mut round = 0u64;
    while svc.cache().get_ext(n, &xm) == Some(awful) && Instant::now() < deadline {
        let requests: Vec<SortRequest> = (0..4)
            .map(|i| SortRequest::new(data::generate_i64(n, dist, round * 4 + i, 2)))
            .collect();
        let report = svc.submit_batch_requests(requests).wait();
        assert_eq!(report.stats.failed, 0);
        assert_eq!(report.stats.invalid, 0);
        round += 1;
    }

    let tuned = svc.cache().get_ext(n, &xm).expect("ext genes stay cached for the class");
    assert_ne!(tuned, awful, "the tuner published better spill genes for the xm class");
    assert!(svc.metrics().counter("tuner.ext_publishes") > 0);
    assert_eq!(spill_dirs_left(&root), 0, "tuning traffic must not leak spill files");
    let _ = std::fs::remove_dir_all(&root);
}
