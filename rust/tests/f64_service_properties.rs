//! f64 total-order edge cases through the service path (companion to
//! `fingerprint_properties.rs`): NaN placement (−NaN first, +NaN last,
//! payloads preserved bit-exactly), `-0.0` vs `+0.0` ordering, and ±inf —
//! across the nine paper distributions and across arbitrary bit patterns
//! from the property runner.

use evosort::coordinator::{ServiceConfig, SortRequest, SortService};
use evosort::data::{generate_i64, Distribution};
use evosort::testkit::{check, PropConfig};

fn service() -> SortService {
    SortService::new(ServiceConfig::sized(2, 2, 16))
}

/// Sort `data` through the service (validation on) and compare bit-exactly
/// against the `total_cmp` oracle.
fn assert_service_total_order(svc: &SortService, data: Vec<f64>) {
    let mut expect = data.clone();
    expect.sort_by(|a, b| a.total_cmp(b));
    let expect_bits: Vec<u64> = expect.iter().map(|x| x.to_bits()).collect();
    let out = svc.submit_request(SortRequest::new(data)).wait().expect("job completed");
    assert!(out.valid, "service-side validation must accept a correct f64 sort");
    let got_bits: Vec<u64> = out.data::<f64>().unwrap().iter().map(|x| x.to_bits()).collect();
    assert_eq!(got_bits, expect_bits, "bit-exact total_cmp order");
}

/// The specials every distribution gets seeded with: signed NaNs (distinct
/// payloads), both infinities, both zeros, and subnormals.
fn specials() -> Vec<f64> {
    vec![
        f64::NAN,
        -f64::NAN,
        f64::from_bits(0x7FF8_0000_0000_0001), // +NaN, different payload
        f64::from_bits(0xFFF8_0000_0000_0001), // -NaN, different payload
        f64::INFINITY,
        f64::NEG_INFINITY,
        0.0,
        -0.0,
        f64::MIN_POSITIVE / 2.0,
        -f64::MIN_POSITIVE / 2.0,
    ]
}

#[test]
fn nine_distributions_with_specials_sort_in_total_order() {
    let svc = service();
    for &dist in Distribution::all() {
        let mut data: Vec<f64> = generate_i64(20_000, dist, 9, 2)
            .into_iter()
            .map(|x| x as f64 / 7.0)
            .collect();
        // Scatter the specials through the body, not just the ends.
        for (i, s) in specials().into_iter().enumerate() {
            data[i * 1_777 % 20_000] = s;
        }
        assert_service_total_order(&svc, data);
    }
    assert_eq!(svc.metrics().counter("jobs.invalid"), 0);
    assert_eq!(svc.metrics().counter("jobs.dtype.f64"), Distribution::all().len() as u64);
}

#[test]
fn nan_placement_and_zero_ordering() {
    let svc = service();
    let data = vec![1.0, -f64::NAN, 0.0, f64::NAN, -0.0, f64::NEG_INFINITY, f64::INFINITY, -1.0];
    let out = svc.submit_request(SortRequest::new(data)).wait().unwrap();
    assert!(out.valid);
    let got = out.data::<f64>().unwrap();
    // total order: -NaN < -inf < -1 < -0.0 < +0.0 < 1 < +inf < +NaN.
    assert!(got[0].is_nan() && got[0].is_sign_negative(), "-NaN first");
    assert_eq!(got[1], f64::NEG_INFINITY);
    assert_eq!(got[2], -1.0);
    assert!(got[3] == 0.0 && got[3].is_sign_negative(), "-0.0 before +0.0");
    assert!(got[4] == 0.0 && got[4].is_sign_positive());
    assert_eq!(got[5], 1.0);
    assert_eq!(got[6], f64::INFINITY);
    assert!(got[7].is_nan() && got[7].is_sign_positive(), "+NaN last");
}

#[test]
fn all_nan_and_all_same_zero_payloads() {
    let svc = service();
    // An array of nothing but NaNs (mixed signs/payloads) must validate:
    // the multiset fingerprint is over raw bits, so payloads count.
    let mut nans = Vec::new();
    for i in 0..4_000u64 {
        let payload = 0x7FF8_0000_0000_0000u64 | (i % 97);
        let sign = if i % 3 == 0 { 0x8000_0000_0000_0000 } else { 0 };
        nans.push(f64::from_bits(payload | sign));
    }
    assert_service_total_order(&svc, nans);
    // Mixed zeros only.
    let zeros: Vec<f64> = (0..2_000).map(|i| if i % 2 == 0 { 0.0 } else { -0.0 }).collect();
    assert_service_total_order(&svc, zeros);
}

#[test]
fn prop_arbitrary_bit_patterns_round_trip_the_service() {
    // Reinterpret arbitrary i64 bit patterns as f64: NaN payloads,
    // subnormals, infinities and ordinary values all appear. The service
    // must return exactly the same multiset in total_cmp order.
    let svc = service();
    let result = check::<Vec<i64>>(PropConfig { cases: 120, seed: 33, ..Default::default() }, |v| {
        let data: Vec<f64> = v.iter().map(|&x| f64::from_bits(x as u64)).collect();
        let mut expect = data.clone();
        expect.sort_by(|a, b| a.total_cmp(b));
        let expect_bits: Vec<u64> = expect.iter().map(|x| x.to_bits()).collect();
        let out = match svc.submit_request(SortRequest::new(data)).wait() {
            Ok(out) => out,
            Err(_) => return false,
        };
        let got_bits: Vec<u64> = out.data::<f64>().unwrap().iter().map(|x| x.to_bits()).collect();
        out.valid && got_bits == expect_bits
    });
    result.unwrap_ok();
}

#[test]
fn f64_fingerprint_classes_stay_stable_across_realisations() {
    // Same guarantee `fingerprint_properties.rs` gives for i64, at the f64
    // dtype: different seeds of one distribution share a (tagged) class.
    for &dist in Distribution::all() {
        let a: Vec<f64> =
            generate_i64(100_000, dist, 1, 2).into_iter().map(|x| x as f64).collect();
        let b: Vec<f64> =
            generate_i64(100_000, dist, 99, 2).into_iter().map(|x| x as f64).collect();
        let la = SortService::fingerprint_label_for(&a);
        let lb = SortService::fingerprint_label_for(&b);
        assert_eq!(la, lb, "{}: different seeds must land in the same f64 class", dist.name());
        assert!(la.ends_with(":f64"), "{la}");
    }
}
