//! End-to-end online-adaptation test: a `SortService` with autotuning
//! enabled, fed repeated batches of one workload shape, must
//!
//! 1. measurably change the cached `SortParams` for that fingerprint class
//!    versus the cold-start (symbolic-model) defaults,
//! 2. keep the submit hot path non-blocking while the tuner thread runs, and
//! 3. shut the tuner down cleanly on drop.

use std::time::{Duration, Instant};

use evosort::autotune::AutotunePolicy;
use evosort::coordinator::{ServiceConfig, SortRequest, SortService};
use evosort::data::{generate_i64, Distribution};
use evosort::symbolic::SymbolicModel;

fn autotuned_service() -> SortService {
    // quick() = eager test policy: tiny observation thresholds, full CPU
    // share, no noise margin (deterministic adaptation is under test).
    let policy = AutotunePolicy { generations_per_cycle: 2, ..AutotunePolicy::quick() };
    SortService::new(ServiceConfig::sized(2, 2, 32).with_autotune(policy))
}

#[test]
fn service_adapts_to_repeated_workload_shape() {
    let svc = autotuned_service();
    assert!(svc.autotuning());
    let n = 30_000;
    let dist = Distribution::Uniform;
    let label = SortService::fingerprint_label(&generate_i64(n, dist, 0, 2));
    let cold_start = SymbolicModel::paper().params_for(n);
    assert!(
        svc.cache().get(n, &label).is_none(),
        "cache must start cold for the workload class"
    );

    // Feed repeated batches of the same shape until the tuner publishes
    // parameters for the class (bounded by a generous deadline; each cycle
    // on a 4k-element sample takes milliseconds).
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut batches = 0u64;
    let mut max_submit_call = Duration::ZERO;
    while svc.cache().get(n, &label).is_none() && Instant::now() < deadline {
        let requests: Vec<SortRequest> = (0..8)
            .map(|i| SortRequest::new(generate_i64(n, dist, batches * 8 + i, 2)))
            .collect();
        // The submit call itself only fingerprints + enqueues: it must stay
        // fast even while the tuner thread is busy refining.
        let t0 = Instant::now();
        let ticket = svc.submit_batch_requests(requests);
        max_submit_call = max_submit_call.max(t0.elapsed());
        let report = ticket.wait();
        assert_eq!(report.stats.invalid, 0);
        batches += 1;
    }

    let tuned = svc
        .cache()
        .get(n, &label)
        .expect("tuner published parameters for the hot fingerprint class");
    assert_ne!(
        tuned, cold_start,
        "published parameters must differ from the cold-start symbolic defaults \
         (the tuner only publishes when the GA beat the seed genome)"
    );
    assert!(svc.metrics().counter("tuner.cycles") > 0);
    assert!(svc.metrics().counter("tuner.generations") > 0);
    assert!(svc.metrics().counter("tuner.publishes") > 0);
    assert!(svc.metrics().gauge("tuner.classes").unwrap_or(0.0) >= 1.0);

    // Zero hot-path blocking: enqueue+fingerprint of an 8-job batch of 30k
    // elements is microseconds of work; even heavily loaded CI machines stay
    // orders of magnitude under this bound — while GA cycles run for
    // comparison at full CPU share.
    assert!(
        max_submit_call < Duration::from_secs(2),
        "submit_batch blocked for {max_submit_call:?} while the tuner ran"
    );

    // The tuned class is now served to new jobs of the same shape. (The
    // tuner may re-publish between our cache read and this submit, so
    // assert resolution went through the cache rather than exact equality
    // with the snapshot above.)
    let hits_before = svc.metrics().counter("params.cache_hit");
    let data = generate_i64(n, dist, 9999, 2);
    let out = svc.submit_request(SortRequest::new(data)).wait().expect("job completed");
    assert!(out.valid);
    assert!(
        svc.metrics().counter("params.cache_hit") > hits_before,
        "subsequent submits must resolve through the tuned fingerprint class"
    );
    assert_ne!(out.params, cold_start, "served params must be the tuned ones, not defaults");

    // Clean shutdown: dropping the service joins the tuner thread.
    let t0 = Instant::now();
    drop(svc);
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "service drop must join the tuner promptly"
    );
}

#[test]
fn autotune_off_means_no_tuner_metrics() {
    let svc = SortService::new(ServiceConfig::sized(1, 2, 8));
    assert!(!svc.autotuning());
    let data = generate_i64(20_000, Distribution::Uniform, 1, 2);
    let out = svc.submit_request(SortRequest::new(data)).wait().expect("job completed");
    assert!(out.valid);
    svc.drain();
    assert_eq!(svc.metrics().counter("tuner.observations"), 0);
    assert_eq!(svc.metrics().counter("tuner.cycles"), 0);
}

#[test]
fn tuned_params_persist_and_restore_across_service_restarts() {
    let path = std::env::temp_dir().join(format!(
        "evosort-autotune-persist-{}.txt",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    let policy = AutotunePolicy { persist_path: Some(path.clone()), ..AutotunePolicy::quick() };
    let n = 30_000;

    // First service lifetime: adapt and persist.
    {
        let svc = SortService::new(ServiceConfig::sized(2, 2, 32).with_autotune(policy.clone()));
        let deadline = Instant::now() + Duration::from_secs(120);
        let mut round = 0u64;
        while svc.cache().is_empty() && Instant::now() < deadline {
            let requests: Vec<SortRequest> = (0..8)
                .map(|i| {
                    SortRequest::new(generate_i64(n, Distribution::Uniform, round * 8 + i, 2))
                })
                .collect();
            let _ = svc.submit_batch_requests(requests).wait();
            round += 1;
        }
        assert!(!svc.cache().is_empty(), "first lifetime never adapted");
    }
    assert!(path.exists(), "publishing must persist the versioned cache file");

    // Second lifetime: the tuned classes are restored at startup.
    let svc = SortService::new(ServiceConfig::sized(1, 2, 8).with_autotune(Some(policy)));
    assert!(
        !svc.cache().is_empty(),
        "restart must restore fingerprint-keyed params from disk"
    );
    std::fs::remove_file(&path).unwrap();
}
