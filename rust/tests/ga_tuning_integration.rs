//! GA-tuner integration: convergence behaviour on a real fitness landscape,
//! the gen-0 spread the paper's figures show, and the §7 round trip
//! (GA sweep → quadratic fit → deployable params).

use evosort::data::Distribution;
use evosort::ga::{GaConfig, GaDriver, SortTimingFitness};
use evosort::params::{ACode, Bounds, SortParams};
use evosort::sort::AdaptiveSorter;
use evosort::symbolic::SymbolicModel;

#[test]
fn ga_converges_on_real_landscape() {
    // On 300k uniform i64, radix configurations dominate merge ones, so the
    // GA should (a) improve from gen 0 and (b) end on a radix genome.
    let sample = evosort::data::generate_i64(300_000, Distribution::Uniform, 3, 2);
    let fitness = SortTimingFitness::new(sample, AdaptiveSorter::new(2), 2);
    let cfg = GaConfig { population: 14, generations: 6, seed: 21, ..Default::default() };
    let r = GaDriver::new(cfg).run(fitness);

    let g0 = &r.history[0];
    let last = r.history.last().unwrap();
    assert!(
        last.best <= g0.average,
        "final best ({:.4}) should beat the gen-0 average ({:.4})",
        last.best,
        g0.average
    );
    assert_eq!(
        SortParams::from_genes(&r.best_genome).algorithm,
        ACode::Radix,
        "radix should win on large uniform integers (paper §6: A_code = 4)"
    );
    assert!(Bounds::default().validate(&r.best_genome));
}

#[test]
fn ga_generation0_spread_is_wide() {
    // The paper's Figures 2-6 show a wide gen-0 spread (bad configs are
    // *much* worse). Log-uniform init should reproduce that.
    let sample = evosort::data::generate_i64(200_000, Distribution::Uniform, 5, 2);
    let fitness = SortTimingFitness::new(sample, AdaptiveSorter::new(2), 1);
    let cfg = GaConfig { population: 16, generations: 1, seed: 23, ..Default::default() };
    let r = GaDriver::new(cfg).run(fitness);
    let g0 = &r.history[0];
    assert!(
        g0.worst > g0.best * 1.5,
        "gen-0 spread too narrow: best {:.4} worst {:.4}",
        g0.best,
        g0.worst
    );
}

#[test]
fn sweep_fit_deploy_roundtrip() {
    // §7 end-to-end at test scale: GA sweep over sizes → quadratic fit →
    // params_for(n) must produce valid, radix-coded configurations that
    // actually sort.
    let threads = 2;
    let sizes = [50_000usize, 100_000, 200_000, 400_000, 800_000];
    let mut sweep = Vec::new();
    for &n in &sizes {
        let cfg = GaConfig { population: 6, generations: 3, seed: 31 ^ n as u64, ..Default::default() };
        let r = GaDriver::new(cfg).run_for_size(
            n,
            200_000,
            Distribution::Uniform,
            AdaptiveSorter::new(threads),
        );
        sweep.push((n, r.best));
    }
    let model = SymbolicModel::fit(&sweep).expect("quadratic fit");
    for n in [75_000usize, 300_000, 600_000] {
        let p = model.params_for(n);
        assert_eq!(p.algorithm, ACode::Radix);
        assert!(Bounds::default().validate(&p.to_genes()), "params_for({n}) out of bounds: {p}");
        // Deploy: the params must actually sort.
        let mut data = evosort::data::generate_i64(n, Distribution::Uniform, 7, threads);
        let mut expect = data.clone();
        expect.sort_unstable();
        AdaptiveSorter::new(threads).sort_i64(&mut data, &p);
        assert_eq!(data, expect);
    }
}

#[test]
fn fitness_never_disqualifies_valid_stack() {
    // Every genome the GA proposes must evaluate finite (no configuration of
    // a correct stack should be disqualified by the validation gate).
    let sample = evosort::data::generate_i64(50_000, Distribution::Uniform, 9, 2);
    let mut fitness = SortTimingFitness::new(sample, AdaptiveSorter::new(2), 1);
    use evosort::rng::Xoshiro256pp;
    let bounds = Bounds::default();
    let mut rng = Xoshiro256pp::seeded(33);
    for _ in 0..30 {
        let g = evosort::ga::individual::random_genome(&bounds, &mut rng);
        let t = fitness.eval(&g);
        assert!(t.is_finite(), "genome {g:?} was disqualified");
    }
}
