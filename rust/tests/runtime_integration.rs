//! Integration tests over the PJRT runtime: load the AOT artifacts emitted by
//! `make artifacts`, compile on the CPU client, execute, and check numerics
//! against the rust-side oracles. Skipped (with a loud message) when
//! artifacts are missing.

use evosort::data::{generate_i32, Distribution};
use evosort::params::{ACode, SortParams};
use evosort::runtime::{Manifest, XlaTileSorter};
use evosort::sort::{AdaptiveSorter, TileSorter};

fn load_backend() -> Option<XlaTileSorter> {
    let dir = Manifest::default_dir();
    match Manifest::load(&dir) {
        Ok(m) => match XlaTileSorter::new(&m) {
            Ok(b) => Some(b),
            Err(e) => panic!("artifacts exist but backend failed: {e:#}"),
        },
        Err(_) => {
            eprintln!("SKIP: no artifacts (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn tile_sort_artifact_sorts_rows() {
    let Some(backend) = load_backend() else { return };
    let tile = backend.tile_size();
    let n_tiles = 5;
    let mut data = generate_i32(tile * n_tiles, Distribution::Uniform, 1, 2);
    let original = data.clone();
    backend.sort_tiles_i32(&mut data).unwrap();
    for (t, chunk) in data.chunks(tile).enumerate() {
        assert!(chunk.windows(2).all(|w| w[0] <= w[1]), "tile {t} unsorted");
        // Same multiset per tile.
        let mut got = chunk.to_vec();
        let mut want = original[t * tile..(t + 1) * tile].to_vec();
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want, "tile {t} multiset");
    }
}

#[test]
fn tile_sort_handles_partial_batch() {
    let Some(backend) = load_backend() else { return };
    let tile = backend.tile_size();
    // More tiles than one executable batch, not a multiple of the batch.
    let n_tiles = backend.batch() + 3;
    let mut data = generate_i32(tile * n_tiles, Distribution::Uniform, 3, 2);
    backend.sort_tiles_i32(&mut data).unwrap();
    for chunk in data.chunks(tile) {
        assert!(chunk.windows(2).all(|w| w[0] <= w[1]));
    }
}

#[test]
fn tile_sort_rejects_ragged_input() {
    let Some(backend) = load_backend() else { return };
    let mut data = vec![0i32; backend.tile_size() + 1];
    assert!(backend.sort_tiles_i32(&mut data).is_err());
}

#[test]
fn histogram_artifact_matches_rust_oracle() {
    let Some(backend) = load_backend() else { return };
    let tile = backend.tile_size();
    let batch = backend.batch();
    let data = generate_i32(tile * batch, Distribution::Uniform, 5, 2);
    for shift in [0i32, 8, 16, 24] {
        let hists = backend.histogram_batch(data.clone(), shift).unwrap();
        assert_eq!(hists.len(), batch * 256);
        for (b, block) in data.chunks(tile).enumerate() {
            let mut want = [0i32; 256];
            for &x in block {
                want[((x as u32 >> shift) & 0xFF) as usize] += 1;
            }
            assert_eq!(&hists[b * 256..(b + 1) * 256], &want[..], "block {b} shift {shift}");
        }
    }
}

#[test]
fn adaptive_sorter_uses_xla_backend_end_to_end() {
    let Some(backend) = load_backend() else { return };
    let sorter = AdaptiveSorter::new(4).with_xla(std::sync::Arc::new(backend));
    let params = SortParams {
        algorithm: ACode::XlaTile,
        fallback_threshold: 16,
        ..SortParams::default()
    };
    // Length deliberately not a multiple of the tile size.
    let mut data = generate_i32(50_000 + 123, Distribution::Uniform, 7, 4);
    let mut expect = data.clone();
    expect.sort_unstable();
    sorter.sort_i32(&mut data, &params);
    assert_eq!(data, expect);
}
