//! Property-based integration tests over the whole sorting stack, using the
//! in-crate `testkit` mini-framework (generation + shrinking).
//!
//! Invariants checked across thousands of random vectors:
//!  * every algorithm produces output identical to the std-sort oracle;
//!  * every algorithm preserves the input multiset (fingerprint);
//!  * the adaptive dispatcher is oracle-equal for *any* (possibly clamped)
//!    genome, i.e. no parameter setting can produce a wrong sort;
//!  * sorting is idempotent.

use evosort::data::validate::{fingerprint_i64, validate_i64, Verdict};
use evosort::params::SortParams;
use evosort::sort::{parallel_merge_sort, radix_sort, AdaptiveSorter, Baseline, MergeTuning};
use evosort::testkit::{check, ArbGenome, PropConfig, PropResult};

fn oracle(v: &[i64]) -> Vec<i64> {
    let mut s = v.to_vec();
    s.sort_unstable();
    s
}

#[test]
fn prop_radix_equals_oracle() {
    check::<Vec<i64>>(PropConfig { cases: 300, seed: 1, ..Default::default() }, |v| {
        let mut got = v.clone();
        radix_sort(&mut got, 3);
        got == oracle(v)
    })
    .unwrap_ok();
}

#[test]
fn prop_parallel_merge_equals_oracle() {
    check::<Vec<i64>>(PropConfig { cases: 300, seed: 2, ..Default::default() }, |v| {
        let mut got = v.clone();
        let tuning = MergeTuning {
            insertion_threshold: 16, // tiny threshold => deep merging even on small cases
            threads: 3,
            ..Default::default()
        };
        parallel_merge_sort(&mut got, &tuning);
        got == oracle(v)
    })
    .unwrap_ok();
}

#[test]
fn prop_baselines_equal_oracle() {
    check::<Vec<i64>>(PropConfig { cases: 200, seed: 3, ..Default::default() }, |v| {
        Baseline::all().iter().all(|b| {
            let mut got = v.clone();
            b.sort_i64(&mut got);
            got == oracle(v)
        })
    })
    .unwrap_ok();
}

#[test]
fn prop_any_genome_sorts_correctly() {
    // The dispatcher must be correct for every genome the GA could ever
    // propose (including out-of-bounds genes, which from_genes clamps).
    let sorter = AdaptiveSorter::new(2);
    let data: Vec<Vec<i64>> = {
        use evosort::rng::Xoshiro256pp;
        use evosort::testkit::Arbitrary;
        let mut rng = Xoshiro256pp::seeded(99);
        (0..10).map(|_| Vec::<i64>::generate(&mut rng)).collect()
    };
    check::<ArbGenome>(PropConfig { cases: 150, seed: 4, ..Default::default() }, |g| {
        let params = SortParams::from_genes(&g.0);
        data.iter().all(|v| {
            let mut got = v.clone();
            sorter.sort_i64(&mut got, &params);
            got == oracle(v)
        })
    })
    .unwrap_ok();
}

#[test]
fn prop_multiset_preserved() {
    check::<Vec<i64>>(PropConfig { cases: 200, seed: 5, ..Default::default() }, |v| {
        let fp = fingerprint_i64(v, 2);
        let mut got = v.clone();
        radix_sort(&mut got, 2);
        validate_i64(fp, &got, 2) == Verdict::Valid
    })
    .unwrap_ok();
}

#[test]
fn prop_idempotent() {
    check::<Vec<i64>>(PropConfig { cases: 150, seed: 6, ..Default::default() }, |v| {
        let mut once = v.clone();
        radix_sort(&mut once, 2);
        let mut twice = once.clone();
        radix_sort(&mut twice, 2);
        once == twice
    })
    .unwrap_ok();
}

#[test]
fn prop_failure_report_shape() {
    // Meta-test: a deliberately broken "sort" must fail with a small shrunk
    // counterexample, demonstrating the harness actually bites.
    let r = check::<Vec<i64>>(
        PropConfig { cases: 500, seed: 7, ..Default::default() },
        |v| {
            let mut got = v.clone();
            got.sort_unstable();
            if got.len() > 3 && got[0] != got[1] {
                got.swap(0, 1); // sabotage
            }
            got == oracle(v)
        },
    );
    match r {
        PropResult::Failed { minimal, original, .. } => {
            assert!(minimal.len() >= 4, "minimal case too small: {minimal:?}");
            assert!(minimal.len() <= original.len());
        }
        PropResult::Ok => panic!("sabotaged sort must fail"),
    }
}
