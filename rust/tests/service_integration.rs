//! Sort-service integration: concurrency, backpressure, parameter
//! resolution, metrics accounting and cache persistence round-trips.

use evosort::coordinator::{ServiceConfig, SortJob, SortService, TuningCache};
use evosort::data::{generate_i64, Distribution};
use evosort::params::SortParams;

#[test]
fn service_sorts_mixed_workloads_concurrently() {
    let svc = SortService::new(ServiceConfig {
        workers: 3,
        sort_threads: 2,
        queue_capacity: 4,
        autotune: None,
    });
    let workloads = [
        (Distribution::Uniform, "uniform"),
        (Distribution::Zipf, "zipf"),
        (Distribution::Reverse, "reverse"),
        (Distribution::FewUnique, "few-unique"),
    ];
    let handles: Vec<_> = (0..20)
        .map(|i| {
            let (dist, name) = workloads[i % workloads.len()];
            let n = 20_000 + (i * 7919) % 60_000; // varied sizes
            let data = generate_i64(n, dist, i as u64, 2);
            let mut job = SortJob::new(data);
            job.dist = name.to_string();
            svc.submit(job)
        })
        .collect();
    for h in handles {
        let out = h.wait();
        assert!(out.valid);
        assert!(out.data.windows(2).all(|w| w[0] <= w[1]));
    }
    assert_eq!(svc.metrics().counter("jobs.completed"), 20);
    assert_eq!(svc.metrics().counter("jobs.invalid"), 0);
    let lat = svc.metrics().latency("sort.latency").unwrap();
    assert_eq!(lat.count(), 20);
    assert!(lat.mean() > 0.0);
}

#[test]
fn backpressure_queue_smaller_than_jobs() {
    // queue_capacity 1 with 1 worker: submissions block but all complete.
    let svc = SortService::new(ServiceConfig {
        workers: 1,
        sort_threads: 1,
        queue_capacity: 1,
        autotune: None,
    });
    let handles: Vec<_> = (0..8)
        .map(|i| svc.submit(SortJob::new(generate_i64(30_000, Distribution::Uniform, i, 1))))
        .collect();
    for h in handles {
        assert!(h.wait().valid);
    }
    assert_eq!(svc.metrics().counter("jobs.completed"), 8);
}

#[test]
fn tuning_cache_lifecycle_through_service() {
    let svc = SortService::new(ServiceConfig {
        workers: 1,
        sort_threads: 2,
        queue_capacity: 8,
        autotune: None,
    });

    // Cold: symbolic model used.
    let out = svc.submit(SortJob::new(generate_i64(400_000, Distribution::Uniform, 1, 2))).wait();
    assert!(out.valid);
    assert_eq!(svc.metrics().counter("params.symbolic"), 1);

    // Warm the cache under the data's fingerprint label (the declared dist
    // string is only a hint since the autotune PR), resubmit same class:
    // cache hit with cached params.
    let warm = generate_i64(450_000, Distribution::Uniform, 2, 2);
    let label = SortService::fingerprint_label(&warm);
    svc.cache().put(warm.len(), &label, SortParams::paper_1e8());
    let out = svc.submit(SortJob::new(warm)).wait();
    assert_eq!(out.params, SortParams::paper_1e8());
    assert_eq!(svc.metrics().counter("params.cache_hit"), 1);

    // Persist + reload the cache (deployment restart scenario). 420_000 sits
    // in the same half-decade band as 450_000, so the entry still resolves.
    let path = std::env::temp_dir().join(format!("evosort-svc-cache-{}.txt", std::process::id()));
    svc.cache().save(&path).unwrap();
    let reloaded = TuningCache::load(&path).unwrap();
    assert_eq!(reloaded.get(420_000, &label), Some(SortParams::paper_1e8()));
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn throughput_accounting() {
    let svc = SortService::new(ServiceConfig {
        workers: 2,
        sort_threads: 1,
        queue_capacity: 8,
        autotune: None,
    });
    let sizes = [10_000usize, 20_000, 30_000];
    for (i, &n) in sizes.iter().enumerate() {
        let _ = svc.submit(SortJob::new(generate_i64(n, Distribution::Uniform, i as u64, 1)));
    }
    svc.drain();
    assert_eq!(
        svc.metrics().counter("elements.sorted"),
        sizes.iter().sum::<usize>() as u64
    );
}
