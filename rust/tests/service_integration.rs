//! Sort-service integration: concurrency, backpressure, parameter
//! resolution, metrics accounting and cache persistence round-trips —
//! through the typed async API.

use std::time::Duration;

use evosort::coordinator::{ServiceConfig, SortRequest, SortService, Ticket, TuningCache};
use evosort::data::{generate_i64, Distribution};
use evosort::params::SortParams;

#[test]
fn service_sorts_mixed_workloads_concurrently() {
    let svc = SortService::new(ServiceConfig::sized(3, 2, 4));
    let workloads = [
        (Distribution::Uniform, "uniform"),
        (Distribution::Zipf, "zipf"),
        (Distribution::Reverse, "reverse"),
        (Distribution::FewUnique, "few-unique"),
    ];
    let tickets: Vec<Ticket> = (0..20)
        .map(|i| {
            let (dist, name) = workloads[i % workloads.len()];
            let n = 20_000 + (i * 7919) % 60_000; // varied sizes
            let data = generate_i64(n, dist, i as u64, 2);
            svc.submit_request(SortRequest::new(data).with_dist(name))
        })
        .collect();
    for t in tickets {
        let out = t.wait().expect("job completed");
        assert!(out.valid);
        let data = out.data::<i64>().unwrap();
        assert!(data.windows(2).all(|w| w[0] <= w[1]));
    }
    assert_eq!(svc.metrics().counter("jobs.completed"), 20);
    assert_eq!(svc.metrics().counter("jobs.invalid"), 0);
    let lat = svc.metrics().latency("sort.latency").unwrap();
    assert_eq!(lat.count(), 20);
    assert!(lat.mean() > 0.0);
}

#[test]
fn backpressure_queue_smaller_than_jobs() {
    // queue_capacity 1 with 1 worker: submissions block but all complete.
    let svc = SortService::new(ServiceConfig::sized(1, 1, 1));
    let tickets: Vec<Ticket> = (0..8)
        .map(|i| {
            let data = generate_i64(30_000, Distribution::Uniform, i, 1);
            svc.submit_request(SortRequest::new(data))
        })
        .collect();
    for t in tickets {
        assert!(t.wait().expect("job completed").valid);
    }
    assert_eq!(svc.metrics().counter("jobs.completed"), 8);
}

#[test]
fn ticket_wait_timeout_on_queued_job() {
    // A single busy worker: a queued job's ticket times out while pending,
    // then resolves normally — no polling, no hang, no panic.
    let svc = SortService::new(ServiceConfig::sized(1, 1, 8));
    let tickets: Vec<Ticket> = (0..4)
        .map(|i| {
            let data = generate_i64(600_000, Distribution::Uniform, i, 1);
            svc.submit_request(SortRequest::new(data))
        })
        .collect();
    let mut tickets = tickets;
    let last = tickets.pop().unwrap();
    // The last job sits behind three 600k sorts; a zero-ish timeout on a
    // pending job hands the ticket back.
    let last = match last.wait_timeout(Duration::from_micros(1)) {
        Ok(result) => {
            // Extremely fast machine: already done — still a valid outcome.
            assert!(result.expect("job completed").valid);
            None
        }
        Err(ticket) => Some(ticket),
    };
    if let Some(ticket) = last {
        let out = ticket.wait().expect("job completed");
        assert!(out.valid);
    }
    for t in tickets {
        assert!(t.wait().expect("job completed").valid);
    }
}

#[test]
fn tuning_cache_lifecycle_through_service() {
    let svc = SortService::new(ServiceConfig::sized(1, 2, 8));

    // Cold: symbolic model used.
    let data = generate_i64(400_000, Distribution::Uniform, 1, 2);
    let out = svc.submit_request(SortRequest::new(data)).wait().unwrap();
    assert!(out.valid);
    assert_eq!(svc.metrics().counter("params.symbolic"), 1);

    // Warm the cache under the data's fingerprint label (the declared dist
    // string is only a hint since the autotune PR), resubmit same class:
    // cache hit with cached params.
    let warm = generate_i64(450_000, Distribution::Uniform, 2, 2);
    let label = SortService::fingerprint_label(&warm);
    svc.cache().put(warm.len(), &label, SortParams::paper_1e8());
    let out = svc.submit_request(SortRequest::new(warm)).wait().unwrap();
    assert_eq!(out.params, SortParams::paper_1e8());
    assert_eq!(svc.metrics().counter("params.cache_hit"), 1);

    // Persist + reload the cache (deployment restart scenario). 420_000 sits
    // in the same half-decade band as 450_000, so the entry still resolves.
    let path = std::env::temp_dir().join(format!("evosort-svc-cache-{}.txt", std::process::id()));
    svc.cache().save(&path).unwrap();
    let reloaded = TuningCache::load(&path).unwrap();
    assert_eq!(reloaded.get(420_000, &label), Some(SortParams::paper_1e8()));
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn dtype_tagged_cache_entries_persist_and_restore() {
    // An f64 class round-trips the versioned text format with its dtype tag.
    let svc = SortService::new(ServiceConfig::sized(1, 2, 8));
    let floats: Vec<f64> =
        generate_i64(300_000, Distribution::Uniform, 3, 2).iter().map(|&x| x as f64).collect();
    let label = SortService::fingerprint_label_for(&floats);
    assert!(label.ends_with(":f64"), "{label}");
    svc.cache().put(floats.len(), &label, SortParams::paper_1e8());
    let out = svc.submit_request(SortRequest::new(floats)).wait().unwrap();
    assert_eq!(out.params, SortParams::paper_1e8());
    assert_eq!(svc.metrics().counter("params.cache_hit"), 1);

    let path = std::env::temp_dir().join(format!("evosort-f64-cache-{}.txt", std::process::id()));
    svc.cache().save(&path).unwrap();
    let reloaded = TuningCache::load(&path).unwrap();
    assert_eq!(reloaded.get(300_000, &label), Some(SortParams::paper_1e8()));
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn throughput_accounting() {
    let svc = SortService::new(ServiceConfig::sized(2, 1, 8));
    let sizes = [10_000usize, 20_000, 30_000];
    for (i, &n) in sizes.iter().enumerate() {
        let data = generate_i64(n, Distribution::Uniform, i as u64, 1);
        let _ = svc.submit_request(SortRequest::new(data));
    }
    svc.drain();
    assert_eq!(svc.metrics().counter("elements.sorted"), sizes.iter().sum::<usize>() as u64);
}
