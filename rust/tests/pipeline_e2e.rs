//! End-to-end master-pipeline tests (Algorithm 1): GA tuning → generation →
//! sort → validation → baseline comparison, plus the symbolic variant and
//! the Table-1 "shape" assertions at test scale.

use evosort::coordinator::{pipeline, ParamSource, PipelineConfig};
use evosort::data::Distribution;
use evosort::ga::GaConfig;
use evosort::params::ACode;
use evosort::sort::Baseline;
use evosort::symbolic::SymbolicModel;

#[test]
fn ga_pipeline_validates_and_records_history() {
    let config = PipelineConfig {
        sizes: vec![200_000, 600_000],
        dist: Distribution::Uniform,
        seed: 7,
        threads: 2,
        params: ParamSource::Ga(GaConfig {
            population: 6,
            generations: 3,
            seed: 7,
            ..Default::default()
        }),
        sample_cap: 200_000,
        baselines: vec![Baseline::Quicksort, Baseline::Mergesort],
    };
    let rows = pipeline::run(&config);
    assert_eq!(rows.len(), 2);
    for row in &rows {
        assert!(row.validated);
        let ga = row.ga.as_ref().unwrap();
        assert_eq!(ga.history.len(), 4);
        // Elitism: the best fitness never regresses across generations.
        for w in ga.history.windows(2) {
            assert!(w[1].best <= w[0].best + 1e-9);
        }
        assert_eq!(row.baselines.len(), 2);
    }
}

#[test]
fn symbolic_pipeline_speedup_shape() {
    // Table-1 shape at test scale: EvoSort (multi-pass linear radix) must
    // beat the sequential mergesort baseline on uniform integers, and the
    // speedup should not collapse as n grows.
    let config = PipelineConfig {
        sizes: vec![1_000_000, 4_000_000],
        dist: Distribution::Uniform,
        seed: 11,
        threads: 2,
        params: ParamSource::Symbolic(SymbolicModel::paper()),
        sample_cap: 0,
        baselines: vec![Baseline::Mergesort],
    };
    let rows = pipeline::run(&config);
    for row in &rows {
        assert!(row.validated);
        assert_eq!(row.params.algorithm, ACode::Radix, "§7 fixes A_code to radix");
        assert!(
            row.best_speedup() > 1.0,
            "EvoSort should beat the sequential mergesort baseline at n={} (got {:.2}x)",
            row.n,
            row.best_speedup()
        );
    }
    assert!(
        rows[1].best_speedup() >= rows[0].best_speedup() * 0.8,
        "speedup should not collapse with n: {:.2}x -> {:.2}x",
        rows[0].best_speedup(),
        rows[1].best_speedup()
    );
}

#[test]
fn pipeline_nonuniform_distributions_validate() {
    for dist in [Distribution::Zipf, Distribution::NearlySorted, Distribution::FewUnique] {
        let config = PipelineConfig {
            sizes: vec![300_000],
            dist,
            seed: 13,
            threads: 2,
            params: ParamSource::Fixed(evosort::params::SortParams::paper_1e7()),
            sample_cap: 0,
            baselines: vec![],
        };
        let rows = pipeline::run(&config);
        assert!(rows[0].validated, "{}", dist.name());
    }
}

#[test]
fn fixed_params_merge_path_validates() {
    let params = evosort::params::SortParams {
        algorithm: ACode::Merge,
        fallback_threshold: 1000,
        ..Default::default()
    };
    let config = PipelineConfig {
        sizes: vec![500_000],
        dist: Distribution::Gaussian,
        seed: 17,
        threads: 3,
        params: ParamSource::Fixed(params),
        sample_cap: 0,
        baselines: vec![Baseline::Std],
    };
    let rows = pipeline::run(&config);
    assert!(rows[0].validated);
    assert_eq!(rows[0].params.algorithm, ACode::Merge);
}
