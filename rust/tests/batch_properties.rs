//! Batched-submission integration tests: a 1k-job mixed batch must return
//! every job sorted, in submission order, bit-identical to the sequential
//! path — including empty-slice and single-element jobs — and the batched
//! path must not be slower than submitting the same jobs one at a time on
//! the same pool.

use evosort::coordinator::{BatchWorkload, ServiceConfig, SortJob, SortService};
use evosort::data::Distribution;
use evosort::testkit::{check, Arbitrary, PropConfig};
use evosort::util::timer;

fn service(workers: usize) -> SortService {
    SortService::new(ServiceConfig { workers, sort_threads: 2, queue_capacity: 32, autotune: None })
}

#[test]
fn thousand_job_mixed_batch_matches_sequential_path() {
    let workload = BatchWorkload {
        jobs: 1000,
        sizes: vec![0, 1, 17, 256, 1_000, 4_096, 9_999],
        dists: vec![
            Distribution::Uniform,
            Distribution::Zipf,
            Distribution::Reverse,
            Distribution::FewUnique,
            Distribution::NearlySorted,
        ],
        seed: 7,
        validate: true,
    };
    let jobs = workload.generate(2);
    // The sequential path: same inputs through the plain std-sort oracle.
    let oracle: Vec<Vec<i64>> = jobs
        .iter()
        .map(|j| {
            let mut v = j.data.clone();
            v.sort_unstable();
            v
        })
        .collect();

    let svc = service(3);
    let report = svc.submit_batch(jobs).wait();

    assert_eq!(report.outcomes.len(), 1000);
    assert_eq!(report.stats.jobs, 1000);
    assert_eq!(report.stats.invalid, 0, "every job must validate");
    for (i, (out, want)) in report.outcomes.iter().zip(&oracle).enumerate() {
        assert!(out.valid, "job {i} invalid");
        assert_eq!(&out.data, want, "job {i} must match the sequential oracle");
    }
    // Percentile stats are well-formed for a big batch.
    assert!(report.stats.p50_secs <= report.stats.p99_secs);
    assert!(report.stats.jobs_per_sec > 0.0);
    assert_eq!(svc.metrics().counter("jobs.completed"), 1000);
    assert_eq!(svc.metrics().counter("jobs.invalid"), 0);
}

/// A small batch of random vectors (lengths 0..=512 with duplicate-heavy and
/// extreme-value regimes from the testkit generator).
#[derive(Debug, Clone)]
struct ArbBatch(Vec<Vec<i64>>);

impl Arbitrary for ArbBatch {
    fn generate(rng: &mut evosort::rng::Xoshiro256pp) -> Self {
        let jobs = 1 + rng.below(8);
        ArbBatch((0..jobs).map(|_| Vec::<i64>::generate(rng)).collect())
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        let n = self.0.len();
        if n > 1 {
            out.push(ArbBatch(self.0[..n / 2].to_vec()));
            out.push(ArbBatch(self.0[n / 2..].to_vec()));
        }
        for (i, v) in self.0.iter().enumerate() {
            for sv in v.shrink() {
                let mut next = self.0.clone();
                next[i] = sv;
                out.push(ArbBatch(next));
            }
        }
        out
    }
}

#[test]
fn prop_random_batches_sort_correctly() {
    let svc = service(2);
    check::<ArbBatch>(PropConfig { cases: 60, seed: 11, ..Default::default() }, |batch| {
        let jobs: Vec<SortJob> = batch.0.iter().map(|v| SortJob::new(v.clone())).collect();
        let report = svc.submit_batch(jobs).wait();
        report.outcomes.len() == batch.0.len()
            && report.outcomes.iter().zip(&batch.0).all(|(out, input)| {
                let mut want = input.clone();
                want.sort_unstable();
                out.valid && out.data == want
            })
    })
    .unwrap_ok();
}

#[test]
fn batch_not_slower_than_one_at_a_time_loop() {
    // Same pool, same jobs: the batched path (parallel shards + scratch
    // reuse) must beat — or at minimum match — submitting one job and
    // waiting for it before submitting the next. The expectation is ~1/workers
    // of the sequential wall; the assertion leaves generous headroom for CI
    // noise.
    let jobs_n = 200;
    let make_jobs = || -> Vec<SortJob> {
        (0..jobs_n as u64)
            .map(|seed| {
                SortJob::new(evosort::data::generate_i64(
                    8_000,
                    Distribution::Uniform,
                    seed,
                    1,
                ))
            })
            .collect()
    };

    let svc = service(3);
    // Warm both paths once (thread spawn, allocator).
    svc.submit(SortJob::new(evosort::data::generate_i64(8_000, Distribution::Uniform, 999, 1)))
        .wait();

    let seq_jobs = make_jobs();
    let (_, seq_secs) = timer::time(|| {
        for job in seq_jobs {
            let out = svc.submit(job).wait();
            assert!(out.valid);
        }
    });

    let batch_jobs = make_jobs();
    let report = svc.submit_batch(batch_jobs).wait();
    assert_eq!(report.stats.invalid, 0);

    assert!(
        report.wall_secs <= seq_secs * 1.5,
        "batched path too slow: batch {:.4}s vs sequential {:.4}s",
        report.wall_secs,
        seq_secs
    );
}
