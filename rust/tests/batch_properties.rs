//! Batched-submission integration tests: a 1k-job mixed batch must return
//! every job sorted, in submission order, bit-identical to the sequential
//! path — including empty-slice and single-element jobs — and the batched
//! path must not be slower than submitting the same jobs one at a time on
//! the same pool.

use evosort::coordinator::{BatchWorkload, ServiceConfig, SortRequest, SortService};
use evosort::data::Distribution;
use evosort::testkit::{check, Arbitrary, PropConfig};
use evosort::util::timer;

fn service(workers: usize) -> SortService {
    SortService::new(ServiceConfig::sized(workers, 2, 32))
}

#[test]
fn thousand_job_mixed_batch_matches_sequential_path() {
    let workload = BatchWorkload {
        jobs: 1000,
        sizes: vec![0, 1, 17, 256, 1_000, 4_096, 9_999],
        dists: vec![
            Distribution::Uniform,
            Distribution::Zipf,
            Distribution::Reverse,
            Distribution::FewUnique,
            Distribution::NearlySorted,
        ],
        seed: 7,
        ..Default::default()
    };
    let requests = workload.generate(2);
    // The sequential path: same inputs through the plain std-sort oracle.
    let oracle: Vec<Vec<i64>> = requests
        .iter()
        .map(|r| {
            let mut v = r.payload().as_slice::<i64>().expect("i64 workload").to_vec();
            v.sort_unstable();
            v
        })
        .collect();

    let svc = service(3);
    let report = svc.submit_batch_requests(requests).wait();

    assert_eq!(report.outcomes.len(), 1000);
    assert_eq!(report.stats.jobs, 1000);
    assert_eq!(report.stats.invalid, 0, "every job must validate");
    assert_eq!(report.stats.failed, 0);
    for (i, want) in oracle.iter().enumerate() {
        let out = report.output(i);
        assert!(out.valid, "job {i} invalid");
        assert_eq!(out.data::<i64>().unwrap(), &want[..], "job {i} must match the oracle");
    }
    // Percentile stats are well-formed for a big batch.
    assert!(report.stats.p50_secs <= report.stats.p99_secs);
    assert!(report.stats.jobs_per_sec > 0.0);
    assert_eq!(svc.metrics().counter("jobs.completed"), 1000);
    assert_eq!(svc.metrics().counter("jobs.invalid"), 0);
}

/// A small batch of random vectors (lengths 0..=512 with duplicate-heavy and
/// extreme-value regimes from the testkit generator).
#[derive(Debug, Clone)]
struct ArbBatch(Vec<Vec<i64>>);

impl Arbitrary for ArbBatch {
    fn generate(rng: &mut evosort::rng::Xoshiro256pp) -> Self {
        let jobs = 1 + rng.below(8);
        ArbBatch((0..jobs).map(|_| Vec::<i64>::generate(rng)).collect())
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        let n = self.0.len();
        if n > 1 {
            out.push(ArbBatch(self.0[..n / 2].to_vec()));
            out.push(ArbBatch(self.0[n / 2..].to_vec()));
        }
        for (i, v) in self.0.iter().enumerate() {
            for sv in v.shrink() {
                let mut next = self.0.clone();
                next[i] = sv;
                out.push(ArbBatch(next));
            }
        }
        out
    }
}

#[test]
fn prop_random_batches_sort_correctly() {
    let svc = service(2);
    check::<ArbBatch>(PropConfig { cases: 60, seed: 11, ..Default::default() }, |batch| {
        let requests: Vec<SortRequest> =
            batch.0.iter().map(|v| SortRequest::new(v.clone())).collect();
        let report = svc.submit_batch_requests(requests).wait();
        report.outcomes.len() == batch.0.len()
            && report.outcomes.iter().zip(&batch.0).all(|(result, input)| {
                let mut want = input.clone();
                want.sort_unstable();
                match result {
                    Ok(out) => out.valid && out.data::<i64>() == Some(&want[..]),
                    Err(_) => false,
                }
            })
    })
    .unwrap_ok();
}

#[test]
fn batch_not_slower_than_one_at_a_time_loop() {
    // Same pool, same jobs: the batched path (parallel shards + scratch
    // reuse) must beat — or at minimum match — submitting one job and
    // waiting for it before submitting the next. The expectation is ~1/workers
    // of the sequential wall; the assertion leaves generous headroom for CI
    // noise.
    let jobs_n = 200;
    let make_requests = || -> Vec<SortRequest> {
        (0..jobs_n as u64)
            .map(|seed| {
                let data = evosort::data::generate_i64(8_000, Distribution::Uniform, seed, 1);
                SortRequest::new(data)
            })
            .collect()
    };

    let svc = service(3);
    // Warm both paths once (thread spawn, allocator).
    let warm = evosort::data::generate_i64(8_000, Distribution::Uniform, 999, 1);
    let _ = svc.submit_request(SortRequest::new(warm)).wait().expect("warmup job");

    let seq_requests = make_requests();
    let (_, seq_secs) = timer::time(|| {
        for req in seq_requests {
            let out = svc.submit_request(req).wait().expect("sequential job");
            assert!(out.valid);
        }
    });

    let batch_requests = make_requests();
    let report = svc.submit_batch_requests(batch_requests).wait();
    assert_eq!(report.stats.invalid, 0);
    assert_eq!(report.stats.failed, 0);

    assert!(
        report.wall_secs <= seq_secs * 1.5,
        "batched path too slow: batch {:.4}s vs sequential {:.4}s",
        report.wall_secs,
        seq_secs
    );
}
