//! Multi-node sharding over TCP: the same frame protocol and router
//! semantics as the Unix-socket deployment, but across `tcp://…` endpoints
//! — local shards on loopback TCP, plus standalone `--listen` workers the
//! router dials as remote fleet members. Also the hostile-peer suite: a
//! TCP listener is reachable by anything, so the receive side must error
//! out of truncated/oversized/garbage frames without hanging or
//! ballooning allocation.

#![cfg(unix)]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use evosort::coordinator::shard::transport::Listener;
use evosort::coordinator::shard::worker::{self, ExitReason, ShardWorkerConfig};
use evosort::coordinator::{
    Endpoint, ServiceConfig, ShardRouter, ShardSpec, SortRequest, TransportKind,
};
use evosort::data::{generate_i64, Distribution};
use evosort::sort::{Dtype, SortPayload};

fn tcp_spec(shards: usize, workers_per_shard: usize) -> ShardSpec {
    ShardSpec {
        shards,
        workers_per_shard,
        sort_threads: 2,
        transport: TransportKind::Tcp,
        binary: Some(PathBuf::from(env!("CARGO_BIN_EXE_evosort"))),
        ..ShardSpec::default()
    }
}

fn wait_until(limit: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + limit;
    while Instant::now() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    cond()
}

#[test]
fn tcp_sharded_batch_sorts_mixed_dtypes_across_processes() {
    // transport = Tcp with no listen base: each shard gets an OS-assigned
    // loopback port and the child dials it back.
    let router = ShardRouter::spawn(tcp_spec(2, 1)).expect("tcp router up");

    let pids = router.shard_pids();
    assert_eq!(pids.len(), 2);
    let (a, b) = (pids[0].expect("shard 0 live"), pids[1].expect("shard 1 live"));
    assert_ne!(a, b, "distinct worker processes");

    let dtypes = Dtype::all();
    let requests: Vec<SortRequest> = (0..16u64)
        .map(|i| {
            let n = 10_000 + (i as usize * 911) % 15_000;
            let data = generate_i64(n, Distribution::Uniform, i, 2);
            let payload = SortPayload::from_i64_values(data, dtypes[i as usize % dtypes.len()]);
            SortRequest::from_payload(payload)
        })
        .collect();
    let report = router.submit_batch_requests(requests).wait();
    assert_eq!(report.stats.jobs, 16);
    assert_eq!(report.stats.failed, 0, "no job may fail over TCP");
    assert_eq!(report.stats.invalid, 0, "every output validates");
    assert_eq!(report.stats.per_dtype.len(), 4, "all four dtypes served");
    for out in report.outputs() {
        if out.dtype() == Dtype::I64 {
            let v = out.data::<i64>().unwrap();
            assert!(v.windows(2).all(|w| w[0] <= w[1]));
        }
    }
    let metrics = router.metrics();
    assert!(metrics.counter("shard.0.jobs.completed") > 0, "shard 0 idle");
    assert!(metrics.counter("shard.1.jobs.completed") > 0, "shard 1 idle");
    assert_eq!(metrics.counter("jobs.completed"), 16);
}

#[test]
fn tcp_shard_killed_mid_batch_fails_over_and_redials() {
    let router = ShardRouter::spawn(tcp_spec(2, 1)).expect("tcp router up");
    let metrics = std::sync::Arc::clone(router.metrics());

    let mut observed_loss = false;
    for attempt in 0..3u64 {
        let requests: Vec<SortRequest> = (0..12u64)
            .map(|i| {
                let data = generate_i64(800_000, Distribution::Uniform, i ^ (attempt * 131), 2);
                SortRequest::new(data)
            })
            .collect();
        let stream = router.submit_batch_requests(requests).stream();
        assert!(
            wait_until(Duration::from_secs(30), || router.inflight(0) > 0),
            "shard 0 never received work"
        );
        assert!(router.kill_shard(0), "kill must reach a live child");
        let results: Vec<_> = stream.collect();
        assert_eq!(results.len(), 12, "the stream always yields every slot — no hangs");
        let lost = results.iter().filter(|r| r.is_err()).count();
        assert!(results.len() - lost >= 1, "the survivor finishes the batch");
        assert!(lost <= 3, "only the in-flight window may be lost, got {lost}");
        if lost >= 1 {
            observed_loss = true;
            break;
        }
    }
    assert!(observed_loss, "killing a busy shard must surface Err(WorkerLost)");

    // The unified recovery counter ticks for the TCP respawn (the local-
    // origin legacy counter does too), and the revived fleet serves a full
    // batch.
    assert!(
        wait_until(Duration::from_secs(30), || metrics.counter("shards.redials") >= 1),
        "the killed shard must be redialed"
    );
    assert!(metrics.counter("shard.respawns") >= 1, "local shards also count as respawns");
    let requests: Vec<SortRequest> = (0..8u64)
        .map(|i| SortRequest::new(generate_i64(20_000, Distribution::Uniform, 900 + i, 2)))
        .collect();
    let report = router.submit_batch_requests(requests).wait();
    assert_eq!(report.stats.failed, 0, "post-redial batch completes fully");
}

/// Spawn a standalone listening worker process and return it with the
/// endpoint it announced on stdout.
fn spawn_listening_worker() -> (Child, Endpoint) {
    let mut child = Command::new(env!("CARGO_BIN_EXE_evosort"))
        .args([
            "shard-worker",
            "--listen",
            "tcp://127.0.0.1:0",
            "--workers",
            "1",
            "--sort-threads",
            "1",
        ])
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn listening shard-worker");
    let stdout = child.stdout.take().expect("piped stdout");
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).expect("read announce line");
    let announced = line
        .trim()
        .strip_prefix("shard-worker listening on ")
        .unwrap_or_else(|| panic!("unexpected announce line {line:?}"))
        .to_string();
    let endpoint: Endpoint = announced.parse().expect("announced endpoint parses");
    (child, endpoint)
}

#[test]
fn remote_listening_worker_serves_routers_and_relistens() {
    // The multi-node topology in miniature: the "remote host" worker
    // listens, the router dials it as a remote fleet slot (zero local
    // shards), and after the router goes away the worker re-listens for
    // the next one.
    let (mut child, endpoint) = spawn_listening_worker();
    let run = |label: &str| {
        let spec = ShardSpec {
            shards: 0,
            remotes: vec![endpoint.clone()],
            ..tcp_spec(0, 1)
        };
        let router = ShardRouter::spawn(spec).expect(label);
        assert_eq!(router.shards(), 1, "one remote fleet slot");
        assert_eq!(router.shard_pids(), vec![None], "remote pids belong to the other host");
        let requests: Vec<SortRequest> = (0..6u64)
            .map(|i| SortRequest::new(generate_i64(30_000, Distribution::Zipf, i, 2)))
            .collect();
        let report = router.submit_batch_requests(requests).wait();
        assert_eq!(report.stats.failed, 0, "{label}: remote worker serves the batch");
        assert_eq!(report.stats.invalid, 0);
        assert!(router.metrics().counter("shard.0.jobs.completed") >= 6);
        // Drop detaches the remote worker (socket shutdown, no Shutdown
        // frame) — it must go back to listening.
    };
    run("first router");
    run("second router against the re-listening worker");
    assert!(
        child.try_wait().expect("poll worker").is_none(),
        "a detached standalone worker keeps running"
    );
    child.kill().expect("stop the worker");
    let _ = child.wait();
}

/// Every hostile byte sequence must make the worker's receive loop return
/// `Disconnected` promptly — no hang, no giant allocation, and the
/// listener must survive to serve the next (well-formed) connection.
#[test]
fn hostile_tcp_frames_error_without_hanging_the_worker() {
    let listener = Listener::bind(&Endpoint::tcp("127.0.0.1", 0)).expect("bind");
    let endpoint = listener.local_endpoint().expect("resolved endpoint");
    let Endpoint::Tcp { host, port } = &endpoint else { panic!("tcp endpoint") };
    let addr = (host.as_str(), *port);

    let config = ShardWorkerConfig {
        shard_id: 0,
        service: ServiceConfig::sized(1, 1, 8),
        publish_interval: Duration::from_secs(60), // quiet ticker
    };

    // [tag][len: u64 LE][payload] — three ways to lie about it.
    let oversized = {
        let mut f = vec![1u8]; // TAG_JOB
        f.extend_from_slice(&u64::MAX.to_le_bytes()); // absurd length
        f
    };
    let truncated = {
        let mut f = vec![5u8]; // TAG_TELEMETRY
        f.extend_from_slice(&4096u64.to_le_bytes()); // claims 4 KiB…
        f.extend_from_slice(b"tiny"); // …delivers 4 bytes, then closes
        f
    };
    let garbage = b"GET / HTTP/1.1\r\n\r\n".to_vec(); // wrong protocol entirely

    for (name, payload) in
        [("oversized", oversized), ("truncated", truncated), ("garbage", garbage)]
    {
        let worker = {
            let stream = listener.accept_after(|| {
                let mut attacker = TcpStream::connect(addr).expect("attacker connects");
                attacker.write_all(&payload).expect("send hostile bytes");
                attacker
            });
            let config = config.clone();
            std::thread::spawn(move || worker::run_on_stream(stream, config))
        };
        let deadline = Instant::now() + Duration::from_secs(10);
        while !worker.is_finished() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(worker.is_finished(), "{name}: hostile frame hung the worker");
        let reason = worker.join().expect("no panic").expect("clean exit");
        assert_eq!(reason, ExitReason::Disconnected, "{name}");
    }

    // The transport seam is intact: a well-formed TCP session still works
    // (ShardRouter directly — the ShardedService front door would route a
    // single local shard in-process).
    drop(listener);
    let router = ShardRouter::spawn(tcp_spec(1, 1)).expect("router with one tcp shard");
    let out = router
        .submit_request(SortRequest::new(generate_i64(10_000, Distribution::Uniform, 7, 2)))
        .wait()
        .expect("clean job sorts");
    assert!(out.valid);
}

/// Test-only helper: accept while a client thread connects (both sides of
/// the handshake live in this test).
trait AcceptAfter {
    fn accept_after(
        &self,
        connect: impl FnOnce() -> TcpStream + Send + 'static,
    ) -> evosort::coordinator::shard::transport::Stream;
}

impl AcceptAfter for Listener {
    fn accept_after(
        &self,
        connect: impl FnOnce() -> TcpStream + Send + 'static,
    ) -> evosort::coordinator::shard::transport::Stream {
        let client = std::thread::spawn(connect);
        let stream = self.accept().expect("accept");
        // Hold the attacker socket open until its bytes are sent; the
        // thread drops (closes) it after write_all returns.
        let _attacker = client.join().expect("client thread");
        stream
    }
}
