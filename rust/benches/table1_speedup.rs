//! Regenerates **Table 1** of the paper: GA-tuned EvoSort vs the sequential
//! quicksort/mergesort baselines across the paper's dataset sizes (scaled to
//! this testbed by `EVOSORT_BENCH_SCALE_DIV`, default 100).
//!
//! Expected *shape* (paper): EvoSort wins every row; the speedup factor grows
//! with n; the GA selects LSD radix sort (A_code = 4) for all large sizes.

use evosort::bench_harness::{banner, tables};
use evosort::util::default_threads;

fn main() {
    banner(
        "table1_speedup",
        "Table 1: EvoSort (GA-tuned) vs NumPy-analog baselines, sizes scaled from the paper",
    );
    tables::print_table1(default_threads());
}
