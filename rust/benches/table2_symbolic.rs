//! Regenerates **Table 2** (and the data of **Figure 12**): EvoSort with
//! *symbolic* parameters (§7.5 — zero tuning overhead) vs the sequential
//! quicksort baseline, at the paper's Table-2 sizes scaled to this testbed.
//!
//! Expected shape: speedups comparable to the GA-tuned Table 1 rows without
//! any GA run, and growing with n.

use evosort::bench_harness::{banner, tables};
use evosort::util::default_threads;

fn main() {
    banner(
        "table2_symbolic",
        "Table 2 / Figure 12: symbolic-parameter EvoSort vs baseline (zero tuning overhead)",
    );
    tables::print_table2(default_threads());
}
