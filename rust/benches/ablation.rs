//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!  A. GA-tuned vs default vs symbolic parameters (how much does tuning buy?)
//!  B. Radix vs mergesort crossover across sizes (the A_code decision).
//!  C. Tile-size sensitivity of the blocked merge (the T_tile gene).
//!  D. Distribution robustness (uniform / zipf / nearly-sorted / few-unique).
//!  E. Radix pass-skipping optimisation (narrow-range inputs).

use evosort::bench_harness::{banner, measure, BenchConfig, Table};
use evosort::data::{generate_i64, Distribution};
use evosort::ga::{GaConfig, GaDriver};
use evosort::params::{ACode, SortParams};
use evosort::sort::{AdaptiveSorter, MergeTuning};
use evosort::symbolic::SymbolicModel;
use evosort::util::{default_threads, fmt_count};

fn main() {
    banner("ablation", "design-choice ablations A-E (see bench source for the list)");
    let threads = default_threads();
    let cfg = BenchConfig::from_env();
    let sorter = AdaptiveSorter::new(threads);

    // --- A: parameter-source ablation. -------------------------------------
    println!("--- A: GA-tuned vs symbolic vs default parameters (n=4e6 uniform) ---");
    let n = 4_000_000;
    let base = generate_i64(n, Distribution::Uniform, 1, threads);
    let ga_params = GaDriver::new(GaConfig { population: 8, generations: 4, seed: 3, ..Default::default() })
        .run_for_size(n, 1_000_000, Distribution::Uniform, AdaptiveSorter::new(threads))
        .best;
    let cases = [
        ("default", SortParams::default()),
        ("symbolic", SymbolicModel::paper().params_for(n)),
        ("ga-tuned", ga_params),
    ];
    let mut t = Table::new(&["params", "median(s)", "config"]);
    for (name, p) in cases {
        let m = measure(&cfg, name, || base.clone(), |mut d| sorter.sort_i64(&mut d, &p));
        t.row(&[name.into(), format!("{:.4}", m.median()), p.to_string()]);
    }
    t.print();

    // --- B: strategy crossover (radix vs merge vs samplesort). --------------
    println!("--- B: radix vs merge vs samplesort across sizes (uniform i64) ---");
    let mut t = Table::new(&["n", "radix(s)", "merge(s)", "sample(s)", "winner"]);
    for n in [50_000usize, 200_000, 1_000_000, 4_000_000, 16_000_000] {
        let data = generate_i64(n, Distribution::Uniform, 2, threads);
        let radix = SortParams { algorithm: ACode::Radix, fallback_threshold: 256, ..SortParams::default() };
        let merge = SortParams { algorithm: ACode::Merge, fallback_threshold: 256, ..SortParams::default() };
        let sample = SortParams { algorithm: ACode::Sample, fallback_threshold: 256, ..SortParams::default() };
        let mr = measure(&cfg, "radix", || data.clone(), |mut d| sorter.sort_i64(&mut d, &radix));
        let mm = measure(&cfg, "merge", || data.clone(), |mut d| sorter.sort_i64(&mut d, &merge));
        let ms = measure(&cfg, "sample", || data.clone(), |mut d| sorter.sort_i64(&mut d, &sample));
        let winner = if mr.median() < mm.median() && mr.median() < ms.median() {
            "radix"
        } else if mm.median() < ms.median() {
            "merge"
        } else {
            "samplesort"
        };
        t.row(&[
            fmt_count(n),
            format!("{:.4}", mr.median()),
            format!("{:.4}", mm.median()),
            format!("{:.4}", ms.median()),
            winner.into(),
        ]);
    }
    t.print();

    // --- C: tile-size sensitivity. ------------------------------------------
    println!("--- C: T_tile sensitivity of the blocked merge (n=4e6) ---");
    let data = generate_i64(4_000_000, Distribution::Uniform, 4, threads);
    let mut t = Table::new(&["tile", "median(s)"]);
    for tile in [64usize, 256, 1024, 4096, 16384, 65536] {
        let tuning = MergeTuning { tile, threads, ..MergeTuning::default() };
        let m = measure(&cfg, "tile", || data.clone(), |mut d| {
            evosort::sort::parallel_merge_sort(&mut d, &tuning)
        });
        t.row(&[tile.to_string(), format!("{:.4}", m.median())]);
    }
    t.print();

    // --- D: distribution robustness. ----------------------------------------
    println!("--- D: symbolic params across distributions (n=2e6) ---");
    let p = SymbolicModel::paper().params_for(2_000_000);
    let mut t = Table::new(&["distribution", "median(s)"]);
    for dist in [
        Distribution::Uniform,
        Distribution::Zipf,
        Distribution::Gaussian,
        Distribution::NearlySorted,
        Distribution::FewUnique,
        Distribution::Reverse,
    ] {
        let data = generate_i64(2_000_000, dist, 5, threads);
        let m = measure(&cfg, dist.name(), || data.clone(), |mut d| sorter.sort_i64(&mut d, &p));
        t.row(&[dist.name().into(), format!("{:.4}", m.median())]);
    }
    t.print();

    // --- E: pass-skipping on narrow ranges. ----------------------------------
    println!("--- E: radix pass-skipping (full-range vs byte-range values, n=4e6) ---");
    let radix = SortParams { algorithm: ACode::Radix, fallback_threshold: 256, ..SortParams::default() };
    let full = generate_i64(4_000_000, Distribution::Uniform, 6, threads);
    let narrow = generate_i64(4_000_000, Distribution::UniformRange(0, 255), 6, threads);
    let mf = measure(&cfg, "full", || full.clone(), |mut d| sorter.sort_i64(&mut d, &radix));
    let mn = measure(&cfg, "narrow", || narrow.clone(), |mut d| sorter.sort_i64(&mut d, &radix));
    let mut t = Table::new(&["input", "median(s)", "passes"]);
    t.row(&["full range".into(), format!("{:.4}", mf.median()), "8 of 8".into()]);
    t.row(&["narrow (1 byte)".into(), format!("{:.4}", mn.median()), "1 of 8 (7 skipped)".into()]);
    t.print();
    println!(
        "pass-skip speedup on narrow data: {:.2}x",
        mf.median() / mn.median().max(1e-9)
    );
}
