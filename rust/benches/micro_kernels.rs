//! Micro-benchmarks of the hot-path kernels — the §Perf profiling harness:
//!
//!  * merge kernels (plain / galloping / tiled) on interleaved + disjoint runs
//!  * radix phases (histogram sweep vs scatter) and end-to-end throughput
//!  * insertion-sort cutoff behaviour
//!  * threadpool / scoped-spawn overhead (exec substrate)
//!  * XLA tile backend throughput (when artifacts are present)
//!
//! Bandwidth roofline context: an 8-pass i64 radix moves ≥ passes × 16 B per
//! element (read+write); the printed GB/s column shows how close we get.

use evosort::bench_harness::{banner, measure, BenchConfig, Table};
use evosort::data::{generate_i64, Distribution};
use evosort::sort::merge::{merge_gallop_into, merge_into, merge_tiled_into};
use evosort::sort::radix_sort;
use evosort::util::{default_threads, fmt_count};

fn main() {
    banner("micro_kernels", "hot-path kernel microbenches (the §Perf harness)");
    let threads = default_threads();
    let cfg = BenchConfig::from_env();

    // --- Merge kernels. ------------------------------------------------------
    println!("--- merge kernels (1e6 + 1e6 elements) ---");
    let mut a = generate_i64(1_000_000, Distribution::Uniform, 1, threads);
    let mut b = generate_i64(1_000_000, Distribution::Uniform, 2, threads);
    a.sort_unstable();
    b.sort_unstable();
    // Disjoint runs: galloping's best case.
    let mut c: Vec<i64> = a.iter().map(|x| x - 3_000_000_000).collect();
    c.sort_unstable();
    let n_out = a.len() + b.len();
    let mut t = Table::new(&["kernel", "interleaved(s)", "disjoint(s)", "Melem/s (interleaved)"]);
    type MergeFn = fn(&[i64], &[i64], &mut [i64]);
    let kernels: [(&str, MergeFn); 3] = [
        ("merge_into", merge_into::<i64>),
        ("merge_gallop", merge_gallop_into::<i64>),
        ("merge_tiled(4096)", |x, y, d| merge_tiled_into(x, y, d, 4096)),
    ];
    for (name, f) in kernels {
        let mi = measure(&cfg, name, || vec![0i64; n_out], |mut d| f(&a, &b, &mut d));
        let md = measure(&cfg, name, || vec![0i64; n_out], |mut d| f(&c, &b, &mut d));
        t.row(&[
            name.into(),
            format!("{:.4}", mi.median()),
            format!("{:.4}", md.median()),
            format!("{:.1}", n_out as f64 / mi.median() / 1e6),
        ]);
    }
    t.print();

    // --- Radix end-to-end throughput + roofline. ------------------------------
    println!("--- LSD radix sort throughput (uniform i64) ---");
    let mut t = Table::new(&["n", "median(s)", "Melem/s", "GB/s moved", "roofline note"]);
    for n in [1_000_000usize, 4_000_000, 16_000_000] {
        let data = generate_i64(n, Distribution::Uniform, 3, threads);
        let m = measure(&cfg, "radix", || data.clone(), |mut d| radix_sort(&mut d, threads));
        // 8 passes × (read + write) × 8 B + histogram read sweep.
        let bytes = n as f64 * 8.0 * (8.0 * 2.0 + 1.0);
        t.row(&[
            fmt_count(n),
            format!("{:.4}", m.median()),
            format!("{:.1}", n as f64 / m.median() / 1e6),
            format!("{:.2}", bytes / m.median() / 1e9),
            "≥136 B/elem moved".into(),
        ]);
    }
    t.print();

    // --- Exec substrate overhead. ---------------------------------------------
    println!("--- exec substrate: scoped parallel_for dispatch overhead ---");
    let mut t = Table::new(&["threads", "spawn+join median (us)"]);
    for nt in [1usize, 2, 4, 8] {
        let m = measure(&cfg, "spawn", || vec![0u8; nt * 16], |mut d| {
            evosort::exec::parallel_for_chunks(&mut d, nt, |_, c| {
                for x in c.iter_mut() {
                    *x = 1;
                }
            })
        });
        t.row(&[nt.to_string(), format!("{:.1}", m.median() * 1e6)]);
    }
    t.print();

    // --- XLA tile backend (optional). -------------------------------------------
    println!("--- XLA tile-sort backend (PJRT, Pallas bitonic artifact) ---");
    match evosort::runtime::XlaTileSorter::from_default_artifacts() {
        Ok(backend) => {
            use evosort::sort::TileSorter;
            let tile = backend.tile_size();
            let batch = backend.batch();
            let n = tile * batch;
            let data: Vec<i32> = generate_i64(n, Distribution::Uniform, 4, threads)
                .iter()
                .map(|&x| x as i32)
                .collect();
            let m = measure(&cfg, "xla", || data.clone(), |mut d| {
                backend.sort_tiles_i32(&mut d).unwrap()
            });
            println!(
                "one executable call ({} tiles x {}): {:.4}s  ({:.2} Melem/s)",
                batch,
                tile,
                m.median(),
                n as f64 / m.median() / 1e6
            );
            println!("(interpret-mode Pallas on CPU: expect low absolute throughput; the");
            println!(" artifact demonstrates composition, real-TPU estimates in DESIGN.md §Perf)");
        }
        Err(e) => println!("skipped (no artifacts: {e})"),
    }
}
