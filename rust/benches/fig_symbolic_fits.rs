//! Regenerates **Figures 7–11**: GA-tuned thresholds versus dataset size n,
//! with degree-2 symbolic fits in x = log10 n — plus the §7.3 residual
//! analysis and the §7.4 analytical properties (curvature / vertex) table.
//!
//! Runs a GA sweep across sizes, fits quadratics to each threshold, prints
//! (n, GA value, fitted value, residual) series per parameter, and compares
//! vertex locations with the paper's.

use evosort::bench_harness::{banner, Table};
use evosort::data::Distribution;
use evosort::ga::{GaConfig, GaDriver};
use evosort::params::SortParams;
use evosort::sort::AdaptiveSorter;
use evosort::symbolic::SymbolicModel;
use evosort::util::{default_threads, fmt_count};

fn main() {
    banner(
        "fig_symbolic_fits",
        "Figures 7-11: GA-tuned thresholds vs n, quadratic symbolic fits, residuals",
    );
    let threads = default_threads();
    let sizes: Vec<usize> = match std::env::var("EVOSORT_BENCH_SIZES") {
        Ok(s) => s
            .split(',')
            .map(|t| evosort::cli::parse_count(t.trim()).expect("EVOSORT_BENCH_SIZES"))
            .collect(),
        Err(_) => vec![100_000, 300_000, 1_000_000, 3_000_000, 10_000_000, 30_000_000],
    };

    // --- GA sweep (training data). -----------------------------------------
    let mut sweep: Vec<(usize, SortParams)> = Vec::new();
    for &n in &sizes {
        let cfg = GaConfig { population: 8, generations: 5, seed: 4242 ^ n as u64, ..Default::default() };
        let r = GaDriver::new(cfg).run_for_size(
            n,
            2_000_000,
            Distribution::Uniform,
            AdaptiveSorter::new(threads),
        );
        println!("GA @ n={:<8} -> {}", fmt_count(n), r.best);
        sweep.push((n, r.best));
    }

    let model = SymbolicModel::fit(&sweep).expect("fit quadratics");

    // --- Per-parameter series (the scatter + line of each figure). ---------
    for (fig, name, q, get) in [
        (11, "insertion threshold", model.insertion, 0usize),
        (10, "parallel-merge threshold", model.parallel_merge, 1),
        (9, "fallback (numpy) threshold", model.fallback, 3),
        (8, "tile size", model.tile, 4),
    ] {
        println!("--- Figure {fig}: {name} ---");
        let mut t = Table::new(&["n", "GA value", "fit value", "residual"]);
        for (n, p) in &sweep {
            let ga_v = p.to_genes()[get] as f64;
            let fit_v = q.eval_n(*n);
            t.row(&[
                fmt_count(*n),
                format!("{ga_v:.0}"),
                format!("{fit_v:.0}"),
                format!("{:+.0}", ga_v - fit_v),
            ]);
        }
        t.print();
        let pts: Vec<(usize, f64)> =
            sweep.iter().map(|(n, p)| (*n, p.to_genes()[get] as f64)).collect();
        println!(
            "fit: a={:+.2} (={}), vertex x*={:.2} (n*≈{:.1e}), R²={:.3}\n",
            q.a,
            if q.is_convex() { "convex/min" } else { "concave/max" },
            q.vertex_x(),
            q.vertex_n(),
            q.r_squared(&pts)
        );
    }

    // --- §7.4 comparison with the paper's analytical properties. ----------
    println!("--- §7.4 vertex comparison (paper model vs our fit) ---");
    let paper = SymbolicModel::paper();
    let mut t = Table::new(&["threshold", "paper x*", "our x*", "paper shape", "our shape"]);
    for (name, p, f) in [
        ("T_insertion", paper.insertion, model.insertion),
        ("T_par_merge", paper.parallel_merge, model.parallel_merge),
        ("T_fallback", paper.fallback, model.fallback),
        ("T_tile", paper.tile, model.tile),
    ] {
        let shape = |q: &evosort::symbolic::Quadratic| {
            if q.is_convex() { "convex" } else { "concave" }
        };
        t.row(&[
            name.into(),
            format!("{:.2}", p.vertex_x()),
            format!("{:.2}", f.vertex_x()),
            shape(&p).into(),
            shape(&f).into(),
        ]);
    }
    t.print();
    println!("(note: our sweep covers smaller n than the paper's 1e7-1e10, so vertices shift)");
}
