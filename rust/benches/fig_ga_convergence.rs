//! Regenerates **Figures 2–6**: GA convergence (best / worst / average
//! execution time per generation) plus the final EvoSort-vs-baselines bars,
//! for each paper size (scaled). One figure block per size.
//!
//! Expected shape (paper §6): a wide generation-0 spread that collapses
//! within 2–3 generations; the best value then stays flat (elitism); final
//! EvoSort beats both baselines.
//!
//! Flags via env: EVOSORT_BENCH_SIZES=1e5,1e6 overrides the size list.

use evosort::bench_harness::{banner, scaled_size, Table};
use evosort::coordinator::{pipeline, ParamSource, PipelineConfig};
use evosort::data::Distribution;
use evosort::ga::GaConfig;
use evosort::sort::Baseline;
use evosort::util::{default_threads, fmt_count, fmt_secs};

fn main() {
    banner(
        "fig_ga_convergence",
        "Figures 2-6: GA best/worst/avg per generation + final performance bars",
    );
    let threads = default_threads();
    // Paper figures cover 1e7, 1e8, 5e8, 1e9, 1e10 — scaled here.
    let sizes: Vec<usize> = match std::env::var("EVOSORT_BENCH_SIZES") {
        Ok(s) => s
            .split(',')
            .map(|t| evosort::cli::parse_count(t.trim()).expect("EVOSORT_BENCH_SIZES"))
            .collect(),
        Err(_) => [
            10_000_000usize,
            100_000_000,
            500_000_000,
            1_000_000_000,
            10_000_000_000,
        ]
        .iter()
        .map(|&n| scaled_size(n))
        .collect(),
    };
    let mut dedup = sizes.clone();
    dedup.dedup();

    for n in dedup {
        println!("--- figure: GA convergence at n={} ---", fmt_count(n));
        let config = PipelineConfig {
            sizes: vec![n],
            dist: Distribution::Uniform,
            seed: 42,
            threads,
            params: ParamSource::Ga(GaConfig {
                population: 10,
                generations: 8,
                seed: 42 ^ n as u64,
                ..GaConfig::default()
            }),
            sample_cap: 2_000_000,
            baselines: vec![Baseline::Quicksort, Baseline::Mergesort],
        };
        let rows = pipeline::run(&config);
        let row = &rows[0];
        let ga = row.ga.as_ref().expect("ga history");

        let mut t = Table::new(&["gen", "best(s)", "avg(s)", "worst(s)"]);
        for h in &ga.history {
            t.row(&[
                h.generation.to_string(),
                format!("{:.4}", h.best),
                format!("{:.4}", h.average),
                format!("{:.4}", h.worst),
            ]);
        }
        t.print();
        println!("best individual: {}", row.params);
        println!("final bars (right panel):");
        println!("  EvoSort          {}", fmt_secs(row.evosort_secs));
        for (b, secs, speedup) in &row.baselines {
            println!("  {:<16} {} ({speedup:.1}x)", b.name(), fmt_secs(*secs));
        }
        println!("validated: {}\n", row.validated);
    }
}
