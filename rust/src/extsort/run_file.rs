//! Schema-versioned on-disk run format for the out-of-core sorter.
//!
//! A *run* is one sorted chunk of a larger job, spilled to its own file under
//! the job's [`SpillGuard`] directory. The format is deliberately tiny and
//! self-describing so a reader can reject damage before allocating anything:
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"EVSR"
//! 4       2     format version (little-endian u16, currently 1)
//! 6       1     dtype code (0=i64, 1=i32, 2=u64, 3=f64)
//! 7       1     reserved (must be 0)
//! 8       8     element count (little-endian u64)
//! 16      n*W   payload: count fixed-width little-endian elements
//! ```
//!
//! Mirroring the hostile-frame rules of the TCP transport, [`RunReader::open`]
//! validates the header against the *actual file length* before reading any
//! payload: a truncated, garbage, or absurdly-sized header fails with a typed
//! [`RunLoadError`] — it can never hang on a short file or over-allocate from
//! an attacker-controlled count (reader buffers are sized by the caller's
//! block budget, not by the header).

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use super::ExtKey;

/// File magic: "EVosort Sorted Run".
pub const RUN_MAGIC: [u8; 4] = *b"EVSR";

/// Bumped whenever the header or payload layout changes.
pub const RUN_FORMAT_VERSION: u16 = 1;

/// Header size in bytes (fixed).
pub const RUN_HEADER_BYTES: usize = 16;

/// Sanity ceiling on the element count a header may claim (2^40 ≈ 1.1e12
/// elements — far beyond any single spilled run). Anything larger is treated
/// as a corrupt header rather than a real run.
pub const MAX_RUN_ELEMS: u64 = 1 << 40;

/// Typed failure modes for loading a spilled run. Corrupt files are rejected
/// eagerly at `open`; they never produce garbage elements downstream.
#[derive(Debug)]
pub enum RunLoadError {
    /// The first four bytes are not [`RUN_MAGIC`].
    BadMagic { found: [u8; 4] },
    /// Unknown format version.
    BadVersion { found: u16 },
    /// The header's dtype code does not match the reader's key type.
    BadDtype { expected: u8, found: u8 },
    /// The file is shorter than the header + payload the header promises.
    Truncated { expected_bytes: u64, actual_bytes: u64 },
    /// The header claims a count past [`MAX_RUN_ELEMS`] (or one whose byte
    /// size overflows) — rejected before any allocation.
    Oversized { count: u64 },
    /// Underlying filesystem error.
    Io(std::io::Error),
}

impl std::fmt::Display for RunLoadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RunLoadError::BadMagic { found } => {
                write!(f, "run file: bad magic {found:?} (want {RUN_MAGIC:?})")
            }
            RunLoadError::BadVersion { found } => {
                write!(
                    f,
                    "run file: unsupported format version {found} (want {RUN_FORMAT_VERSION})"
                )
            }
            RunLoadError::BadDtype { expected, found } => {
                write!(f, "run file: dtype code {found} (reader expects {expected})")
            }
            RunLoadError::Truncated {
                expected_bytes,
                actual_bytes,
            } => {
                write!(
                    f,
                    "run file: truncated ({actual_bytes} bytes on disk, header promises {expected_bytes})"
                )
            }
            RunLoadError::Oversized { count } => {
                write!(
                    f,
                    "run file: header claims {count} elements (cap {MAX_RUN_ELEMS})"
                )
            }
            RunLoadError::Io(e) => write!(f, "run file: io error: {e}"),
        }
    }
}

impl std::error::Error for RunLoadError {}

impl From<std::io::Error> for RunLoadError {
    fn from(e: std::io::Error) -> Self {
        RunLoadError::Io(e)
    }
}

/// Serialization byte-buffer size for readers and writers: one fixed
/// 256 KiB staging area per stream, independent of the header's claims.
pub(crate) const IO_BUF_BYTES: usize = 256 * 1024;

/// Streaming run writer. The element count is part of the header, so the
/// caller declares it up front and [`RunWriter::finish`] verifies every
/// element was actually written — a crash mid-write leaves a file whose
/// length disagrees with its header, which `open` then rejects as truncated.
pub struct RunWriter<K: ExtKey> {
    out: BufWriter<File>,
    declared: u64,
    written: u64,
    buf: Vec<u8>,
    _key: std::marker::PhantomData<K>,
}

impl<K: ExtKey> RunWriter<K> {
    /// Create `path` and write the header for exactly `count` elements.
    pub fn create(path: &Path, count: u64) -> Result<Self, RunLoadError> {
        let file = File::create(path)?;
        let mut out = BufWriter::new(file);
        let mut header = [0u8; RUN_HEADER_BYTES];
        header[0..4].copy_from_slice(&RUN_MAGIC);
        header[4..6].copy_from_slice(&RUN_FORMAT_VERSION.to_le_bytes());
        header[6] = K::DTYPE_CODE;
        header[7] = 0;
        header[8..16].copy_from_slice(&count.to_le_bytes());
        out.write_all(&header)?;
        Ok(RunWriter {
            out,
            declared: count,
            written: 0,
            buf: Vec::with_capacity(IO_BUF_BYTES),
            _key: std::marker::PhantomData,
        })
    }

    /// Append a sorted slice (the writer does not re-check ordering).
    pub fn push_slice(&mut self, elems: &[K]) -> Result<(), RunLoadError> {
        for &e in elems {
            e.write_le(&mut self.buf);
            if self.buf.len() + K::WIDTH > IO_BUF_BYTES {
                self.out.write_all(&self.buf)?;
                self.buf.clear();
            }
        }
        self.written += elems.len() as u64;
        Ok(())
    }

    /// Flush and close, verifying the declared count was honoured.
    pub fn finish(mut self) -> Result<(), RunLoadError> {
        debug_assert_eq!(self.written, self.declared, "run writer element count");
        if !self.buf.is_empty() {
            self.out.write_all(&self.buf)?;
            self.buf.clear();
        }
        self.out.flush()?;
        Ok(())
    }
}

/// Write a whole sorted slice as one run file.
pub fn write_run<K: ExtKey>(path: &Path, data: &[K]) -> Result<(), RunLoadError> {
    let mut w = RunWriter::<K>::create(path, data.len() as u64)?;
    w.push_slice(data)?;
    w.finish()
}

/// Double-buffered streaming reader over one spilled run.
///
/// Holds two decoded element blocks (`front` being consumed, `back` ready)
/// plus one byte staging buffer; all three are sized by the caller's
/// `block_elems` budget, so memory per reader is
/// `block_elems * (2 * WIDTH) + min(IO_BUF_BYTES, block_elems * WIDTH)`
/// regardless of what the header claims.
pub struct RunReader<K: ExtKey> {
    file: File,
    /// Elements not yet read off disk.
    remaining: u64,
    /// Total element count from the (validated) header.
    len: u64,
    block_elems: usize,
    front: Vec<K>,
    pos: usize,
    back: Vec<K>,
    bytes: Vec<u8>,
}

impl<K: ExtKey> RunReader<K> {
    /// Open and validate `path`, priming both buffers.
    pub fn open(path: &Path, block_elems: usize) -> Result<Self, RunLoadError> {
        let mut file = File::open(path)?;
        let actual_bytes = file.metadata()?.len();
        let mut header = [0u8; RUN_HEADER_BYTES];
        if actual_bytes < RUN_HEADER_BYTES as u64 {
            return Err(RunLoadError::Truncated {
                expected_bytes: RUN_HEADER_BYTES as u64,
                actual_bytes,
            });
        }
        file.read_exact(&mut header)?;
        let magic = [header[0], header[1], header[2], header[3]];
        if magic != RUN_MAGIC {
            return Err(RunLoadError::BadMagic { found: magic });
        }
        let version = u16::from_le_bytes([header[4], header[5]]);
        if version != RUN_FORMAT_VERSION {
            return Err(RunLoadError::BadVersion { found: version });
        }
        if header[6] != K::DTYPE_CODE {
            return Err(RunLoadError::BadDtype {
                expected: K::DTYPE_CODE,
                found: header[6],
            });
        }
        let count = u64::from_le_bytes(header[8..16].try_into().unwrap());
        if count > MAX_RUN_ELEMS {
            return Err(RunLoadError::Oversized { count });
        }
        let payload = count
            .checked_mul(K::WIDTH as u64)
            .and_then(|p| p.checked_add(RUN_HEADER_BYTES as u64))
            .ok_or(RunLoadError::Oversized { count })?;
        if payload != actual_bytes {
            return Err(RunLoadError::Truncated {
                expected_bytes: payload,
                actual_bytes,
            });
        }
        let block_elems = block_elems.max(1);
        let mut reader = RunReader {
            file,
            remaining: count,
            len: count,
            block_elems,
            front: Vec::with_capacity(block_elems.min(count as usize)),
            pos: 0,
            back: Vec::with_capacity(block_elems.min(count as usize)),
            bytes: Vec::new(),
        };
        reader.fill_back()?;
        reader.swap_in_back();
        reader.fill_back()?;
        Ok(reader)
    }

    /// Element count from the validated header.
    pub fn len(&self) -> u64 {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bytes of buffer memory this reader holds at steady state.
    pub fn buffer_bytes(&self) -> usize {
        let block = self.block_elems * K::WIDTH;
        2 * block + block.min(IO_BUF_BYTES)
    }

    /// Decode the next block off disk into `back` (no-op when exhausted).
    fn fill_back(&mut self) -> Result<(), RunLoadError> {
        self.back.clear();
        let take = (self.remaining.min(self.block_elems as u64)) as usize;
        if take == 0 {
            return Ok(());
        }
        let want = take * K::WIDTH;
        self.bytes.resize(want, 0);
        self.file.read_exact(&mut self.bytes[..want]).map_err(|e| {
            // A file shrinking between open and read is the same class of
            // damage as a short file at open time.
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                RunLoadError::Truncated {
                    expected_bytes: RUN_HEADER_BYTES as u64 + self.len * K::WIDTH as u64,
                    actual_bytes: 0,
                }
            } else {
                RunLoadError::Io(e)
            }
        })?;
        for chunk in self.bytes[..want].chunks_exact(K::WIDTH) {
            self.back.push(K::read_le(chunk));
        }
        self.remaining -= take as u64;
        Ok(())
    }

    fn swap_in_back(&mut self) {
        std::mem::swap(&mut self.front, &mut self.back);
        self.pos = 0;
    }

    /// Current head element, or `None` when the run is exhausted.
    pub fn peek(&self) -> Option<&K> {
        self.front.get(self.pos)
    }

    /// Consume and return the head, refilling the back buffer as the front
    /// drains.
    pub fn pop(&mut self) -> Result<Option<K>, RunLoadError> {
        let Some(&head) = self.front.get(self.pos) else {
            return Ok(None);
        };
        self.pos += 1;
        if self.pos == self.front.len() {
            self.swap_in_back();
            self.fill_back()?;
        }
        Ok(Some(head))
    }
}

/// Monotonic suffix so concurrent jobs in one process never collide on a
/// spill subdirectory name.
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

/// RAII owner of one job's spill subdirectory.
///
/// Created under the configured spill root as `evsr-<pid>-<seq>`; `Drop`
/// removes the whole subtree. Because every code path out of the external
/// sorter — success, cancel, error, and the worker-loss panic that
/// [`CompletionGuard`](crate::coordinator) converts to `WorkerLost` — unwinds
/// through this guard, spill files can never outlive their job.
#[derive(Debug)]
pub struct SpillGuard {
    dir: PathBuf,
}

impl SpillGuard {
    /// Create a fresh unique subdirectory under `root` (creating `root`
    /// itself if needed).
    pub fn create(root: &Path) -> std::io::Result<SpillGuard> {
        std::fs::create_dir_all(root)?;
        let seq = SPILL_SEQ.fetch_add(1, Ordering::Relaxed);
        let dir = root.join(format!("evsr-{}-{}", std::process::id(), seq));
        std::fs::create_dir(&dir)?;
        Ok(SpillGuard { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Path for run file `idx` inside this job's subdirectory.
    pub fn run_path(&self, idx: u64) -> PathBuf {
        self.dir.join(format!("run-{idx:06}.evsr"))
    }
}

impl Drop for SpillGuard {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_root(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "evosort-runfile-{}-{}",
            std::process::id(),
            tag
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip_all_dtypes() {
        let root = tmp_root("roundtrip");
        let p = root.join("r.evsr");

        let data_i64: Vec<i64> = (0..5000).map(|i| i * 3 - 7000).collect();
        write_run(&p, &data_i64).unwrap();
        let mut r = RunReader::<i64>::open(&p, 128).unwrap();
        assert_eq!(r.len(), 5000);
        let mut got = Vec::new();
        while let Some(v) = r.pop().unwrap() {
            got.push(v);
        }
        assert_eq!(got, data_i64);

        let data_f64: Vec<f64> = vec![-1.5, 0.0, 3.25, f64::NAN, 9.0];
        write_run(&p, &data_f64).unwrap();
        let mut r = RunReader::<f64>::open(&p, 2).unwrap();
        let mut got = Vec::new();
        while let Some(v) = r.pop().unwrap() {
            got.push(v);
        }
        assert_eq!(got.len(), 5);
        assert!(got[3].is_nan());
        assert_eq!(got[4], 9.0);

        let data_i32: Vec<i32> = vec![i32::MIN, -1, 0, 1, i32::MAX];
        write_run(&p, &data_i32).unwrap();
        let mut r = RunReader::<i32>::open(&p, 3).unwrap();
        let mut got = Vec::new();
        while let Some(v) = r.pop().unwrap() {
            got.push(v);
        }
        assert_eq!(got, data_i32);

        let data_u64: Vec<u64> = vec![0, 1, u64::MAX / 2, u64::MAX];
        write_run(&p, &data_u64).unwrap();
        let mut r = RunReader::<u64>::open(&p, 1).unwrap();
        let mut got = Vec::new();
        while let Some(v) = r.pop().unwrap() {
            got.push(v);
        }
        assert_eq!(got, data_u64);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn truncated_header_rejected() {
        let root = tmp_root("short-header");
        let p = root.join("r.evsr");
        std::fs::write(&p, b"EVSR\x01").unwrap();
        match RunReader::<i64>::open(&p, 64) {
            Err(RunLoadError::Truncated { .. }) => {}
            other => panic!("want Truncated, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn truncated_payload_rejected() {
        let root = tmp_root("short-payload");
        let p = root.join("r.evsr");
        let data: Vec<i64> = (0..100).collect();
        write_run(&p, &data).unwrap();
        // Chop the last 13 bytes off the payload.
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 13]).unwrap();
        match RunReader::<i64>::open(&p, 64) {
            Err(RunLoadError::Truncated { .. }) => {}
            other => panic!("want Truncated, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn garbage_magic_and_version_rejected() {
        let root = tmp_root("garbage");
        let p = root.join("r.evsr");
        let mut junk = vec![0u8; 64];
        junk[0..4].copy_from_slice(b"NOPE");
        std::fs::write(&p, &junk).unwrap();
        assert!(matches!(
            RunReader::<i64>::open(&p, 64),
            Err(RunLoadError::BadMagic { .. })
        ));

        let data: Vec<i64> = (0..4).collect();
        write_run(&p, &data).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[4] = 0xFF; // bogus version
        std::fs::write(&p, &bytes).unwrap();
        assert!(matches!(
            RunReader::<i64>::open(&p, 64),
            Err(RunLoadError::BadVersion { .. })
        ));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let root = tmp_root("dtype");
        let p = root.join("r.evsr");
        let data: Vec<i64> = (0..4).collect();
        write_run(&p, &data).unwrap();
        assert!(matches!(
            RunReader::<u64>::open(&p, 64),
            Err(RunLoadError::BadDtype { .. })
        ));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn oversized_header_rejected_without_allocation() {
        let root = tmp_root("oversized");
        let p = root.join("r.evsr");
        let mut header = [0u8; RUN_HEADER_BYTES];
        header[0..4].copy_from_slice(&RUN_MAGIC);
        header[4..6].copy_from_slice(&RUN_FORMAT_VERSION.to_le_bytes());
        header[6] = 0; // i64
        header[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&p, header).unwrap();
        // A count of u64::MAX must be rejected as Oversized before any
        // payload-sized allocation is attempted.
        assert!(matches!(
            RunReader::<i64>::open(&p, 64),
            Err(RunLoadError::Oversized { .. })
        ));
        // A merely-large-but-under-cap count whose payload is absent fails
        // the exact-length check instead.
        header[8..16].copy_from_slice(&(1u64 << 30).to_le_bytes());
        std::fs::write(&p, header).unwrap();
        assert!(matches!(
            RunReader::<i64>::open(&p, 64),
            Err(RunLoadError::Truncated { .. })
        ));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn spill_guard_removes_directory_on_drop() {
        let root = tmp_root("guard");
        let kept;
        {
            let guard = SpillGuard::create(&root).unwrap();
            kept = guard.dir().to_path_buf();
            write_run(&guard.run_path(0), &[1i64, 2, 3]).unwrap();
            assert!(kept.exists());
        }
        assert!(!kept.exists(), "spill dir must be removed on drop");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn spill_guard_cleans_up_across_panic() {
        let root = tmp_root("guard-panic");
        let dir = std::sync::Arc::new(std::sync::Mutex::new(PathBuf::new()));
        let dir2 = dir.clone();
        let root2 = root.clone();
        let result = std::panic::catch_unwind(move || {
            let guard = SpillGuard::create(&root2).unwrap();
            *dir2.lock().unwrap() = guard.dir().to_path_buf();
            write_run(&guard.run_path(0), &[9i64]).unwrap();
            panic!("simulated worker loss");
        });
        assert!(result.is_err());
        assert!(!dir.lock().unwrap().exists(), "guard must clean up on unwind");
        let _ = std::fs::remove_dir_all(&root);
    }
}
