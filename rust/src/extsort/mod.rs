//! Out-of-core external sorting: spill-to-disk runs + k-way streaming merge.
//!
//! The paper scales EvoSort to 10-billion-element workloads; this module is
//! the beyond-RAM half of that story. Oversized inputs are chunked into runs
//! sorted *in place* by the existing adaptive kernels (Algorithm 6 dispatch,
//! [`SortScratch`] arenas, the parked [`Executor`](crate::exec::Executor) —
//! nothing is re-implemented), each run is spilled to a schema-versioned file
//! under a per-job [`SpillGuard`] directory, and the runs are then k-way
//! merged with a loser tree whose reader/chunk buffers are all sized from a
//! byte budget. Merged output streams out through a chunk callback — the
//! service forwards chunks over the normal `Ticket`/`ResultStream` contracts,
//! so consumers see the first sorted elements while the tail of the merge is
//! still on disk.
//!
//! The three policy knobs — `run_size`, `merge_fan_in`, `spill_threshold` —
//! are GA-tunable genes keyed by a beyond-memory fingerprint class (the base
//! workload label suffixed `:xm`, see
//! [`beyond_memory_label`](crate::autotune::fingerprint::beyond_memory_label)),
//! giving the online tuner genuinely new territory: the trade-off between
//! many cheap runs and few expensive merge passes is exactly the kind of
//! hardware-dependent constant the paper's GA discovers empirically.

// Enforced boundary of the unsafe audit surface (see README
// “Correctness tooling”): spill/merge I/O is built on safe std APIs only.
#![forbid(unsafe_code)]

pub mod merge;
pub mod run_file;

use std::path::PathBuf;
use std::time::Instant;

use crate::obs::Phase;
use crate::params::{GeneRange, SortParams};
use crate::sort::adaptive::AdaptiveSorter;
use crate::sort::key::{SortKey, SortScratch};

pub use run_file::{RunLoadError, RunReader, RunWriter, SpillGuard, write_run};

/// Extension of [`SortKey`] with the fixed-width little-endian encoding the
/// on-disk run format needs. Floats round-trip through raw IEEE bits, so
/// every NaN payload survives a spill byte-exactly.
pub trait ExtKey: SortKey {
    /// Serialized width in bytes.
    const WIDTH: usize;
    /// Dtype code in the run-file header.
    const DTYPE_CODE: u8;
    fn write_le(self, out: &mut Vec<u8>);
    /// Decode from exactly [`Self::WIDTH`] bytes.
    fn read_le(bytes: &[u8]) -> Self;
}

impl ExtKey for i64 {
    const WIDTH: usize = 8;
    const DTYPE_CODE: u8 = 0;
    #[inline]
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    #[inline]
    fn read_le(bytes: &[u8]) -> Self {
        i64::from_le_bytes(bytes.try_into().unwrap())
    }
}

impl ExtKey for i32 {
    const WIDTH: usize = 4;
    const DTYPE_CODE: u8 = 1;
    #[inline]
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    #[inline]
    fn read_le(bytes: &[u8]) -> Self {
        i32::from_le_bytes(bytes.try_into().unwrap())
    }
}

impl ExtKey for u64 {
    const WIDTH: usize = 8;
    const DTYPE_CODE: u8 = 2;
    #[inline]
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    #[inline]
    fn read_le(bytes: &[u8]) -> Self {
        u64::from_le_bytes(bytes.try_into().unwrap())
    }
}

impl ExtKey for f64 {
    const WIDTH: usize = 8;
    const DTYPE_CODE: u8 = 3;
    #[inline]
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_bits().to_le_bytes());
    }
    #[inline]
    fn read_le(bytes: &[u8]) -> Self {
        f64::from_bits(u64::from_le_bytes(bytes.try_into().unwrap()))
    }
}

/// Smallest run the planner will form (kernel dispatch below this is all
/// overhead).
pub const MIN_RUN_ELEMS: usize = 1024;
/// Smallest reader/output block.
pub const MIN_BLOCK_ELEMS: usize = 256;
/// Planner floor on the byte budget — below this the buffer floors dominate
/// and the budget is not honourable anyway.
pub const MIN_BUDGET_BYTES: usize = 64 * 1024;

/// The GA-tunable out-of-core policy genes.
///
/// Stored as `i64` to share the tuning cache's gene wire format.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExtParams {
    /// Elements per spilled run (the planner additionally caps this so one
    /// run plus its kernel scratch fits in half the byte budget).
    pub run_size: i64,
    /// Maximum runs merged per pass; more runs than this triggers
    /// intermediate merge passes.
    pub merge_fan_in: i64,
    /// Element count above which a job escalates out-of-core even when it
    /// fits the byte budget; `0` means escalate on budget alone. This lets
    /// the GA discover that spilling *earlier* than the hard budget can win
    /// (e.g. when in-memory sorting starts thrashing caches).
    pub spill_threshold: i64,
}

impl Default for ExtParams {
    fn default() -> Self {
        ExtParams {
            run_size: 1 << 21,
            merge_fan_in: 16,
            spill_threshold: 0,
        }
    }
}

impl ExtParams {
    pub fn to_genes(self) -> [i64; 3] {
        [self.run_size, self.merge_fan_in, self.spill_threshold]
    }

    /// Decode a gene triple, clamping into [`ExtBounds::default`].
    pub fn from_genes(genes: &[i64; 3]) -> ExtParams {
        ExtBounds::default().clamp(genes)
    }
}

/// Legal ranges for the spill genes (the ext analogue of `params::Bounds`).
#[derive(Debug, Clone, Copy)]
pub struct ExtBounds {
    pub run_size: GeneRange,
    pub merge_fan_in: GeneRange,
    pub spill_threshold: GeneRange,
}

impl Default for ExtBounds {
    fn default() -> Self {
        ExtBounds {
            run_size: GeneRange::new(MIN_RUN_ELEMS as i64, 1 << 26),
            merge_fan_in: GeneRange::new(2, 128),
            spill_threshold: GeneRange::new(0, 1 << 40),
        }
    }
}

impl ExtBounds {
    pub fn clamp(&self, genes: &[i64; 3]) -> ExtParams {
        ExtParams {
            run_size: self.run_size.clamp_val(genes[0]),
            merge_fan_in: self.merge_fan_in.clamp_val(genes[1]),
            spill_threshold: self.spill_threshold.clamp_val(genes[2]),
        }
    }

    pub fn validate(&self, genes: &[i64; 3]) -> bool {
        self.run_size.contains(genes[0])
            && self.merge_fan_in.contains(genes[1])
            && self.spill_threshold.contains(genes[2])
    }
}

/// Service-level out-of-core configuration.
#[derive(Debug, Clone)]
pub struct ExternalConfig {
    /// Byte budget for the sort path's working set (run-kernel scratch,
    /// reader blocks, output chunk). Jobs whose payload exceeds this
    /// escalate to the external sorter.
    pub memory_budget: usize,
    /// Root directory for per-job spill subdirectories.
    pub spill_dir: PathBuf,
    /// Explicit spill genes; `None` resolves tuned genes from the tuning
    /// cache's beyond-memory class, falling back to [`ExtParams::default`].
    pub params: Option<ExtParams>,
}

impl ExternalConfig {
    pub fn new(memory_budget: usize) -> Self {
        ExternalConfig {
            memory_budget,
            spill_dir: std::env::temp_dir(),
            params: None,
        }
    }

    pub fn with_spill_dir(mut self, dir: PathBuf) -> Self {
        self.spill_dir = dir;
        self
    }

    pub fn with_params(mut self, params: ExtParams) -> Self {
        self.params = Some(params);
        self
    }

    /// Should a job of `bytes` payload / `elems` length leave RAM?
    pub fn escalates(&self, bytes: usize, elems: usize, params: &ExtParams) -> bool {
        bytes > self.memory_budget
            || (params.spill_threshold > 0 && elems as i64 > params.spill_threshold)
    }
}

/// Failure modes of an external sort.
#[derive(Debug)]
pub enum ExtError {
    /// The cancel probe fired; spill files are already gone (guard drop).
    Cancelled,
    /// A spilled run failed validation on re-load.
    Run(RunLoadError),
    /// Filesystem trouble in the spill directory.
    Io(std::io::Error),
}

impl std::fmt::Display for ExtError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExtError::Cancelled => write!(f, "external sort cancelled"),
            ExtError::Run(e) => write!(f, "external sort: {e}"),
            ExtError::Io(e) => write!(f, "external sort: io error: {e}"),
        }
    }
}

impl std::error::Error for ExtError {}

impl From<RunLoadError> for ExtError {
    fn from(e: RunLoadError) -> Self {
        ExtError::Run(e)
    }
}

impl From<std::io::Error> for ExtError {
    fn from(e: std::io::Error) -> Self {
        ExtError::Io(e)
    }
}

/// What one external sort actually did — the service turns this into
/// `extsort.*` metrics and the trace timeline.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExtReport {
    pub elements: u64,
    pub runs_spilled: u64,
    /// Merge passes including the final streaming pass.
    pub merge_passes: u64,
    pub chunks_streamed: u64,
    /// Analytic peak of the sort-path working set (kernel scratch, reader
    /// blocks, staging buffers, output chunk) — excludes the caller's input
    /// and reassembled output vectors.
    pub peak_working_bytes: usize,
    pub run_elems: usize,
    pub block_elems: usize,
    pub chunk_elems: usize,
}

/// Deterministic buffer sizing derived from `(n, width, budget, genes)`.
///
/// Shared by [`ExternalSorter::sort_streaming`] and the service's streaming
/// submission path, which must know `total_chunks` before the sort starts to
/// size its [`BatchTicket`](crate::coordinator::BatchTicket).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpillPlan {
    pub run_elems: usize,
    pub block_elems: usize,
    pub chunk_elems: usize,
    pub fan_in: usize,
    pub runs: usize,
    pub total_chunks: usize,
}

/// Compute the spill plan for an `n`-element job of `width`-byte keys under
/// `budget` bytes with genes `p`.
pub fn plan(n: usize, width: usize, budget: usize, p: ExtParams) -> SpillPlan {
    let budget = budget.max(MIN_BUDGET_BYTES);
    // Run formation sorts one run in place; the kernel's ping-pong scratch
    // is about one extra copy of the run, so a run gets half the budget.
    let run_cap = (budget / (2 * width)).max(MIN_RUN_ELEMS);
    let run_elems = (p.run_size.max(1) as usize).clamp(MIN_RUN_ELEMS, run_cap);
    let runs = n.div_ceil(run_elems).max(1);
    let fan_in = (p.merge_fan_in.clamp(2, 128) as usize).min(runs.max(2));
    // Merge holds `fan_in` double-buffered readers (2 blocks + staging each,
    // ~3 blocks) plus one output chunk of the same size.
    let block_elems = (budget / (width * (3 * fan_in + 1))).max(MIN_BLOCK_ELEMS);
    let chunk_elems = block_elems;
    let total_chunks = if n == 0 { 1 } else { n.div_ceil(chunk_elems) };
    SpillPlan {
        run_elems,
        block_elems,
        chunk_elems,
        fan_in,
        runs,
        total_chunks,
    }
}

/// The out-of-core driver: run formation → spill → (multi-pass) loser-tree
/// merge, streaming chunks to a callback.
pub struct ExternalSorter<'a> {
    sorter: &'a AdaptiveSorter,
    config: &'a ExternalConfig,
}

impl<'a> ExternalSorter<'a> {
    pub fn new(sorter: &'a AdaptiveSorter, config: &'a ExternalConfig) -> Self {
        ExternalSorter { sorter, config }
    }

    /// Sort `data` out of core, handing sorted chunks to `emit` in order.
    ///
    /// Takes the input by value: once every run is spilled the input buffer
    /// is freed, so the merge phase never holds input + buffers together.
    /// `cancel` is probed between runs and at every chunk boundary; a `true`
    /// aborts with [`ExtError::Cancelled`]. The per-job spill directory is
    /// removed on *every* exit path — success, error, cancel, or unwind —
    /// by the [`SpillGuard`]'s `Drop`.
    ///
    /// Run sorting reuses the caller's [`SortScratch`]; when its phase timer
    /// is armed, run-formation/spill/merge time accumulates under the
    /// [`Phase::ExtRunForm`] / [`Phase::ExtSpill`] / [`Phase::ExtMerge`]
    /// observability phases alongside the per-kernel phases of the run
    /// sorts themselves.
    pub fn sort_streaming<K: ExtKey>(
        &self,
        mut data: Vec<K>,
        params: &SortParams,
        ext: ExtParams,
        scratch: &mut SortScratch,
        emit: &mut dyn FnMut(Vec<K>) -> Result<(), ExtError>,
        cancel: &mut dyn FnMut() -> bool,
    ) -> Result<ExtReport, ExtError> {
        let n = data.len();
        let width = K::WIDTH;
        let plan = plan(n, width, self.config.memory_budget, ext);
        let guard = SpillGuard::create(&self.config.spill_dir)?;
        let mut report = ExtReport {
            run_elems: plan.run_elems,
            block_elems: plan.block_elems,
            chunk_elems: plan.chunk_elems,
            ..ExtReport::default()
        };

        // --- Phase 1: run formation + spill -------------------------------
        let mut next_run = 0u64;
        let mut start = 0usize;
        while start < n {
            if cancel() {
                return Err(ExtError::Cancelled);
            }
            let end = (start + plan.run_elems).min(n);
            let t = scratch.timer_mut().begin();
            K::sort_with(self.sorter, &mut data[start..end], params, scratch);
            scratch.timer_mut().end(Phase::ExtRunForm, t);
            let t = scratch.timer_mut().begin();
            write_run(&guard.run_path(next_run), &data[start..end])?;
            scratch.timer_mut().end(Phase::ExtSpill, t);
            next_run += 1;
            start = end;
        }
        report.runs_spilled = next_run;
        // Working set so far: one run's kernel scratch + writer staging.
        report.peak_working_bytes = plan.run_elems.min(n.max(1)) * width + run_file::IO_BUF_BYTES;
        // Everything lives on disk now — free the input before the merge
        // allocates its reader buffers.
        data.clear();
        data.shrink_to_fit();
        drop(data);

        let mut live: Vec<PathBuf> = (0..next_run).map(|i| guard.run_path(i)).collect();

        // --- Phase 2: intermediate merge passes (fan-in capped) ------------
        while live.len() > plan.fan_in {
            if cancel() {
                return Err(ExtError::Cancelled);
            }
            let group: Vec<PathBuf> = live.drain(..plan.fan_in).collect();
            let mut readers = Vec::with_capacity(group.len());
            for p in &group {
                readers.push(RunReader::<K>::open(p, plan.block_elems)?);
            }
            let pass_bytes: usize = readers.iter().map(|r| r.buffer_bytes()).sum::<usize>()
                + plan.chunk_elems * width
                + run_file::IO_BUF_BYTES;
            report.peak_working_bytes = report.peak_working_bytes.max(pass_bytes);
            let dest = guard.run_path(next_run);
            next_run += 1;
            let t = scratch.timer_mut().begin();
            merge::merge_to_run(readers, &dest, plan.chunk_elems, cancel)?;
            scratch.timer_mut().end(Phase::ExtMerge, t);
            for p in &group {
                let _ = std::fs::remove_file(p);
            }
            live.push(dest);
            report.merge_passes += 1;
        }

        // --- Phase 3: final streaming merge --------------------------------
        let mut readers = Vec::with_capacity(live.len());
        for p in &live {
            readers.push(RunReader::<K>::open(p, plan.block_elems)?);
        }
        let final_bytes: usize = readers.iter().map(|r| r.buffer_bytes()).sum::<usize>()
            + plan.chunk_elems * width;
        report.peak_working_bytes = report.peak_working_bytes.max(final_bytes);
        let mut chunks = 0u64;
        let t = scratch.timer_mut().begin();
        let emitted = merge::merge_streaming(
            readers,
            plan.chunk_elems,
            &mut |chunk| {
                chunks += 1;
                emit(chunk)
            },
            cancel,
        )?;
        scratch.timer_mut().end(Phase::ExtMerge, t);
        report.merge_passes += 1;
        report.chunks_streamed = chunks;
        report.elements = emitted;
        Ok(report)
        // `guard` drops here: spill subdirectory removed.
    }
}

/// In-memory proxy fitness for the spill genes, used by the online tuner.
///
/// The tuner thread must not touch the spill disk, so the gene trade-off is
/// replayed on the retained workload sample: the run count the genes would
/// produce at full job scale (`n_hint / run_size`) partitions the sample,
/// each stripe is sorted, and the stripes are merged in passes of
/// `merge_fan_in`. Wall time of the best repeat is the fitness (lower is
/// better) — responsive to both the run-count/merge-depth trade and the
/// fan-in width, on the same machine the real merges run on.
pub fn simulate_fitness(sample: &[i64], n_hint: usize, p: &ExtParams, repeats: usize) -> f64 {
    let n = sample.len().max(1);
    let runs_full = n_hint.max(1).div_ceil((p.run_size.max(1)) as usize).max(1);
    let runs = runs_full.min(n);
    let fan = p.merge_fan_in.clamp(2, 128) as usize;
    let run_len = n.div_ceil(runs);
    let mut best = f64::INFINITY;
    for _ in 0..repeats.max(1) {
        let started = Instant::now();
        let mut stripes: Vec<Vec<i64>> = sample
            .chunks(run_len)
            .map(|c| {
                let mut v = c.to_vec();
                v.sort_unstable();
                v
            })
            .collect();
        while stripes.len() > 1 {
            let mut next = Vec::with_capacity(stripes.len().div_ceil(fan));
            for group in stripes.chunks(fan) {
                next.push(merge_group(group));
            }
            stripes = next;
        }
        best = best.min(started.elapsed().as_secs_f64());
    }
    best
}

/// Linear k-way merge of sorted stripes (sample scale, so `O(k)` per element
/// is fine and keeps the proxy allocation-light).
fn merge_group(stripes: &[Vec<i64>]) -> Vec<i64> {
    let total: usize = stripes.iter().map(|s| s.len()).sum();
    let mut idx = vec![0usize; stripes.len()];
    let mut out = Vec::with_capacity(total);
    loop {
        let mut pick: Option<(usize, i64)> = None;
        for (i, s) in stripes.iter().enumerate() {
            if let Some(&v) = s.get(idx[i]) {
                let better = match pick {
                    None => true,
                    Some((_, best)) => v < best,
                };
                if better {
                    pick = Some((i, v));
                }
            }
        }
        match pick {
            Some((i, v)) => {
                out.push(v);
                idx[i] += 1;
            }
            None => break,
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::SortParams;

    fn tmp_root(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "evosort-extsort-{}-{}",
            std::process::id(),
            tag
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn spill_dirs_under(root: &PathBuf) -> usize {
        std::fs::read_dir(root)
            .map(|it| it.filter_map(|e| e.ok()).count())
            .unwrap_or(0)
    }

    #[test]
    fn gene_roundtrip_and_clamping() {
        let p = ExtParams {
            run_size: 8192,
            merge_fan_in: 8,
            spill_threshold: 1_000_000,
        };
        assert_eq!(ExtParams::from_genes(&p.to_genes()), p);
        let clamped = ExtParams::from_genes(&[-5, 1_000_000, -1]);
        assert_eq!(clamped.run_size, MIN_RUN_ELEMS as i64);
        assert_eq!(clamped.merge_fan_in, 128);
        assert_eq!(clamped.spill_threshold, 0);
        assert!(ExtBounds::default().validate(&p.to_genes()));
        assert!(!ExtBounds::default().validate(&[-5, 8, 0]));
    }

    #[test]
    fn plan_is_budget_monotone_and_deterministic() {
        let p = ExtParams::default();
        let a = plan(10_000_000, 8, 1 << 20, p);
        let b = plan(10_000_000, 8, 1 << 20, p);
        assert_eq!(a, b);
        // One run plus scratch must fit in half the budget.
        assert!(a.run_elems * 8 * 2 <= (1 << 20));
        assert!(a.runs >= 3);
        // A bigger budget never shrinks the buffers.
        let big = plan(10_000_000, 8, 1 << 24, p);
        assert!(big.run_elems >= a.run_elems);
        assert!(big.block_elems >= a.block_elems);
        // Chunk math covers the whole input.
        assert!(a.total_chunks * a.chunk_elems >= 10_000_000);
        assert_eq!(plan(0, 8, 1 << 20, p).total_chunks, 1);
    }

    #[test]
    fn external_sort_streams_sorted_output_and_cleans_up() {
        let root = tmp_root("stream");
        let cfg = ExternalConfig::new(1 << 20).with_spill_dir(root.clone());
        let sorter = AdaptiveSorter::new(2);
        let mut scratch = SortScratch::new();
        let n = 300_000usize;
        let data: Vec<i64> = (0..n as i64).map(|i| (i * 2_654_435_761) % 1_000_003).collect();
        let mut expect = data.clone();
        expect.sort_unstable();
        let mut got: Vec<i64> = Vec::new();
        let report = ExternalSorter::new(&sorter, &cfg)
            .sort_streaming(
                data,
                &SortParams::default(),
                ExtParams {
                    run_size: 40_000,
                    merge_fan_in: 4,
                    spill_threshold: 0,
                },
                &mut scratch,
                &mut |chunk| {
                    got.extend_from_slice(&chunk);
                    Ok(())
                },
                &mut || false,
            )
            .unwrap();
        assert_eq!(got, expect);
        assert!(report.runs_spilled >= 3, "run_size forces >= 3 runs");
        assert!(report.merge_passes >= 2, "fan-in 4 over 8 runs needs a pre-pass");
        assert_eq!(report.elements, n as u64);
        assert!(report.chunks_streamed > 1);
        assert!(
            report.peak_working_bytes <= 1 << 20,
            "tracked working set {} exceeds budget",
            report.peak_working_bytes
        );
        assert_eq!(spill_dirs_under(&root), 0, "spill dir must be empty after success");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn cancel_mid_merge_removes_spill_files() {
        let root = tmp_root("cancel");
        let cfg = ExternalConfig::new(1 << 18).with_spill_dir(root.clone());
        let sorter = AdaptiveSorter::new(1);
        let mut scratch = SortScratch::new();
        let data: Vec<i64> = (0..120_000).rev().collect();
        let mut chunks = 0usize;
        let err = ExternalSorter::new(&sorter, &cfg)
            .sort_streaming(
                data,
                &SortParams::default(),
                ExtParams {
                    run_size: 20_000,
                    merge_fan_in: 16,
                    spill_threshold: 0,
                },
                &mut scratch,
                &mut |_chunk| {
                    chunks += 1;
                    Ok(())
                },
                &mut || chunks >= 2, // cancel once merged output is flowing
            )
            .unwrap_err();
        assert!(matches!(err, ExtError::Cancelled));
        assert_eq!(
            spill_dirs_under(&root),
            0,
            "cancel mid-merge must remove the spill directory"
        );
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn first_chunk_arrives_while_runs_still_on_disk() {
        let root = tmp_root("early");
        let cfg = ExternalConfig::new(1 << 19).with_spill_dir(root.clone());
        let sorter = AdaptiveSorter::new(1);
        let mut scratch = SortScratch::new();
        let data: Vec<i64> = (0..200_000).rev().collect();
        let mut first_chunk_saw_runs = false;
        let mut chunks = 0usize;
        ExternalSorter::new(&sorter, &cfg)
            .sort_streaming(
                data,
                &SortParams::default(),
                ExtParams {
                    run_size: 30_000,
                    merge_fan_in: 32,
                    spill_threshold: 0,
                },
                &mut scratch,
                &mut |_chunk| {
                    if chunks == 0 {
                        // Streaming means the consumer holds sorted output
                        // while the merge's inputs are still spilled.
                        first_chunk_saw_runs = spill_dirs_under(&root) > 0;
                    }
                    chunks += 1;
                    Ok(())
                },
                &mut || false,
            )
            .unwrap();
        assert!(chunks > 1, "expected a multi-chunk stream");
        assert!(
            first_chunk_saw_runs,
            "first chunk must be emitted before the merge finishes"
        );
        assert_eq!(spill_dirs_under(&root), 0);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn simulate_fitness_tracks_gene_quality() {
        let sample: Vec<i64> = (0..4096).map(|i| (i * 37) % 911).collect();
        let p = ExtParams::default();
        let f = simulate_fitness(&sample, 50_000_000, &p, 2);
        assert!(f.is_finite() && f >= 0.0);
        // Degenerate genes (runs of 1 element, minimum fan-in) must cost
        // strictly more than sane ones on the same sample.
        let bad = ExtParams {
            run_size: MIN_RUN_ELEMS as i64,
            merge_fan_in: 2,
            spill_threshold: 0,
        };
        let fb = simulate_fitness(&sample, 1 << 34, &bad, 2);
        assert!(fb.is_finite() && fb >= 0.0);
    }
}
