//! Bounded-memory k-way merge over spilled runs.
//!
//! The merge is a classic loser tree (tournament tree): `k` leaves, one per
//! run, `k - 1` internal nodes each remembering the *loser* of its match, and
//! the overall winner at the root. Emitting the winner and replaying its leaf
//! costs one root-to-leaf path — `O(log k)` comparisons per element instead
//! of the `O(k)` of a linear scan, which is what makes wide fan-ins cheap.
//!
//! Each leaf draws from a double-buffered [`RunReader`], so the whole merge
//! holds `k * 3` blocks plus one output chunk — all sized from the memory
//! budget by the caller, never from file headers. Output leaves through a
//! chunk callback so the first merged elements reach the consumer while the
//! tail of the merge is still on disk.

use std::path::Path;

use super::run_file::{RunLoadError, RunReader, RunWriter};
use super::{ExtError, ExtKey};

/// Tournament tree over `k` run readers, padded to a power of two with
/// permanently-exhausted virtual leaves.
struct LoserTree<K: ExtKey> {
    /// Padded leaf count (power of two, >= 1).
    k: usize,
    /// `tree[0]` is the current winner's leaf index; `tree[1..k]` hold the
    /// loser of each internal match.
    tree: Vec<usize>,
    readers: Vec<RunReader<K>>,
    /// Current head per leaf; `None` = exhausted (or virtual padding).
    heads: Vec<Option<K>>,
}

impl<K: ExtKey> LoserTree<K> {
    fn new(mut readers: Vec<RunReader<K>>) -> Result<Self, RunLoadError> {
        let real = readers.len().max(1);
        let k = real.next_power_of_two();
        let mut heads = Vec::with_capacity(k);
        for r in readers.iter_mut() {
            heads.push(r.pop()?);
        }
        heads.resize(k, None);
        let mut t = LoserTree {
            k,
            tree: vec![0; k],
            readers,
            heads,
        };
        t.tree[0] = t.build(1);
        Ok(t)
    }

    /// `true` when leaf `a`'s head wins (sorts before) leaf `b`'s. Exhausted
    /// leaves always lose; ties break toward the lower run index so the
    /// merge order is deterministic.
    fn beats(&self, a: usize, b: usize) -> bool {
        match (&self.heads[a], &self.heads[b]) {
            (Some(x), Some(y)) => match K::key_cmp(x, y) {
                std::cmp::Ordering::Less => true,
                std::cmp::Ordering::Greater => false,
                std::cmp::Ordering::Equal => a < b,
            },
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (None, None) => a < b,
        }
    }

    /// Recursively play the bracket below `node`, storing losers on the way
    /// up; returns the subtree's winner.
    fn build(&mut self, node: usize) -> usize {
        if node >= self.k {
            return node - self.k;
        }
        let l = self.build(2 * node);
        let r = self.build(2 * node + 1);
        let (win, lose) = if self.beats(l, r) { (l, r) } else { (r, l) };
        self.tree[node] = lose;
        win
    }

    /// Emit the current winner (if any), refill its leaf from the reader,
    /// and replay its path to the root.
    fn pop(&mut self) -> Result<Option<K>, RunLoadError> {
        let w = self.tree[0];
        let Some(val) = self.heads[w] else {
            return Ok(None);
        };
        self.heads[w] = match self.readers.get_mut(w) {
            Some(r) => r.pop()?,
            None => None,
        };
        let mut winner = w;
        let mut node = (w + self.k) / 2;
        while node >= 1 {
            if self.beats(self.tree[node], winner) {
                std::mem::swap(&mut self.tree[node], &mut winner);
            }
            if node == 1 {
                break;
            }
            node /= 2;
        }
        self.tree[0] = winner;
        Ok(Some(val))
    }
}

/// Merge `readers` and hand the output to `emit` in chunks of `chunk_elems`.
///
/// `cancel` is probed once per chunk boundary; a `true` aborts the merge with
/// [`ExtError::Cancelled`] before the chunk is emitted. An empty input still
/// emits exactly one empty chunk so streaming consumers always see at least
/// one result. Returns the number of elements emitted.
pub(crate) fn merge_streaming<K: ExtKey>(
    readers: Vec<RunReader<K>>,
    chunk_elems: usize,
    emit: &mut dyn FnMut(Vec<K>) -> Result<(), ExtError>,
    cancel: &mut dyn FnMut() -> bool,
) -> Result<u64, ExtError> {
    let total: u64 = readers.iter().map(|r| r.len()).sum();
    let chunk_elems = chunk_elems.max(1);
    let mut tree = LoserTree::new(readers)?;
    let mut out: Vec<K> = Vec::with_capacity(chunk_elems.min(total.max(1) as usize));
    let mut emitted = 0u64;
    while let Some(v) = tree.pop()? {
        out.push(v);
        if out.len() >= chunk_elems {
            if cancel() {
                return Err(ExtError::Cancelled);
            }
            emitted += out.len() as u64;
            let full = std::mem::replace(&mut out, Vec::with_capacity(chunk_elems));
            emit(full)?;
        }
    }
    if !out.is_empty() || emitted == 0 {
        if cancel() {
            return Err(ExtError::Cancelled);
        }
        emitted += out.len() as u64;
        emit(out)?;
    }
    Ok(emitted)
}

/// Merge `readers` into a new intermediate run at `dest` (one multi-pass
/// step when the live run count exceeds the fan-in). The writer's buffered
/// staging plus `chunk_elems` decoded elements is the only extra memory.
pub(crate) fn merge_to_run<K: ExtKey>(
    readers: Vec<RunReader<K>>,
    dest: &Path,
    chunk_elems: usize,
    cancel: &mut dyn FnMut() -> bool,
) -> Result<u64, ExtError> {
    let total: u64 = readers.iter().map(|r| r.len()).sum();
    let mut writer = RunWriter::<K>::create(dest, total)?;
    let written = merge_streaming(
        readers,
        chunk_elems,
        &mut |chunk| {
            writer.push_slice(&chunk)?;
            Ok(())
        },
        cancel,
    )?;
    writer.finish()?;
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::super::run_file::write_run;
    use super::*;
    use std::path::PathBuf;

    fn tmp_root(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "evosort-merge-{}-{}",
            std::process::id(),
            tag
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn spill_runs(root: &Path, runs: &[Vec<i64>]) -> Vec<RunReader<i64>> {
        runs.iter()
            .enumerate()
            .map(|(i, run)| {
                let p = root.join(format!("run-{i}.evsr"));
                write_run(&p, run).unwrap();
                RunReader::<i64>::open(&p, 16).unwrap()
            })
            .collect()
    }

    fn collect(readers: Vec<RunReader<i64>>, chunk: usize) -> Vec<i64> {
        let mut out = Vec::new();
        merge_streaming(
            readers,
            chunk,
            &mut |c| {
                out.extend_from_slice(&c);
                Ok(())
            },
            &mut || false,
        )
        .unwrap();
        out
    }

    #[test]
    fn merges_many_runs_in_order() {
        let root = tmp_root("order");
        // 7 runs (non-power-of-two fan) with overlap and duplicates.
        let runs: Vec<Vec<i64>> = (0..7)
            .map(|r| (0..200).map(|i| i * 7 + r as i64 * 3 - 400).collect())
            .collect();
        let mut expect: Vec<i64> = runs.iter().flatten().copied().collect();
        expect.sort_unstable();
        let got = collect(spill_runs(&root, &runs), 37);
        assert_eq!(got, expect);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn single_and_empty_runs() {
        let root = tmp_root("edge");
        let got = collect(spill_runs(&root, &[vec![5, 6, 7]]), 2);
        assert_eq!(got, vec![5, 6, 7]);
        // Empty run set: exactly one empty chunk.
        let mut chunks = 0;
        merge_streaming::<i64>(
            Vec::new(),
            8,
            &mut |c| {
                chunks += 1;
                assert!(c.is_empty());
                Ok(())
            },
            &mut || false,
        )
        .unwrap();
        assert_eq!(chunks, 1);
        // A present-but-empty spilled run merges away silently.
        let got = collect(spill_runs(&root, &[vec![], vec![1, 2]]), 8);
        assert_eq!(got, vec![1, 2]);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn cancel_stops_before_chunk_emission() {
        let root = tmp_root("cancel");
        let readers = spill_runs(&root, &[(0..100).collect(), (50..150).collect()]);
        let mut emitted = 0usize;
        let mut polls = 0usize;
        let err = merge_streaming(
            readers,
            10,
            &mut |_| {
                emitted += 1;
                Ok(())
            },
            &mut || {
                polls += 1;
                polls > 3 // cancel at the 4th chunk boundary
            },
        )
        .unwrap_err();
        assert!(matches!(err, ExtError::Cancelled));
        assert_eq!(emitted, 3, "no chunk may be emitted after cancellation");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn merge_to_run_produces_loadable_sorted_run() {
        let root = tmp_root("rerun");
        let runs: Vec<Vec<i64>> = vec![(0..50).collect(), (25..75).collect(), (60..90).collect()];
        let mut expect: Vec<i64> = runs.iter().flatten().copied().collect();
        expect.sort_unstable();
        let dest = root.join("merged.evsr");
        let n = merge_to_run(spill_runs(&root, &runs), &dest, 16, &mut || false).unwrap();
        assert_eq!(n, expect.len() as u64);
        let got = collect(vec![RunReader::<i64>::open(&dest, 16).unwrap()], 16);
        assert_eq!(got, expect);
        let _ = std::fs::remove_dir_all(&root);
    }
}
