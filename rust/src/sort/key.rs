//! Generic sort keys: the dtype layer under the typed service API.
//!
//! The paper positions EvoSort as a drop-in replacement for NumPy sort
//! routines across many dtypes; this module is the seam that opens the
//! framework beyond `i64`. A [`SortKey`] is any fixed-width key the adaptive
//! dispatcher (Algorithm 6) can serve: it knows its total order, its
//! canonical bit pattern (for multiset validation), a monotone projection
//! onto `i64` (for workload fingerprinting and retained tuning samples), and
//! how to route itself through [`AdaptiveSorter`] with a reusable scratch
//! buffer.
//!
//! Floats sort in IEEE-754 `total_cmp` order via the monotone bit transform
//! in [`super::floats`] — NaNs are real keys with defined positions, not
//! errors, exactly as `np.sort` treats them.
//!
//! [`SortPayload`] is the dtype-erased carrier the service moves through its
//! queues: one concrete enum rather than trait objects, so job routing stays
//! allocation-free and exhaustively matched.

use std::cmp::Ordering;

use super::adaptive::AdaptiveSorter;
use crate::data::validate::{mix64, Fingerprint, Verdict};
use crate::exec::{self, Executor};
use crate::obs::PhaseTimer;
use crate::params::SortParams;

/// Key dtype the service can sort. `name()` is the tag carried by
/// dtype-qualified fingerprint labels (`i64` stays untagged for cache
/// back-compat with pre-dtype persisted files).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dtype {
    I64,
    I32,
    U64,
    F64,
}

impl Dtype {
    pub fn name(self) -> &'static str {
        match self {
            Dtype::I64 => "i64",
            Dtype::I32 => "i32",
            Dtype::U64 => "u64",
            Dtype::F64 => "f64",
        }
    }

    /// Parse a CLI/config name.
    pub fn parse(s: &str) -> Option<Dtype> {
        Some(match s {
            "i64" => Dtype::I64,
            "i32" => Dtype::I32,
            "u64" => Dtype::U64,
            "f64" => Dtype::F64,
            _ => return None,
        })
    }

    pub fn all() -> &'static [Dtype] {
        &[Dtype::I64, Dtype::I32, Dtype::U64, Dtype::F64]
    }

    /// Key width in bytes (the `w<bytes>` fingerprint segment; also what
    /// payload-size budgeting multiplies element counts by).
    pub fn width(self) -> usize {
        match self {
            Dtype::I32 => 4,
            Dtype::I64 | Dtype::U64 | Dtype::F64 => 8,
        }
    }
}

impl std::fmt::Display for Dtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Per-worker scratch arena: one buffer per element width, reused across
/// every job a worker executes regardless of dtype mix (`f64` shares the
/// `u64` buffer — it sorts as transformed bits). Whichever kernel Algorithm
/// 6 dispatches uses the same buffer — radix scatter target, mergesort
/// ping-pong, samplesort bucket scatter — so one arena covers the whole
/// dispatch surface.
///
/// Buffers are checked out through the `*_for(n)` accessors, which ensure
/// capacity **before** the kernel runs and count every capacity growth in
/// [`grows`](Self::grows). Steady-state traffic (same job shape, warm
/// arena) therefore performs zero heap allocation in the sort path, and the
/// counter makes that testable: it must stay flat after the first job of a
/// shape.
///
/// Retention is bounded: every [`TRIM_INTERVAL`](Self::TRIM_INTERVAL)
/// checkouts the arena compares its capacity against the window's peak
/// request and releases buffers holding more than twice that, so one
/// outlier job cannot pin its high-water allocation in a long-lived worker
/// forever. Steady same-shape traffic never trips the trim (capacity ==
/// peak), keeping the hot path churn-free.
#[derive(Default)]
pub struct SortScratch {
    w_i64: Vec<i64>,
    w_i32: Vec<i32>,
    w_u64: Vec<u64>,
    /// Second i32 buffer for the XLA tile path's sentinel-padded copy (the
    /// tile backend needs a padded-to-tile-multiple working array *and* the
    /// regular merge scratch at the same time).
    w_i32_pad: Vec<i32>,
    grows: u64,
    /// Largest element count requested in the current retention window.
    peak_recent: usize,
    /// Checkouts since the last retention check.
    checkouts: u32,
    /// Per-phase kernel timer for the job currently using this arena
    /// (disabled by default — zero-cost; the traced service enables it and
    /// drains it after each sort). Lives here so timing, like the buffers,
    /// needs no per-job allocation.
    timer: PhaseTimer,
}

impl SortScratch {
    /// Checkouts between retention checks (see the struct docs).
    pub const TRIM_INTERVAL: u32 = 64;

    pub fn new() -> SortScratch {
        SortScratch::default()
    }

    /// How many times any buffer has had to grow (allocation events). Flat
    /// across jobs once the arena is warm.
    pub fn grows(&self) -> u64 {
        self.grows
    }

    /// The i64 buffer, grown (and counted) to hold at least `n` elements.
    pub fn i64_for(&mut self, n: usize) -> &mut Vec<i64> {
        self.note(n);
        Self::ensure(&mut self.w_i64, n, &mut self.grows)
    }

    /// The i32 buffer, grown (and counted) to hold at least `n` elements.
    pub fn i32_for(&mut self, n: usize) -> &mut Vec<i32> {
        self.note(n);
        Self::ensure(&mut self.w_i32, n, &mut self.grows)
    }

    /// The u64 buffer (shared by u64 and f64 jobs), grown (and counted) to
    /// hold at least `n` elements.
    pub fn u64_for(&mut self, n: usize) -> &mut Vec<u64> {
        self.note(n);
        Self::ensure(&mut self.w_u64, n, &mut self.grows)
    }

    /// The phase timer (enable before a job, drain after).
    pub fn timer_mut(&mut self) -> &mut PhaseTimer {
        &mut self.timer
    }

    /// Split-borrow checkouts: the width buffer **and** the timer at once,
    /// so `SortKey::sort_with` can hand both to the timed kernel entries
    /// without fighting the borrow checker.
    pub fn i64_and_timer(&mut self, n: usize) -> (&mut Vec<i64>, &mut PhaseTimer) {
        self.note(n);
        (Self::ensure(&mut self.w_i64, n, &mut self.grows), &mut self.timer)
    }

    /// See [`i64_and_timer`](Self::i64_and_timer).
    pub fn i32_and_timer(&mut self, n: usize) -> (&mut Vec<i32>, &mut PhaseTimer) {
        self.note(n);
        (Self::ensure(&mut self.w_i32, n, &mut self.grows), &mut self.timer)
    }

    /// See [`i64_and_timer`](Self::i64_and_timer).
    pub fn u64_and_timer(&mut self, n: usize) -> (&mut Vec<u64>, &mut PhaseTimer) {
        self.note(n);
        (Self::ensure(&mut self.w_u64, n, &mut self.grows), &mut self.timer)
    }

    /// Three-way checkout for the i32 XLA tile path: merge scratch, the
    /// sentinel-padding buffer, and the timer. Both buffers count toward the
    /// grow/trim bookkeeping, so the tile path is as allocation-free (and as
    /// outlier-bounded) at steady state as every other kernel.
    pub fn i32_pad_and_timer(
        &mut self,
        n: usize,
    ) -> (&mut Vec<i32>, &mut Vec<i32>, &mut PhaseTimer) {
        self.note(n);
        Self::ensure(&mut self.w_i32, n, &mut self.grows);
        Self::ensure(&mut self.w_i32_pad, n, &mut self.grows);
        (&mut self.w_i32, &mut self.w_i32_pad, &mut self.timer)
    }

    /// Record this checkout in the retention window; on the window
    /// boundary, release any buffer holding more than twice the window's
    /// peak request.
    fn note(&mut self, n: usize) {
        self.peak_recent = self.peak_recent.max(n);
        self.checkouts += 1;
        if self.checkouts >= Self::TRIM_INTERVAL {
            let keep = self.peak_recent;
            Self::trim(&mut self.w_i64, keep);
            Self::trim(&mut self.w_i32, keep);
            Self::trim(&mut self.w_u64, keep);
            Self::trim(&mut self.w_i32_pad, keep);
            self.checkouts = 0;
            self.peak_recent = 0;
        }
    }

    fn trim<T>(buf: &mut Vec<T>, keep: usize) {
        if buf.capacity() > keep.saturating_mul(2) {
            buf.truncate(keep);
            buf.shrink_to(keep);
        }
    }

    fn ensure<T>(buf: &mut Vec<T>, n: usize, grows: &mut u64) -> &mut Vec<T> {
        if buf.capacity() < n {
            *grows += 1;
            // `reserve` (not `_exact`) so repeated slightly-growing jobs
            // amortise instead of reallocating every time.
            buf.reserve(n - buf.len());
        }
        buf
    }
}

/// A fixed-width key the adaptive dispatcher can sort, validate and
/// fingerprint. Implemented for `i64`, `i32`, `u64` and `f64`.
pub trait SortKey: Copy + Send + Sync + Default + 'static {
    /// This key's dtype tag.
    const DTYPE: Dtype;

    /// Total-order comparison (IEEE-754 `total_cmp` for floats: -NaN first,
    /// -0.0 before +0.0, +NaN last).
    fn key_cmp(a: &Self, b: &Self) -> Ordering;

    /// Canonical bit pattern for the order-independent multiset fingerprint
    /// (distinct NaN payloads are distinct patterns — sorting must preserve
    /// them bit-exactly).
    fn canonical_bits(self) -> u64;

    /// Monotone projection onto `i64`: `a <= b` (total order) iff
    /// `a.to_order_i64() <= b.to_order_i64()`. Feeds workload fingerprinting
    /// and the retained tuning samples, so every dtype reuses the one
    /// GA-fitness pipeline. Magnitudes are *not* preserved (only order), so
    /// fingerprint value-features describe the projected shape.
    fn to_order_i64(self) -> i64;

    /// Algorithm 6 dispatch for this key width, reusing `scratch`.
    fn sort_with(
        sorter: &AdaptiveSorter,
        data: &mut [Self],
        params: &SortParams,
        scratch: &mut SortScratch,
    );

    /// Wrap a typed vector into the dtype-erased payload.
    fn into_payload(data: Vec<Self>) -> SortPayload;

    /// Recover the typed vector; returns the payload unchanged on a dtype
    /// mismatch.
    fn from_payload(payload: SortPayload) -> Result<Vec<Self>, SortPayload>;

    /// Borrow the typed slice when the payload holds this dtype.
    fn slice_of(payload: &SortPayload) -> Option<&[Self]>;
}

impl SortKey for i64 {
    const DTYPE: Dtype = Dtype::I64;

    #[inline]
    fn key_cmp(a: &Self, b: &Self) -> Ordering {
        a.cmp(b)
    }

    #[inline]
    fn canonical_bits(self) -> u64 {
        self as u64
    }

    #[inline]
    fn to_order_i64(self) -> i64 {
        self
    }

    fn sort_with(
        sorter: &AdaptiveSorter,
        data: &mut [Self],
        params: &SortParams,
        scratch: &mut SortScratch,
    ) {
        let (buf, timer) = scratch.i64_and_timer(data.len());
        sorter.sort_i64_timed(data, params, buf, timer);
    }

    fn into_payload(data: Vec<Self>) -> SortPayload {
        SortPayload::I64(data)
    }

    fn from_payload(payload: SortPayload) -> Result<Vec<Self>, SortPayload> {
        match payload {
            SortPayload::I64(v) => Ok(v),
            other => Err(other),
        }
    }

    fn slice_of(payload: &SortPayload) -> Option<&[Self]> {
        match payload {
            SortPayload::I64(v) => Some(v),
            _ => None,
        }
    }
}

impl SortKey for i32 {
    const DTYPE: Dtype = Dtype::I32;

    #[inline]
    fn key_cmp(a: &Self, b: &Self) -> Ordering {
        a.cmp(b)
    }

    #[inline]
    fn canonical_bits(self) -> u64 {
        self as u32 as u64
    }

    #[inline]
    fn to_order_i64(self) -> i64 {
        self as i64
    }

    fn sort_with(
        sorter: &AdaptiveSorter,
        data: &mut [Self],
        params: &SortParams,
        scratch: &mut SortScratch,
    ) {
        let (buf, pad, timer) = scratch.i32_pad_and_timer(data.len());
        sorter.sort_i32_timed_padded(data, params, buf, pad, timer);
    }

    fn into_payload(data: Vec<Self>) -> SortPayload {
        SortPayload::I32(data)
    }

    fn from_payload(payload: SortPayload) -> Result<Vec<Self>, SortPayload> {
        match payload {
            SortPayload::I32(v) => Ok(v),
            other => Err(other),
        }
    }

    fn slice_of(payload: &SortPayload) -> Option<&[Self]> {
        match payload {
            SortPayload::I32(v) => Some(v),
            _ => None,
        }
    }
}

impl SortKey for u64 {
    const DTYPE: Dtype = Dtype::U64;

    #[inline]
    fn key_cmp(a: &Self, b: &Self) -> Ordering {
        a.cmp(b)
    }

    #[inline]
    fn canonical_bits(self) -> u64 {
        self
    }

    #[inline]
    fn to_order_i64(self) -> i64 {
        // Flip the top bit: monotone map from unsigned order onto i64 order.
        (self ^ (1 << 63)) as i64
    }

    fn sort_with(
        sorter: &AdaptiveSorter,
        data: &mut [Self],
        params: &SortParams,
        scratch: &mut SortScratch,
    ) {
        let (buf, timer) = scratch.u64_and_timer(data.len());
        sorter.sort_u64_timed(data, params, buf, timer);
    }

    fn into_payload(data: Vec<Self>) -> SortPayload {
        SortPayload::U64(data)
    }

    fn from_payload(payload: SortPayload) -> Result<Vec<Self>, SortPayload> {
        match payload {
            SortPayload::U64(v) => Ok(v),
            other => Err(other),
        }
    }

    fn slice_of(payload: &SortPayload) -> Option<&[Self]> {
        match payload {
            SortPayload::U64(v) => Some(v),
            _ => None,
        }
    }
}

impl SortKey for f64 {
    const DTYPE: Dtype = Dtype::F64;

    #[inline]
    fn key_cmp(a: &Self, b: &Self) -> Ordering {
        a.total_cmp(b)
    }

    #[inline]
    fn canonical_bits(self) -> u64 {
        self.to_bits()
    }

    #[inline]
    fn to_order_i64(self) -> i64 {
        // total-order bits (unsigned order == total_cmp order), then the
        // monotone u64 -> i64 top-bit flip.
        (super::floats::f64_to_key(self.to_bits()) ^ (1 << 63)) as i64
    }

    fn sort_with(
        sorter: &AdaptiveSorter,
        data: &mut [Self],
        params: &SortParams,
        scratch: &mut SortScratch,
    ) {
        let (buf, timer) = scratch.u64_and_timer(data.len());
        sorter.sort_f64_timed(data, params, buf, timer);
    }

    fn into_payload(data: Vec<Self>) -> SortPayload {
        SortPayload::F64(data)
    }

    fn from_payload(payload: SortPayload) -> Result<Vec<Self>, SortPayload> {
        match payload {
            SortPayload::F64(v) => Ok(v),
            other => Err(other),
        }
    }

    fn slice_of(payload: &SortPayload) -> Option<&[Self]> {
        match payload {
            SortPayload::F64(v) => Some(v),
            _ => None,
        }
    }
}

/// Dtype-erased job data: the one concrete type the service moves through
/// its queues and hands back in outcomes.
#[derive(Debug, Clone, PartialEq)]
pub enum SortPayload {
    I64(Vec<i64>),
    I32(Vec<i32>),
    U64(Vec<u64>),
    F64(Vec<f64>),
}

impl SortPayload {
    pub fn dtype(&self) -> Dtype {
        match self {
            SortPayload::I64(_) => Dtype::I64,
            SortPayload::I32(_) => Dtype::I32,
            SortPayload::U64(_) => Dtype::U64,
            SortPayload::F64(_) => Dtype::F64,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            SortPayload::I64(v) => v.len(),
            SortPayload::I32(v) => v.len(),
            SortPayload::U64(v) => v.len(),
            SortPayload::F64(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow the typed slice (`None` on a dtype mismatch).
    pub fn as_slice<K: SortKey>(&self) -> Option<&[K]> {
        K::slice_of(self)
    }

    /// Unwrap into the typed vector (`Err(self)` on a dtype mismatch).
    pub fn into_vec<K: SortKey>(self) -> Result<Vec<K>, SortPayload> {
        K::from_payload(self)
    }

    /// Map generated `i64` test data into any dtype with an order-preserving
    /// transform (the workload generators are i64-native; this is how the
    /// CLI/bench layers open the f64/u64 scenario space).
    pub fn from_i64_values(data: Vec<i64>, dtype: Dtype) -> SortPayload {
        match dtype {
            Dtype::I64 => SortPayload::I64(data),
            Dtype::I32 => SortPayload::I32(
                data.into_iter()
                    .map(|x| x.clamp(i32::MIN as i64, i32::MAX as i64) as i32)
                    .collect(),
            ),
            // Shift by i64::MIN: monotone bijection onto u64.
            Dtype::U64 => {
                SortPayload::U64(data.into_iter().map(|x| x.wrapping_sub(i64::MIN) as u64).collect())
            }
            // Exact for |x| < 2^53 — the generators stay within ±1e9.
            Dtype::F64 => SortPayload::F64(data.into_iter().map(|x| x as f64).collect()),
        }
    }
}

/// Order-independent multiset fingerprint over canonical key bits — the
/// generic analog of [`validate::fingerprint_i64`]; identical results for
/// `i64` input.
///
/// [`validate::fingerprint_i64`]: crate::data::validate::fingerprint_i64
pub fn fingerprint_keys<K: SortKey>(data: &[K], threads: usize) -> Fingerprint {
    fingerprint_keys_on(exec::global(), data, threads)
}

/// [`fingerprint_keys`] on an explicit executor — the service passes its own
/// pool so validation sweeps never touch (or lazily construct) the global
/// one.
pub fn fingerprint_keys_on<K: SortKey>(on: &Executor, data: &[K], threads: usize) -> Fingerprint {
    let bounds = exec::partition_even(data.len(), threads.max(1));
    let parts = on.run_map(bounds.len(), |i| {
        let chunk = &data[bounds[i].clone()];
        let mut sum = 0u64;
        let mut xor = 0u64;
        let mut mix = 0u64;
        for &x in chunk {
            let u = x.canonical_bits();
            sum = sum.wrapping_add(u);
            xor ^= u;
            mix = mix.wrapping_add(mix64(u));
        }
        (sum, xor, mix)
    });
    let mut fp = Fingerprint { len: data.len(), sum: 0, xor: 0, mix: 0 };
    for (s, x, m) in parts {
        fp.sum = fp.sum.wrapping_add(s);
        fp.xor ^= x;
        fp.mix = fp.mix.wrapping_add(m);
    }
    fp
}

/// Parallel total-order sortedness check over any key dtype.
pub fn is_sorted_keys<K: SortKey>(data: &[K], threads: usize) -> bool {
    is_sorted_keys_on(exec::global(), data, threads)
}

/// [`is_sorted_keys`] on an explicit executor.
pub fn is_sorted_keys_on<K: SortKey>(on: &Executor, data: &[K], threads: usize) -> bool {
    if data.len() < 2 {
        return true;
    }
    let bounds = exec::partition_even(data.len(), threads.max(1));
    let oks = on.run_map(bounds.len(), |i| {
        let r = bounds[i].clone();
        // Include the seam with the previous chunk.
        let start = r.start.saturating_sub(1);
        data[start..r.end].windows(2).all(|w| K::key_cmp(&w[0], &w[1]) != Ordering::Greater)
    });
    oks.into_iter().all(|ok| ok)
}

/// Full generic validation: `output` must be totally-ordered and a bit-exact
/// permutation of whatever produced `input_fp` (fingerprint taken pre-sort).
/// The sortedness pass is the parallel [`is_sorted_keys`]; the violation
/// position is located sequentially only on the (rare) failure path.
pub fn validate_keys<K: SortKey>(input_fp: Fingerprint, output: &[K], threads: usize) -> Verdict {
    validate_keys_on(exec::global(), input_fp, output, threads)
}

/// [`validate_keys`] on an explicit executor.
pub fn validate_keys_on<K: SortKey>(
    on: &Executor,
    input_fp: Fingerprint,
    output: &[K],
    threads: usize,
) -> Verdict {
    if !is_sorted_keys_on(on, output, threads) {
        let pos = output
            .windows(2)
            .position(|w| K::key_cmp(&w[0], &w[1]) == Ordering::Greater)
            .unwrap_or(0);
        return Verdict::NotSorted { first_violation: pos };
    }
    if fingerprint_keys_on(on, output, threads) != input_fp {
        return Verdict::MultisetMismatch;
    }
    Verdict::Valid
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::validate;

    #[test]
    fn dtype_parse_roundtrip() {
        for &d in Dtype::all() {
            assert_eq!(Dtype::parse(d.name()), Some(d));
            assert_eq!(format!("{d}"), d.name());
        }
        assert_eq!(Dtype::parse("f32"), None);
    }

    #[test]
    fn to_order_i64_is_monotone_per_dtype() {
        let i64s = [i64::MIN, -5, 0, 5, i64::MAX];
        let u64s = [0u64, 1, 1 << 62, 1 << 63, u64::MAX];
        let f64s = [
            f64::NEG_INFINITY,
            -1.5,
            -0.0,
            0.0,
            f64::MIN_POSITIVE,
            2.5,
            f64::INFINITY,
            f64::NAN,
        ];
        assert!(i64s.windows(2).all(|w| w[0].to_order_i64() < w[1].to_order_i64()));
        assert!(u64s.windows(2).all(|w| w[0].to_order_i64() < w[1].to_order_i64()));
        assert!(f64s.windows(2).all(|w| w[0].to_order_i64() < w[1].to_order_i64()));
        // -NaN sits below everything in total order.
        assert!((-f64::NAN).to_order_i64() < f64::NEG_INFINITY.to_order_i64());
    }

    #[test]
    fn payload_roundtrip_and_mismatch() {
        let p = SortPayload::from_i64_values(vec![3, -1, 2], Dtype::F64);
        assert_eq!(p.dtype(), Dtype::F64);
        assert_eq!(p.len(), 3);
        assert!(p.as_slice::<i64>().is_none());
        assert_eq!(p.as_slice::<f64>(), Some(&[3.0, -1.0, 2.0][..]));
        let back = p.into_vec::<i64>();
        assert!(back.is_err());
        let v = back.unwrap_err().into_vec::<f64>().unwrap();
        assert_eq!(v, vec![3.0, -1.0, 2.0]);
    }

    #[test]
    fn from_i64_values_preserves_order_u64() {
        let src = vec![i64::MIN, -7, 0, 7, i64::MAX];
        let SortPayload::U64(u) = SortPayload::from_i64_values(src, Dtype::U64) else {
            panic!("expected u64 payload");
        };
        assert!(u.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(u[0], 0);
        assert_eq!(*u.last().unwrap(), u64::MAX);
    }

    #[test]
    fn scratch_arena_counts_grows_and_goes_flat() {
        let mut s = SortScratch::new();
        assert_eq!(s.grows(), 0);
        let _ = s.i64_for(10_000);
        assert_eq!(s.grows(), 1);
        let _ = s.i64_for(10_000);
        let _ = s.i64_for(5_000);
        assert_eq!(s.grows(), 1, "warm checkouts must not grow");
        let _ = s.u64_for(4_096);
        assert_eq!(s.grows(), 2, "each width grows once");
        let _ = s.i64_for(20_000);
        assert_eq!(s.grows(), 3, "a larger request grows again");
        assert!(s.i64_for(20_000).capacity() >= 20_000);
    }

    #[test]
    fn scratch_arena_releases_outlier_capacity() {
        let mut s = SortScratch::new();
        let _ = s.i64_for(1 << 20); // outlier job pins ~8 MB
        assert!(s.i64_for(0).capacity() >= 1 << 20);
        // The outlier sits in the first retention window (keep includes
        // it), so release happens at the second window boundary — two full
        // windows of small jobs guarantee it.
        for _ in 0..2 * SortScratch::TRIM_INTERVAL {
            let _ = s.i64_for(1024);
        }
        assert!(s.i64_for(0).capacity() < 1 << 20, "outlier capacity released");
        assert!(s.i64_for(1024).capacity() >= 1024, "window peak retained");
        // …while steady same-shape traffic never trims (no churn).
        let g = s.grows();
        for _ in 0..3 * SortScratch::TRIM_INTERVAL {
            let _ = s.i64_for(1024);
        }
        assert_eq!(s.grows(), g, "steady traffic stays allocation-free");
    }

    #[test]
    fn scratch_timer_split_borrow() {
        use crate::obs::Phase;
        let mut s = SortScratch::new();
        assert!(!s.timer_mut().is_enabled(), "timing is off by default");
        s.timer_mut().set_enabled(true);
        let (buf, timer) = s.i64_and_timer(100);
        assert!(buf.capacity() >= 100);
        timer.add(Phase::RadixScatter, 0.25);
        assert_eq!(s.timer_mut().drain(), vec![(Phase::RadixScatter, 0.25)]);
        // The split checkout still counts toward the grow/trim bookkeeping.
        assert_eq!(s.grows(), 1);
    }

    #[test]
    fn scratch_pad_checkout_three_ways() {
        let mut s = SortScratch::new();
        let (buf, pad, _timer) = s.i32_pad_and_timer(512);
        assert!(buf.capacity() >= 512);
        assert!(pad.capacity() >= 512);
        assert_eq!(s.grows(), 2, "merge scratch and pad each grow once");
        let _ = s.i32_pad_and_timer(512);
        assert_eq!(s.grows(), 2, "warm tile-path checkouts stay allocation-free");
    }

    #[test]
    fn generic_fingerprint_matches_i64_fingerprint() {
        let data = vec![5i64, -2, 9, 0, 5];
        assert_eq!(fingerprint_keys(&data, 2), validate::fingerprint_i64(&data, 2));
    }

    #[test]
    fn validate_keys_f64_with_specials() {
        let input =
            vec![3.5f64, f64::NAN, -f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -0.0, 0.0, -1.5];
        let fp = fingerprint_keys(&input, 2);
        let mut out = input.clone();
        out.sort_by(|a, b| a.total_cmp(b));
        assert_eq!(validate_keys(fp, &out, 2), Verdict::Valid);
        assert!(is_sorted_keys(&out, 2));
        // NaN-first is NOT sorted under total order (only -NaN is first).
        let mut bad = out.clone();
        bad.swap(0, 7);
        assert!(matches!(validate_keys(fp, &bad, 2), Verdict::NotSorted { .. }));
        // Dropping a NaN payload is a multiset mismatch even though the
        // remaining order is fine.
        let mut lost = out.clone();
        lost[7] = 3.5; // replace +NaN with a duplicate ordinary value
        lost.sort_by(|a, b| a.total_cmp(b));
        assert_eq!(validate_keys(fp, &lost, 2), Verdict::MultisetMismatch);
    }
}
