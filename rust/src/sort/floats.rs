//! Radix sorting for floating-point keys.
//!
//! NumPy's `np.sort` handles floats; EvoSort's radix path extends to them
//! through the classic monotone bit transform: for an IEEE-754 value with
//! bit pattern `b`,
//!
//! ```text
//! key(b) = !b          if sign bit set   (negatives reverse order)
//!        = b | SIGN    otherwise         (positives above negatives)
//! ```
//!
//! `key` is a strictly increasing map from the `total_cmp` order onto
//! unsigned integers (NaNs land at the extremes exactly as `total_cmp`
//! places them: -NaN first, +NaN last). The float slice is reinterpreted as
//! its integer bit patterns in place, transformed, sorted with the
//! block-based LSD radix sort, and transformed back — zero extra copies.

use super::radix::radix_sort_with_scratch;

#[inline]
fn f32_to_key(b: u32) -> u32 {
    if b & 0x8000_0000 != 0 {
        !b
    } else {
        b | 0x8000_0000
    }
}

#[inline]
fn f32_from_key(k: u32) -> u32 {
    if k & 0x8000_0000 != 0 {
        k & !0x8000_0000
    } else {
        !k
    }
}

/// Monotone map from `total_cmp` order onto unsigned order (see module
/// docs). Shared with the generic [`SortKey`](super::key::SortKey) layer:
/// the adaptive f64 path and the fingerprint projection both ride on it.
#[inline]
pub(crate) fn f64_to_key(b: u64) -> u64 {
    if b & 0x8000_0000_0000_0000 != 0 {
        !b
    } else {
        b | 0x8000_0000_0000_0000
    }
}

/// Inverse of [`f64_to_key`].
#[inline]
pub(crate) fn f64_from_key(k: u64) -> u64 {
    if k & 0x8000_0000_0000_0000 != 0 {
        k & !0x8000_0000_0000_0000
    } else {
        !k
    }
}

/// Sort f32s into `total_cmp` order with the parallel LSD radix sort.
pub fn radix_sort_f32(data: &mut [f32], threads: usize) {
    debug_assert_eq!(std::mem::size_of::<f32>(), std::mem::size_of::<u32>());
    debug_assert_eq!(std::mem::align_of::<f32>(), std::mem::align_of::<u32>());
    debug_assert_eq!(data.as_ptr() as usize % std::mem::align_of::<u32>(), 0);
    // SAFETY: f32 and u32 have identical size/alignment and every bit
    // pattern is valid for both (guarded above in debug builds). The
    // transforms below are inverse bijections, so the slice always holds
    // valid patterns.
    let bits: &mut [u32] =
        unsafe { std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u32, data.len()) };
    crate::exec::parallel_for_chunks(bits, threads, |_, chunk| {
        for b in chunk.iter_mut() {
            *b = f32_to_key(*b);
        }
    });
    radix_sort_with_scratch(bits, threads, &mut Vec::new());
    crate::exec::parallel_for_chunks(bits, threads, |_, chunk| {
        for b in chunk.iter_mut() {
            *b = f32_from_key(*b);
        }
    });
}

/// Sort f64s into `total_cmp` order with the parallel LSD radix sort.
pub fn radix_sort_f64(data: &mut [f64], threads: usize) {
    debug_assert_eq!(std::mem::size_of::<f64>(), std::mem::size_of::<u64>());
    debug_assert_eq!(std::mem::align_of::<f64>(), std::mem::align_of::<u64>());
    debug_assert_eq!(data.as_ptr() as usize % std::mem::align_of::<u64>(), 0);
    // SAFETY: as above, for f64/u64.
    let bits: &mut [u64] =
        unsafe { std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u64, data.len()) };
    crate::exec::parallel_for_chunks(bits, threads, |_, chunk| {
        for b in chunk.iter_mut() {
            *b = f64_to_key(*b);
        }
    });
    radix_sort_with_scratch(bits, threads, &mut Vec::new());
    crate::exec::parallel_for_chunks(bits, threads, |_, chunk| {
        for b in chunk.iter_mut() {
            *b = f64_from_key(*b);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn check_f64(data: &[f64]) {
        let mut got = data.to_vec();
        radix_sort_f64(&mut got, 3);
        let mut expect = data.to_vec();
        expect.sort_by(|a, b| a.total_cmp(b));
        // Bit-exact comparison (total_cmp distinguishes -0.0/0.0 and NaN payloads).
        let gb: Vec<u64> = got.iter().map(|x| x.to_bits()).collect();
        let eb: Vec<u64> = expect.iter().map(|x| x.to_bits()).collect();
        assert_eq!(gb, eb);
    }

    fn check_f32(data: &[f32]) {
        let mut got = data.to_vec();
        radix_sort_f32(&mut got, 3);
        let mut expect = data.to_vec();
        expect.sort_by(|a, b| a.total_cmp(b));
        let gb: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
        let eb: Vec<u32> = expect.iter().map(|x| x.to_bits()).collect();
        assert_eq!(gb, eb);
    }

    #[test]
    fn key_transform_is_monotone_f64() {
        let vals = [
            f64::NEG_INFINITY,
            -1e300,
            -2.5,
            -0.0,
            0.0,
            1e-300,
            2.5,
            1e300,
            f64::INFINITY,
        ];
        let keys: Vec<u64> = vals.iter().map(|v| f64_to_key(v.to_bits())).collect();
        for w in keys.windows(2) {
            assert!(w[0] < w[1], "keys must be strictly increasing");
        }
        // Round trip.
        for v in vals {
            assert_eq!(f64_from_key(f64_to_key(v.to_bits())), v.to_bits());
        }
    }

    #[test]
    fn sorts_specials() {
        check_f64(&[
            3.5,
            f64::NAN,
            -f64::NAN,
            f64::INFINITY,
            f64::NEG_INFINITY,
            -0.0,
            0.0,
            -1.5,
        ]);
        check_f32(&[1.0, -1.0, f32::NAN, 0.0, -0.0, f32::MIN, f32::MAX]);
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn sorts_random_f64() {
        let mut rng = Xoshiro256pp::seeded(404);
        let data: Vec<f64> = (0..50_000)
            .map(|_| (rng.next_f64() - 0.5) * 1e12)
            .collect();
        check_f64(&data);
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn sorts_random_f32() {
        let mut rng = Xoshiro256pp::seeded(405);
        let data: Vec<f32> = (0..50_000)
            .map(|_| ((rng.next_f64() - 0.5) * 1e6) as f32)
            .collect();
        check_f32(&data);
    }

    #[test]
    fn subnormals_and_edges() {
        check_f64(&[f64::MIN_POSITIVE / 2.0, -f64::MIN_POSITIVE / 2.0, f64::EPSILON, 0.0]);
    }

    #[test]
    fn empty_and_single() {
        check_f64(&[]);
        check_f64(&[42.0]);
        check_f32(&[]);
    }
}
