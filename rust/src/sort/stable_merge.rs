//! Sequential stable bottom-up mergesort — the NumPy `np.sort(kind='mergesort')`
//! baseline: single-threaded, O(n) scratch, stable, insertion-sorted base
//! runs of 32 elements (matching the classic library implementation shape).

use super::insertion::insertion_sort;
use super::merge::merge_into;

const RUN: usize = 32;

/// Sort in place with a sequential stable mergesort (baseline).
pub fn stable_merge_sort<T: Copy + Ord + Default>(a: &mut [T]) {
    let n = a.len();
    if n <= 1 {
        return;
    }
    if n <= RUN {
        insertion_sort(a);
        return;
    }
    // Base runs.
    let mut lo = 0;
    while lo < n {
        let hi = (lo + RUN).min(n);
        insertion_sort(&mut a[lo..hi]);
        lo = hi;
    }
    // Bottom-up merging, ping-pong with one scratch buffer.
    let mut scratch: Vec<T> = vec![T::default(); n];
    let mut in_a = true;
    let mut width = RUN;
    while width < n {
        {
            let (src, dst): (&[T], &mut [T]) =
                if in_a { (&*a, &mut scratch[..]) } else { (&scratch[..], &mut *a) };
            let mut lo = 0;
            while lo < n {
                let mid = (lo + width).min(n);
                let hi = (lo + 2 * width).min(n);
                merge_into(&src[lo..mid], &src[mid..hi], &mut dst[lo..hi]);
                lo = hi;
            }
        }
        in_a = !in_a;
        width *= 2;
    }
    if !in_a {
        a.copy_from_slice(&scratch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_i64, Distribution};

    fn check(data: &[i64]) {
        let mut got = data.to_vec();
        stable_merge_sort(&mut got);
        let mut expect = data.to_vec();
        expect.sort(); // std stable sort as oracle
        assert_eq!(got, expect);
    }

    #[test]
    fn edge_cases() {
        check(&[]);
        check(&[7]);
        check(&[2, 1]);
        check(&[3, 3, 3]);
    }

    #[test]
    fn random_inputs() {
        for n in [31usize, 32, 33, 1000, 10_000, 65_537] {
            check(&generate_i64(n, Distribution::Uniform, 71, 1));
        }
    }

    #[test]
    fn adversarial_inputs() {
        for dist in [
            Distribution::Sorted,
            Distribution::Reverse,
            Distribution::OrganPipe,
            Distribution::FewUnique,
        ] {
            check(&generate_i64(5000, dist, 73, 1));
        }
    }

    #[test]
    fn stability() {
        #[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
        struct KV(i32, i32);
        impl PartialOrd for KV {
            fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
                Some(self.cmp(o))
            }
        }
        impl Ord for KV {
            fn cmp(&self, o: &Self) -> std::cmp::Ordering {
                self.0.cmp(&o.0) // key only
            }
        }
        // 200 elements, 4 distinct keys, tags record input order.
        let mut xs: Vec<KV> = (0..200).map(|i| KV(i % 4, i)).collect();
        stable_merge_sort(&mut xs);
        for w in xs.windows(2) {
            assert!(w[0].0 <= w[1].0);
            if w[0].0 == w[1].0 {
                assert!(w[0].1 < w[1].1, "stability violated: {w:?}");
            }
        }
    }
}
