//! Adaptive Partition Sort — Algorithm 6 of the paper.
//!
//! Dispatch on the tuned parameters:
//! * `|A| < T_numpy`  → the tuned library routine (`slice::sort_unstable`,
//!   the `np.sort` analog);
//! * `A_code = 4` and integer dtype → block-based LSD radix sort;
//! * `A_code = 5` and an XLA backend is attached → Pallas bitonic tile sort
//!   via PJRT, runs merged in rust (this reproduction's L1/L2 integration);
//! * otherwise → refined parallel mergesort.

use std::sync::Arc;

use super::parallel_merge::{merge_runs_bottom_up, parallel_merge_sort_timed, MergeTuning};
use super::radix::{radix_sort_timed, radix_sort_with_executor, RadixKey};
use super::samplesort::{sample_sort_timed, SampleSortTuning};
use crate::exec::{self, Executor};
use crate::obs::PhaseTimer;
use crate::params::{ACode, SortParams};

/// Sort backend exporting "sort each fixed-size tile" — implemented by the
/// PJRT runtime over the Pallas bitonic artifact (see `runtime::xla_sort`).
pub trait TileSorter: Send + Sync {
    /// Tile width the backend was compiled for (power of two).
    fn tile_size(&self) -> usize;
    /// Sort each consecutive `tile_size()` chunk of `data` independently.
    /// `data.len()` must be a multiple of `tile_size()`.
    fn sort_tiles_i32(&self, data: &mut [i32]) -> anyhow::Result<()>;
}

/// The adaptive sorter: owns thread budget, executor, scratch reuse and the
/// optional XLA tile backend. Every kernel it dispatches runs its fork-join
/// sections on the sorter's [`Executor`] — the process-wide parked pool by
/// default, a deployment-owned pool when the sort service builds one.
pub struct AdaptiveSorter {
    threads: usize,
    /// `None` means "the process-wide executor", resolved lazily at
    /// dispatch so merely constructing a sorter (e.g. as a builder input
    /// that gets `with_executor`'d) never spins up the global pool.
    exec: Option<Arc<Executor>>,
    xla: Option<std::sync::Arc<dyn TileSorter>>,
}

impl AdaptiveSorter {
    pub fn new(threads: usize) -> Self {
        AdaptiveSorter { threads: threads.max(1), exec: None, xla: None }
    }

    pub fn with_xla(mut self, backend: std::sync::Arc<dyn TileSorter>) -> Self {
        self.xla = Some(backend);
        self
    }

    /// Replace the fork-join executor all dispatched kernels run on.
    pub fn with_executor(mut self, exec: Arc<Executor>) -> Self {
        self.exec = Some(exec);
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The executor the kernels run on (the process-wide one unless
    /// [`with_executor`](Self::with_executor) replaced it).
    pub fn executor(&self) -> &Arc<Executor> {
        self.exec.as_ref().unwrap_or_else(|| exec::global())
    }

    /// Rebuild with a new thread budget, preserving the executor and any
    /// attached XLA backend.
    pub fn rebudget(self, threads: usize) -> AdaptiveSorter {
        AdaptiveSorter { threads: threads.max(1), exec: self.exec, xla: self.xla }
    }

    pub fn has_xla(&self) -> bool {
        self.xla.is_some()
    }

    fn merge_tuning(&self, p: &SortParams) -> MergeTuning {
        MergeTuning {
            insertion_threshold: p.insertion_threshold,
            parallel_merge_threshold: p.parallel_merge_threshold,
            tile: p.tile,
            threads: self.threads,
            exec: Arc::clone(self.executor()),
        }
    }

    /// Algorithm 6 for i64 keys.
    pub fn sort_i64(&self, data: &mut [i64], p: &SortParams) {
        self.sort_i64_with_scratch(data, p, &mut Vec::new());
    }

    /// Scratch-reusing variant (hot path for the service/benches).
    pub fn sort_i64_with_scratch(
        &self,
        data: &mut [i64],
        p: &SortParams,
        scratch: &mut Vec<i64>,
    ) {
        self.sort_i64_timed(data, p, scratch, &mut PhaseTimer::disabled())
    }

    /// [`sort_i64_with_scratch`](Self::sort_i64_with_scratch) with the
    /// dispatched kernel accumulating per-phase durations into `timer` (the
    /// traced service enables the timer on its worker scratch and drains it
    /// into span events after each job). Disabled-timer calls compile to the
    /// untimed path.
    pub fn sort_i64_timed(
        &self,
        data: &mut [i64],
        p: &SortParams,
        scratch: &mut Vec<i64>,
        timer: &mut PhaseTimer,
    ) {
        if data.len() < p.fallback_threshold {
            data.sort_unstable(); // the library fallback (T_numpy branch)
            return;
        }
        match p.algorithm {
            ACode::Radix => {
                radix_sort_timed(data, self.threads, p.radix_width, scratch, self.executor(), timer)
            }
            ACode::Sample => {
                let tuning = SampleSortTuning::for_threads(self.threads);
                sample_sort_timed(data, &tuning, self.executor(), scratch, timer)
            }
            // No 64-bit bitonic artifact is compiled; Algorithm 6's
            // "other cases" branch applies.
            ACode::Merge | ACode::XlaTile => {
                parallel_merge_sort_timed(data, &self.merge_tuning(p), scratch, timer)
            }
        }
    }

    /// Algorithm 6 for i32 keys (the dtype the XLA tile backend serves).
    pub fn sort_i32(&self, data: &mut [i32], p: &SortParams) {
        self.sort_i32_with_scratch(data, p, &mut Vec::new());
    }

    pub fn sort_i32_with_scratch(
        &self,
        data: &mut [i32],
        p: &SortParams,
        scratch: &mut Vec<i32>,
    ) {
        self.sort_i32_timed(data, p, scratch, &mut PhaseTimer::disabled())
    }

    /// Timed variant; see [`sort_i64_timed`](Self::sort_i64_timed). The XLA
    /// tile path (backend attached) is not phase-instrumented — its cost
    /// structure lives in PJRT, outside the rust kernels. This entry
    /// allocates a fresh sentinel-padding buffer when the XLA branch is
    /// taken; arena callers use
    /// [`sort_i32_timed_padded`](Self::sort_i32_timed_padded) instead.
    pub fn sort_i32_timed(
        &self,
        data: &mut [i32],
        p: &SortParams,
        scratch: &mut Vec<i32>,
        timer: &mut PhaseTimer,
    ) {
        self.sort_i32_timed_padded(data, p, scratch, &mut Vec::new(), timer)
    }

    /// [`sort_i32_timed`](Self::sort_i32_timed) with an explicit reusable
    /// buffer for the XLA tile path's sentinel-padded copy (checked out of
    /// [`SortScratch`](super::key::SortScratch) by the service workers, so
    /// the tile branch is allocation-free at steady state like every other
    /// kernel). `pad` is untouched by the non-XLA branches.
    pub fn sort_i32_timed_padded(
        &self,
        data: &mut [i32],
        p: &SortParams,
        scratch: &mut Vec<i32>,
        pad: &mut Vec<i32>,
        timer: &mut PhaseTimer,
    ) {
        if data.len() < p.fallback_threshold {
            data.sort_unstable();
            return;
        }
        match p.algorithm {
            ACode::Radix => {
                radix_sort_timed(data, self.threads, p.radix_width, scratch, self.executor(), timer)
            }
            ACode::Sample => {
                let tuning = SampleSortTuning::for_threads(self.threads);
                sample_sort_timed(data, &tuning, self.executor(), scratch, timer)
            }
            ACode::XlaTile => match &self.xla {
                Some(backend) => {
                    if let Err(e) = self.sort_i32_via_xla(data, p, backend.as_ref(), scratch, pad)
                    {
                        crate::log_warn!("xla tile sort failed ({e}); merge fallback");
                        parallel_merge_sort_timed(data, &self.merge_tuning(p), scratch, timer);
                    }
                }
                None => parallel_merge_sort_timed(data, &self.merge_tuning(p), scratch, timer),
            },
            ACode::Merge => {
                parallel_merge_sort_timed(data, &self.merge_tuning(p), scratch, timer)
            }
        }
    }

    /// XLA path: pad to a whole number of tiles with i32::MAX sentinels into
    /// the reusable `pad` buffer, let the PJRT executable (Pallas bitonic
    /// kernel) sort every tile, then merge the sorted runs bottom-up in rust
    /// (through the caller's scratch) and drop the padding.
    fn sort_i32_via_xla(
        &self,
        data: &mut [i32],
        p: &SortParams,
        backend: &dyn TileSorter,
        scratch: &mut Vec<i32>,
        pad: &mut Vec<i32>,
    ) -> anyhow::Result<()> {
        let tile = backend.tile_size();
        let n = data.len();
        let padded_len = n.div_ceil(tile) * tile;
        pad.clear();
        pad.reserve(padded_len);
        pad.extend_from_slice(data);
        pad.resize(padded_len, i32::MAX);
        backend.sort_tiles_i32(pad)?;
        merge_runs_bottom_up(pad, tile, &self.merge_tuning(p), scratch);
        // Sentinels are MAX; originals containing MAX sort equal to the
        // sentinels, so the first n elements are exactly the sorted input.
        data.copy_from_slice(&pad[..n]);
        Ok(())
    }

    /// Algorithm 6 for u64 keys (same dispatch shape as i64: the radix sort
    /// runs with a zero sign mask, merge/sample compare in unsigned order).
    pub fn sort_u64_with_scratch(
        &self,
        data: &mut [u64],
        p: &SortParams,
        scratch: &mut Vec<u64>,
    ) {
        self.sort_u64_timed(data, p, scratch, &mut PhaseTimer::disabled())
    }

    /// Timed variant; see [`sort_i64_timed`](Self::sort_i64_timed).
    pub fn sort_u64_timed(
        &self,
        data: &mut [u64],
        p: &SortParams,
        scratch: &mut Vec<u64>,
        timer: &mut PhaseTimer,
    ) {
        if data.len() < p.fallback_threshold {
            data.sort_unstable();
            return;
        }
        match p.algorithm {
            ACode::Radix => {
                radix_sort_timed(data, self.threads, p.radix_width, scratch, self.executor(), timer)
            }
            ACode::Sample => {
                let tuning = SampleSortTuning::for_threads(self.threads);
                sample_sort_timed(data, &tuning, self.executor(), scratch, timer)
            }
            // No 64-bit bitonic artifact is compiled; "other cases" branch.
            ACode::Merge | ACode::XlaTile => {
                parallel_merge_sort_timed(data, &self.merge_tuning(p), scratch, timer)
            }
        }
    }

    pub fn sort_u64(&self, data: &mut [u64], p: &SortParams) {
        self.sort_u64_with_scratch(data, p, &mut Vec::new());
    }

    /// Algorithm 6 for f64 keys in IEEE-754 `total_cmp` order: the slice is
    /// reinterpreted as bits, transformed with the monotone total-order map
    /// (`sort::floats`), dispatched through the u64 path — every branch of
    /// which respects unsigned order — and transformed back in place.
    pub fn sort_f64_with_scratch(
        &self,
        data: &mut [f64],
        p: &SortParams,
        scratch: &mut Vec<u64>,
    ) {
        self.sort_f64_timed(data, p, scratch, &mut PhaseTimer::disabled())
    }

    /// Timed variant; see [`sort_i64_timed`](Self::sort_i64_timed). The
    /// bit transforms themselves are untimed (they are not a kernel phase);
    /// the u64 dispatch between them reports as usual.
    pub fn sort_f64_timed(
        &self,
        data: &mut [f64],
        p: &SortParams,
        scratch: &mut Vec<u64>,
        timer: &mut PhaseTimer,
    ) {
        debug_assert_eq!(std::mem::size_of::<f64>(), std::mem::size_of::<u64>());
        debug_assert_eq!(std::mem::align_of::<f64>(), std::mem::align_of::<u64>());
        debug_assert_eq!(data.as_ptr() as usize % std::mem::align_of::<u64>(), 0);
        // SAFETY: f64 and u64 have identical size/alignment and every bit
        // pattern is valid for both (guarded above in debug builds). The
        // transforms are inverse bijections, so the slice always holds valid
        // patterns.
        let bits: &mut [u64] =
            unsafe { std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u64, data.len()) };
        self.executor().run_chunks(bits, self.threads, |_, chunk| {
            for b in chunk.iter_mut() {
                *b = super::floats::f64_to_key(*b);
            }
        });
        self.sort_u64_timed(bits, p, scratch, timer);
        self.executor().run_chunks(bits, self.threads, |_, chunk| {
            for b in chunk.iter_mut() {
                *b = super::floats::f64_from_key(*b);
            }
        });
    }

    pub fn sort_f64(&self, data: &mut [f64], p: &SortParams) {
        self.sort_f64_with_scratch(data, p, &mut Vec::new());
    }

    /// Generic radix entry for other key widths (u32/u64) — not part of
    /// Algorithm 6 but exposed for library users.
    pub fn sort_radix<T: RadixKey>(&self, data: &mut [T]) {
        radix_sort_with_executor(data, self.threads, &mut Vec::new(), self.executor());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_i32, generate_i64, Distribution};
    use crate::params::{ACode, SortParams};

    fn sorter() -> AdaptiveSorter {
        AdaptiveSorter::new(4)
    }

    fn check_i64(data: &[i64], p: &SortParams) {
        let mut got = data.to_vec();
        sorter().sort_i64(&mut got, p);
        let mut expect = data.to_vec();
        expect.sort_unstable();
        assert_eq!(got, expect);
    }

    #[test]
    fn fallback_branch_small_arrays() {
        let p = SortParams { fallback_threshold: 1000, ..SortParams::default() };
        let data = generate_i64(999, Distribution::Uniform, 81, 2);
        check_i64(&data, &p);
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn radix_branch() {
        let p = SortParams { algorithm: ACode::Radix, fallback_threshold: 100, ..Default::default() };
        check_i64(&generate_i64(20_000, Distribution::Uniform, 83, 2), &p);
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn merge_branch() {
        let p = SortParams { algorithm: ACode::Merge, fallback_threshold: 100, ..Default::default() };
        check_i64(&generate_i64(20_000, Distribution::Uniform, 85, 2), &p);
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn xla_code_without_backend_uses_merge() {
        let p = SortParams { algorithm: ACode::XlaTile, fallback_threshold: 100, ..Default::default() };
        check_i64(&generate_i64(10_000, Distribution::Uniform, 87, 2), &p);
        let mut d32 = generate_i32(10_000, Distribution::Uniform, 88, 2);
        let mut expect = d32.clone();
        expect.sort_unstable();
        sorter().sort_i32(&mut d32, &p);
        assert_eq!(d32, expect);
    }

    /// A fake tile backend (sorts tiles with std) exercising the padding and
    /// run-merging logic without PJRT.
    struct FakeTileSorter(usize);
    impl TileSorter for FakeTileSorter {
        fn tile_size(&self) -> usize {
            self.0
        }
        fn sort_tiles_i32(&self, data: &mut [i32]) -> anyhow::Result<()> {
            assert_eq!(data.len() % self.0, 0);
            for tile in data.chunks_mut(self.0) {
                tile.sort_unstable();
            }
            Ok(())
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn xla_tile_path_with_fake_backend() {
        let s = AdaptiveSorter::new(4).with_xla(std::sync::Arc::new(FakeTileSorter(256)));
        assert!(s.has_xla());
        let p = SortParams { algorithm: ACode::XlaTile, fallback_threshold: 10, ..Default::default() };
        // Non-multiple-of-tile length exercises sentinel padding; data
        // containing i32::MAX exercises sentinel collision.
        let mut data = generate_i32(10_000 + 37, Distribution::Uniform, 89, 2);
        data[5] = i32::MAX;
        data[100] = i32::MAX;
        let mut expect = data.clone();
        expect.sort_unstable();
        s.sort_i32(&mut data, &p);
        assert_eq!(data, expect);
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn explicit_executor_preserved_across_rebudget() {
        let exec = Arc::new(Executor::new(3));
        let s = AdaptiveSorter::new(2).with_executor(Arc::clone(&exec)).rebudget(4);
        assert_eq!(s.threads(), 4);
        assert!(Arc::ptr_eq(s.executor(), &exec), "rebudget must keep the executor");
        let mut scratch = Vec::new();
        for algo in [ACode::Radix, ACode::Merge, ACode::Sample] {
            let p = SortParams { algorithm: algo, fallback_threshold: 100, ..Default::default() };
            let mut data = generate_i64(20_000, Distribution::Zipf, 90, 2);
            let mut expect = data.clone();
            expect.sort_unstable();
            s.sort_i64_with_scratch(&mut data, &p, &mut scratch);
            assert_eq!(data, expect, "{algo:?}");
        }
        assert_eq!(exec.spawn_count(), 2, "all three kernels ran on the parked pool");
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn paper_configs_sort_correctly() {
        for p in [SortParams::paper_1e7(), SortParams::paper_5e8()] {
            check_i64(&generate_i64(50_000, Distribution::Uniform, 91, 4), &p);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn u64_dispatch_all_branches() {
        let base: Vec<u64> = generate_i64(20_000, Distribution::Uniform, 94, 2)
            .iter()
            .map(|&x| x.wrapping_sub(i64::MIN) as u64)
            .collect();
        let mut expect = base.clone();
        expect.sort_unstable();
        for algo in [ACode::Radix, ACode::Merge, ACode::Sample, ACode::XlaTile] {
            let p = SortParams { algorithm: algo, fallback_threshold: 100, ..Default::default() };
            let mut got = base.clone();
            sorter().sort_u64(&mut got, &p);
            assert_eq!(got, expect, "{algo:?}");
        }
        // Fallback branch.
        let p = SortParams { fallback_threshold: usize::MAX, ..Default::default() };
        let mut got = base.clone();
        sorter().sort_u64(&mut got, &p);
        assert_eq!(got, expect);
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn f64_dispatch_total_order_with_specials() {
        let mut base: Vec<f64> = generate_i64(20_000, Distribution::Gaussian, 96, 2)
            .iter()
            .map(|&x| x as f64 / 3.0)
            .collect();
        base[7] = f64::NAN;
        base[19] = -f64::NAN;
        base[101] = f64::INFINITY;
        base[202] = f64::NEG_INFINITY;
        base[303] = -0.0;
        base[404] = 0.0;
        let mut expect = base.clone();
        expect.sort_by(|a, b| a.total_cmp(b));
        let expect_bits: Vec<u64> = expect.iter().map(|x| x.to_bits()).collect();
        for algo in [ACode::Radix, ACode::Merge, ACode::Sample] {
            let p = SortParams { algorithm: algo, fallback_threshold: 100, ..Default::default() };
            let mut got = base.clone();
            sorter().sort_f64(&mut got, &p);
            let got_bits: Vec<u64> = got.iter().map(|x| x.to_bits()).collect();
            assert_eq!(got_bits, expect_bits, "{algo:?}");
        }
    }

    #[test]
    fn generic_radix_u64() {
        let mut data: Vec<u64> =
            generate_i64(5_000, Distribution::Uniform, 93, 2).iter().map(|&x| x as u64).collect();
        let mut expect = data.clone();
        expect.sort_unstable();
        sorter().sort_radix(&mut data);
        assert_eq!(data, expect);
    }
}
