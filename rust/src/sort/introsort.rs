//! Sequential introsort — the NumPy `np.sort(kind='quicksort')` baseline.
//!
//! NumPy's "quicksort" is in fact introsort: median-of-3 quicksort with a
//! depth limit of 2·log2(n) falling back to heapsort, and insertion sort
//! below a small cutoff — exactly what we implement here, from scratch, so
//! the paper's baseline comparison is against the same algorithm class it
//! used. Deliberately single-threaded, like `np.sort`.

use super::insertion::insertion_sort;

const SMALL: usize = 16;

/// Sort in place with introsort (single-threaded baseline).
pub fn introsort<T: Copy + Ord>(a: &mut [T]) {
    let n = a.len();
    if n <= 1 {
        return;
    }
    let depth_limit = 2 * (usize::BITS - n.leading_zeros()) as usize;
    introsort_rec(a, depth_limit);
    insertion_sort(a); // final pass over nearly-sorted blocks
}

fn introsort_rec<T: Copy + Ord>(a: &mut [T], depth: usize) {
    let mut slice = a;
    let mut depth = depth;
    // Tail-recursion elimination on the larger side.
    while slice.len() > SMALL {
        if depth == 0 {
            heapsort(slice);
            return;
        }
        depth -= 1;
        let p = partition(slice);
        let (lo, hi) = slice.split_at_mut(p);
        let hi = &mut hi[1..]; // pivot in final place
        if lo.len() < hi.len() {
            introsort_rec(lo, depth);
            slice = hi;
        } else {
            introsort_rec(hi, depth);
            slice = lo;
        }
    }
    // Leave blocks <= SMALL for the final insertion pass.
}

/// Hoare-style partition with median-of-3 pivot; returns the pivot's final
/// index. The pivot element ends at that index.
fn partition<T: Copy + Ord>(a: &mut [T]) -> usize {
    let n = a.len();
    let mid = n / 2;
    // Median-of-3: order a[0], a[mid], a[n-1].
    if a[mid] < a[0] {
        a.swap(mid, 0);
    }
    if a[n - 1] < a[0] {
        a.swap(n - 1, 0);
    }
    if a[n - 1] < a[mid] {
        a.swap(n - 1, mid);
    }
    // Median now at mid; park it at n-2 (Lomuto-ish guarded Hoare).
    a.swap(mid, n - 2);
    let pivot = a[n - 2];
    let (mut i, mut j) = (0usize, n - 2);
    loop {
        i += 1;
        while a[i] < pivot {
            i += 1;
        }
        j -= 1;
        while a[j] > pivot {
            j -= 1;
        }
        if i >= j {
            break;
        }
        a.swap(i, j);
    }
    a.swap(i, n - 2);
    i
}

/// Bottom-up binary heapsort (introsort's depth-limit fallback).
pub fn heapsort<T: Copy + Ord>(a: &mut [T]) {
    let n = a.len();
    if n <= 1 {
        return;
    }
    // Heapify.
    for i in (0..n / 2).rev() {
        sift_down(a, i, n);
    }
    // Extract.
    for end in (1..n).rev() {
        a.swap(0, end);
        sift_down(a, 0, end);
    }
}

fn sift_down<T: Copy + Ord>(a: &mut [T], mut root: usize, end: usize) {
    loop {
        let mut child = 2 * root + 1;
        if child >= end {
            return;
        }
        if child + 1 < end && a[child] < a[child + 1] {
            child += 1;
        }
        if a[root] >= a[child] {
            return;
        }
        a.swap(root, child);
        root = child;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_i64, Distribution};

    fn check(data: &[i64]) {
        let mut got = data.to_vec();
        introsort(&mut got);
        let mut expect = data.to_vec();
        expect.sort_unstable();
        assert_eq!(got, expect);
    }

    #[test]
    fn edge_cases() {
        check(&[]);
        check(&[1]);
        check(&[2, 1]);
        check(&[1, 1, 1, 1, 1]);
        check(&[i64::MIN, i64::MAX, 0]);
    }

    #[test]
    fn random_and_adversarial() {
        for dist in [
            Distribution::Uniform,
            Distribution::Sorted,
            Distribution::Reverse,
            Distribution::FewUnique,
            Distribution::OrganPipe,
            Distribution::Constant,
            Distribution::Zipf,
        ] {
            let data = generate_i64(25_000, dist, 61, 2);
            check(&data);
        }
    }

    #[test]
    fn odd_sizes() {
        for n in [2usize, 3, 15, 16, 17, 1000, 4099] {
            check(&generate_i64(n, Distribution::Uniform, 63, 1));
        }
    }

    #[test]
    fn heapsort_standalone() {
        let data = generate_i64(10_000, Distribution::Uniform, 65, 1);
        let mut got = data.clone();
        heapsort(&mut got);
        let mut expect = data;
        expect.sort_unstable();
        assert_eq!(got, expect);
    }

    #[test]
    fn heapsort_edges() {
        let mut a: Vec<i64> = vec![];
        heapsort(&mut a);
        let mut b = vec![5i64];
        heapsort(&mut b);
        assert_eq!(b, vec![5]);
        let mut c = vec![2i64, 1];
        heapsort(&mut c);
        assert_eq!(c, vec![1, 2]);
    }
}
