//! Merge kernels: the `MergeStandardOpt` routine of Algorithm 3 and its
//! building blocks.
//!
//! * [`merge_into`] — branch-light sequential two-way merge.
//! * [`merge_tiled_into`] — the paper's *block-based* merge: the output is
//!   produced in tiles of `T_tile` elements so the working set of each step
//!   stays cache-resident (§6.2 "the tile size ... optimizes cache usage in
//!   merges").
//! * [`gallop_right`] / [`gallop_left`] — exponential search used both by the
//!   merge fast path (long runs from one side) and by [`merge_path_split`].
//! * [`merge_path_split`] — splits one big merge into `k` independent
//!   sub-merges of near-equal output size (the parallel merge used once runs
//!   outgrow `T_merge`).

/// Sequential stable merge of two sorted runs into `dst`.
/// `dst.len()` must equal `a.len() + b.len()`.
pub fn merge_into<T: Copy + Ord>(a: &[T], b: &[T], dst: &mut [T]) {
    debug_assert_eq!(a.len() + b.len(), dst.len());
    let (mut i, mut j) = (0usize, 0usize);
    let mut k = 0usize;
    // Main loop: both runs non-empty.
    while i < a.len() && j < b.len() {
        // `<=` keeps stability (a wins ties).
        let take_a = a[i] <= b[j];
        dst[k] = if take_a { a[i] } else { b[j] };
        i += usize::from(take_a);
        j += usize::from(!take_a);
        k += 1;
    }
    if i < a.len() {
        dst[k..].copy_from_slice(&a[i..]);
    } else {
        dst[k..].copy_from_slice(&b[j..]);
    }
}

/// Find the number of elements in sorted `run` that are `< key`
/// (lower bound) via exponential (galloping) search from the left.
pub fn gallop_left<T: Copy + Ord>(run: &[T], key: T) -> usize {
    // Exponential probe.
    let mut hi = 1usize;
    while hi < run.len() && run[hi - 1] < key {
        hi = (hi * 2).min(run.len() + 1);
    }
    let lo = hi / 2;
    let hi = hi.min(run.len());
    lo + run[lo..hi].partition_point(|x| *x < key)
}

/// Number of elements in sorted `run` that are `<= key` (upper bound).
pub fn gallop_right<T: Copy + Ord>(run: &[T], key: T) -> usize {
    let mut hi = 1usize;
    while hi < run.len() && run[hi - 1] <= key {
        hi = (hi * 2).min(run.len() + 1);
    }
    let lo = hi / 2;
    let hi = hi.min(run.len());
    lo + run[lo..hi].partition_point(|x| *x <= key)
}

/// Galloping merge: like [`merge_into`] but when one side wins repeatedly it
/// switches to exponential search + bulk copy. Big win on runs with little
/// interleaving (nearly-sorted data, concatenated sorted blocks).
pub fn merge_gallop_into<T: Copy + Ord>(a: &[T], b: &[T], dst: &mut [T]) {
    const MIN_GALLOP: usize = 7;
    debug_assert_eq!(a.len() + b.len(), dst.len());
    let (mut i, mut j, mut k) = (0usize, 0usize, 0usize);
    let (mut wins_a, mut wins_b) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        if a[i] <= b[j] {
            dst[k] = a[i];
            i += 1;
            k += 1;
            wins_a += 1;
            wins_b = 0;
            if wins_a >= MIN_GALLOP && i < a.len() {
                // Copy the whole prefix of `a` that precedes b[j].
                let take = gallop_right(&a[i..], b[j]);
                dst[k..k + take].copy_from_slice(&a[i..i + take]);
                i += take;
                k += take;
                wins_a = 0;
            }
        } else {
            dst[k] = b[j];
            j += 1;
            k += 1;
            wins_b += 1;
            wins_a = 0;
            if wins_b >= MIN_GALLOP && j < b.len() {
                let take = gallop_left(&b[j..], a[i]);
                dst[k..k + take].copy_from_slice(&b[j..j + take]);
                j += take;
                k += take;
                wins_b = 0;
            }
        }
    }
    if i < a.len() {
        dst[k..].copy_from_slice(&a[i..]);
    } else {
        dst[k..].copy_from_slice(&b[j..]);
    }
}

/// Block-based merge: emits the output in tiles of at most `tile` elements.
/// Each tile's sources are located with one merge-path split, then produced
/// with the branch-light kernel — bounding the live working set to ~3 tiles,
/// which is the cache-blocking effect the paper tunes `T_tile` for.
pub fn merge_tiled_into<T: Copy + Ord>(a: &[T], b: &[T], dst: &mut [T], tile: usize) {
    debug_assert_eq!(a.len() + b.len(), dst.len());
    let tile = tile.max(16);
    if dst.len() <= tile {
        merge_into(a, b, dst);
        return;
    }
    let mut ai = 0usize;
    let mut bi = 0usize;
    let mut out = 0usize;
    while out < dst.len() {
        let want = tile.min(dst.len() - out);
        // Split point: how many of the next `want` outputs come from `a`.
        let (da, db) = merge_path(&a[ai..], &b[bi..], want);
        merge_into(
            &a[ai..ai + da],
            &b[bi..bi + db],
            &mut dst[out..out + want],
        );
        ai += da;
        bi += db;
        out += want;
    }
}

/// Merge-path search: given sorted `a`, `b` and a diagonal `k`, return
/// `(i, j)` with `i + j = k` such that merging `a[..i]` and `b[..j]` yields
/// exactly the first `k` elements of the merged output (stable convention:
/// ties prefer `a`).
pub fn merge_path<T: Copy + Ord>(a: &[T], b: &[T], k: usize) -> (usize, usize) {
    debug_assert!(k <= a.len() + b.len());
    let mut lo = k.saturating_sub(b.len());
    let mut hi = k.min(a.len());
    while lo < hi {
        let i = (lo + hi) / 2;
        let j = k - i;
        // Feasible iff a[..i], b[..j] are exactly the k smallest:
        //   a[i-1] <= b[j]  (taking one more from b wouldn't be forced)
        //   b[j-1] <  a[i]  (ties go to a, so b[j-1] == a[i] means take a first)
        if i < a.len() && j > 0 && b[j - 1] > a[i] {
            lo = i + 1;
        } else {
            hi = i;
        }
    }
    let i = lo;
    let j = k - i;
    (i, j)
}

/// Split the merge of `a` and `b` into `parts` independent (src-range,
/// src-range, out-range) jobs of near-equal output size.
pub fn merge_path_split<T: Copy + Ord>(
    a: &[T],
    b: &[T],
    parts: usize,
) -> Vec<(std::ops::Range<usize>, std::ops::Range<usize>, std::ops::Range<usize>)> {
    let total = a.len() + b.len();
    let bounds = crate::exec::partition_even(total, parts.max(1));
    let mut out = Vec::with_capacity(bounds.len());
    let (mut pi, mut pj) = (0usize, 0usize);
    for r in bounds {
        let (i, j) = merge_path(a, b, r.end);
        out.push((pi..i, pj..j, r.clone()));
        pi = i;
        pj = j;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn sorted_rand(rng: &mut Xoshiro256pp, len: usize, span: i64) -> Vec<i64> {
        let mut v: Vec<i64> = (0..len).map(|_| rng.range_i64(-span, span)).collect();
        v.sort_unstable();
        v
    }

    fn check_all_merges(a: &[i64], b: &[i64]) {
        let mut expect: Vec<i64> = a.iter().chain(b.iter()).copied().collect();
        expect.sort();
        let mut d1 = vec![0i64; expect.len()];
        merge_into(a, b, &mut d1);
        assert_eq!(d1, expect, "merge_into");
        let mut d2 = vec![0i64; expect.len()];
        merge_gallop_into(a, b, &mut d2);
        assert_eq!(d2, expect, "merge_gallop_into");
        for tile in [16usize, 37, 128] {
            let mut d3 = vec![0i64; expect.len()];
            merge_tiled_into(a, b, &mut d3, tile);
            assert_eq!(d3, expect, "merge_tiled_into tile={tile}");
        }
    }

    #[test]
    fn merge_edges() {
        check_all_merges(&[], &[]);
        check_all_merges(&[1], &[]);
        check_all_merges(&[], &[1]);
        check_all_merges(&[1, 3, 5], &[2, 4, 6]);
        check_all_merges(&[1, 2, 3], &[4, 5, 6]);
        check_all_merges(&[4, 5, 6], &[1, 2, 3]);
        check_all_merges(&[2, 2, 2], &[2, 2]);
    }

    #[test]
    fn merge_random() {
        let mut rng = Xoshiro256pp::seeded(101);
        for _ in 0..50 {
            let la = rng.below(200);
            let lb = rng.below(200);
            let a = sorted_rand(&mut rng, la, 50);
            let b = sorted_rand(&mut rng, lb, 50);
            check_all_merges(&a, &b);
        }
    }

    #[test]
    fn gallop_bounds() {
        let run = [1i64, 3, 3, 3, 7, 9];
        assert_eq!(gallop_left(&run, 3), 1); // elements < 3
        assert_eq!(gallop_right(&run, 3), 4); // elements <= 3
        assert_eq!(gallop_left(&run, 0), 0);
        assert_eq!(gallop_right(&run, 100), 6);
        assert_eq!(gallop_left(&[], 5), 0);
    }

    #[test]
    fn merge_path_invariants() {
        let mut rng = Xoshiro256pp::seeded(303);
        for _ in 0..30 {
            let la = rng.below(100);
            let lb = rng.below(100);
            let a = sorted_rand(&mut rng, la, 20);
            let b = sorted_rand(&mut rng, lb, 20);
            for k in [0, 1, (a.len() + b.len()) / 2, a.len() + b.len()] {
                let (i, j) = merge_path(&a, &b, k);
                assert_eq!(i + j, k);
                // Elements taken must not exceed any element left behind.
                if i > 0 && j < b.len() {
                    assert!(a[i - 1] <= b[j], "a tail vs b head");
                }
                if j > 0 && i < a.len() {
                    assert!(b[j - 1] >= a[i] || b[j - 1] < a[i] || true);
                    assert!(b[j - 1] <= a[i] || a[i] >= b[j - 1] || true);
                    // The strict correctness claim: b[j-1] cannot be > a[i]
                    // under the tie-to-a convention... b[j-1] <= a[i] is not
                    // required; what is required is b[j-1] < a[i] OR equal
                    // handled by preferring a. Check the merged prefix is the
                    // k smallest instead:
                }
                let mut prefix: Vec<i64> =
                    a[..i].iter().chain(b[..j].iter()).copied().collect();
                prefix.sort_unstable();
                let mut all: Vec<i64> = a.iter().chain(b.iter()).copied().collect();
                all.sort_unstable();
                assert_eq!(prefix, all[..k].to_vec(), "prefix is k smallest");
            }
        }
    }

    #[test]
    fn merge_path_split_reassembles() {
        let mut rng = Xoshiro256pp::seeded(404);
        let a = sorted_rand(&mut rng, 333, 100);
        let b = sorted_rand(&mut rng, 278, 100);
        let mut expect: Vec<i64> = a.iter().chain(b.iter()).copied().collect();
        expect.sort();
        for parts in [1usize, 2, 5, 16] {
            let jobs = merge_path_split(&a, &b, parts);
            let mut dst = vec![0i64; expect.len()];
            for (ra, rb, rd) in jobs {
                let len = rd.len();
                let mut tmp = vec![0i64; len];
                merge_into(&a[ra], &b[rb], &mut tmp);
                dst[rd].copy_from_slice(&tmp);
            }
            assert_eq!(dst, expect, "parts={parts}");
        }
    }
}
