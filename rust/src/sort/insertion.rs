//! Insertion sort for small subarrays — the base case of the refined parallel
//! mergesort (paper §3.1: "switching to simpler algorithms, such as insertion
//! sort, for small subarrays ... enhances cache performance and reduces
//! constant factors").

/// Classic in-place insertion sort. O(n²) worst case, O(n) on nearly-sorted
/// input; fastest choice below a few thousand elements for plain integers.
pub fn insertion_sort<T: Copy + Ord>(a: &mut [T]) {
    for i in 1..a.len() {
        let key = a[i];
        let mut j = i;
        while j > 0 && a[j - 1] > key {
            a[j] = a[j - 1];
            j -= 1;
        }
        a[j] = key;
    }
}

/// Binary insertion sort: finds the insertion point with binary search, then
/// shifts with a (memmove-friendly) rotate. Fewer comparisons than the linear
/// scan — useful when comparisons are the dominant cost.
pub fn binary_insertion_sort<T: Copy + Ord>(a: &mut [T]) {
    for i in 1..a.len() {
        let key = a[i];
        // partition_point gives the first index whose element is > key among
        // a[..i] (upper bound — keeps the sort stable).
        let pos = a[..i].partition_point(|x| *x <= key);
        if pos < i {
            a.copy_within(pos..i, pos + 1);
            a[pos] = key;
        }
    }
}

/// Insertion sort starting at `from` (elements before it are assumed
/// sorted) — a tail pass after block-sorting a prefix. Currently exercised
/// only by tests; kept crate-private until a sort path adopts it.
#[allow(dead_code)]
pub(crate) fn insertion_sort_tail<T: Copy + Ord>(a: &mut [T], from: usize) {
    for i in from.max(1)..a.len() {
        let key = a[i];
        let mut j = i;
        while j > 0 && a[j - 1] > key {
            a[j] = a[j - 1];
            j -= 1;
        }
        a[j] = key;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn check_sorts(xs: &[i64]) {
        let mut expect = xs.to_vec();
        expect.sort();
        let mut a = xs.to_vec();
        insertion_sort(&mut a);
        assert_eq!(a, expect, "insertion_sort");
        let mut b = xs.to_vec();
        binary_insertion_sort(&mut b);
        assert_eq!(b, expect, "binary_insertion_sort");
        let mut c = xs.to_vec();
        insertion_sort_tail(&mut c, 1);
        assert_eq!(c, expect, "insertion_sort_tail");
    }

    #[test]
    fn edge_cases() {
        check_sorts(&[]);
        check_sorts(&[1]);
        check_sorts(&[2, 1]);
        check_sorts(&[1, 2]);
        check_sorts(&[3, 3, 3]);
        check_sorts(&[i64::MAX, i64::MIN, 0, -1, 1]);
    }

    #[test]
    fn random_arrays() {
        let mut rng = Xoshiro256pp::seeded(77);
        for len in [3usize, 10, 33, 100, 257] {
            let xs: Vec<i64> =
                (0..len).map(|_| rng.range_i64(-1000, 1000)).collect();
            check_sorts(&xs);
        }
    }

    #[test]
    fn already_sorted_and_reverse() {
        let asc: Vec<i64> = (0..200).collect();
        check_sorts(&asc);
        let desc: Vec<i64> = (0..200).rev().collect();
        check_sorts(&desc);
    }

    #[test]
    fn stability_of_binary_insertion() {
        // With (key, tag) pairs ordered by key only, equal keys must keep
        // their input order. Use a key-only Ord wrapper.
        #[derive(Clone, Copy, PartialEq, Eq, Debug)]
        struct KV(i32, i32);
        impl PartialOrd for KV {
            fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
                Some(self.cmp(o))
            }
        }
        impl Ord for KV {
            fn cmp(&self, o: &Self) -> std::cmp::Ordering {
                self.0.cmp(&o.0)
            }
        }
        let mut xs = vec![KV(2, 0), KV(1, 0), KV(2, 1), KV(1, 1), KV(2, 2)];
        binary_insertion_sort(&mut xs);
        assert_eq!(xs, vec![KV(1, 0), KV(1, 1), KV(2, 0), KV(2, 1), KV(2, 2)]);
    }
}
