//! The EvoSort sorting library: every algorithm the paper describes plus the
//! baselines it compares against.
//!
//! | Paper | Here |
//! |---|---|
//! | Refined parallel mergesort (Alg. 3) | [`parallel_merge::parallel_merge_sort`] |
//! | Block-based LSD radix sort (Alg. 4/5) | [`radix::radix_sort`] |
//! | Adaptive Partition Sort (Alg. 6) | [`adaptive::AdaptiveSorter`] |
//! | NumPy quicksort baseline | [`introsort::introsort`] |
//! | NumPy mergesort baseline | [`stable_merge::stable_merge_sort`] |
//! | Library fallback below `T_numpy` | `slice::sort_unstable` via Alg. 6 |

pub mod adaptive;
pub mod floats;
pub mod insertion;
pub mod introsort;
pub mod key;
pub mod merge;
pub mod parallel_merge;
pub mod radix;
pub mod samplesort;
pub mod stable_merge;

pub use adaptive::{AdaptiveSorter, TileSorter};
pub use floats::{radix_sort_f32, radix_sort_f64};
pub use key::{Dtype, SortKey, SortPayload, SortScratch};
pub use parallel_merge::{
    merge_runs_bottom_up, parallel_merge_sort, parallel_merge_sort_timed,
    parallel_merge_sort_with_scratch, MergeTuning,
};
pub use radix::{
    radix_sort, radix_sort_timed, radix_sort_with_executor, radix_sort_with_scratch, RadixKey,
};
pub use samplesort::{sample_sort, sample_sort_timed, sample_sort_with_scratch, SampleSortTuning};

/// Baseline selector used by benches and the CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Baseline {
    /// Sequential introsort — `np.sort(kind='quicksort')` analog.
    Quicksort,
    /// Sequential stable mergesort — `np.sort(kind='mergesort')` analog.
    Mergesort,
    /// Rust std `sort_unstable` (pdqsort) — the strongest library routine.
    Std,
}

impl Baseline {
    pub fn name(self) -> &'static str {
        match self {
            Baseline::Quicksort => "baseline-quicksort",
            Baseline::Mergesort => "baseline-mergesort",
            Baseline::Std => "baseline-std",
        }
    }

    pub fn all() -> &'static [Baseline] {
        &[Baseline::Quicksort, Baseline::Mergesort, Baseline::Std]
    }

    /// Run the baseline on i64 data.
    pub fn sort_i64(self, data: &mut [i64]) {
        match self {
            Baseline::Quicksort => introsort::introsort(data),
            Baseline::Mergesort => stable_merge::stable_merge_sort(data),
            Baseline::Std => data.sort_unstable(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_i64, Distribution};

    #[test]
    fn baselines_agree() {
        let data = generate_i64(10_000, Distribution::Uniform, 95, 2);
        let mut expect = data.clone();
        expect.sort_unstable();
        for b in Baseline::all() {
            let mut got = data.clone();
            b.sort_i64(&mut got);
            assert_eq!(got, expect, "{}", b.name());
        }
    }
}
