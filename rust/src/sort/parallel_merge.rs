//! Refined parallel mergesort — Algorithm 3 of the paper.
//!
//! Bottom-up: partition into base chunks of `T_insertion` elements, insertion
//! sort each chunk in parallel, then repeatedly merge adjacent runs of width
//! `w` into runs of width `2w`, in parallel, ping-ponging between the input
//! buffer and one scratch buffer. Two parallelism levels:
//!
//! * across run pairs — every pair merge at a given width is independent;
//! * within a pair — once a single merge's output exceeds `T_merge`, it is
//!   split with merge-path partitioning into near-equal sub-merges (this is
//!   what keeps all cores busy in the last passes when only a few giant runs
//!   remain).
//!
//! The inner merge kernel is the tiled/galloping `MergeStandardOpt`
//! (see [`super::merge`]), with `T_tile` bounding the live working set.

use super::insertion::insertion_sort;
use super::merge::{merge_gallop_into, merge_path_split, merge_tiled_into};
use crate::exec;

/// Tuning knobs for the refined parallel mergesort (a projection of the full
/// [`crate::params::SortParams`] genome).
#[derive(Debug, Clone, Copy)]
pub struct MergeTuning {
    /// Base chunk size sorted with insertion sort (`T_insertion`).
    pub insertion_threshold: usize,
    /// Output size above which a single merge is split across threads
    /// (`T_merge`).
    pub parallel_merge_threshold: usize,
    /// Cache tile for the blocked merge kernel (`T_tile`).
    pub tile: usize,
    /// Worker thread budget.
    pub threads: usize,
}

impl Default for MergeTuning {
    fn default() -> Self {
        MergeTuning {
            insertion_threshold: 2048,
            parallel_merge_threshold: 1 << 16,
            tile: 4096,
            threads: crate::util::default_threads(),
        }
    }
}

/// Sort `data` in place with the refined parallel mergesort.
pub fn parallel_merge_sort<T: Copy + Ord + Send + Sync + Default>(
    data: &mut [T],
    tuning: &MergeTuning,
) {
    let n = data.len();
    if n <= 1 {
        return;
    }
    let chunk = tuning.insertion_threshold.clamp(8, n.max(8));
    if n <= chunk {
        insertion_sort(data);
        return;
    }

    // Phase 1 — parallel insertion sort of base chunks.
    // Chunk geometry: fixed size `chunk` (last chunk may be short). We hand
    // groups of chunks to threads.
    let nchunks = n.div_ceil(chunk);
    let workers = tuning.threads.max(1);
    {
        let mut views: Vec<&mut [T]> = Vec::with_capacity(nchunks);
        let mut rest = &mut *data;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            views.push(head);
            rest = tail;
        }
        if workers == 1 || nchunks == 1 {
            for v in views {
                insertion_sort(v);
            }
        } else {
            let mut per_worker: Vec<Vec<&mut [T]>> = (0..workers.min(nchunks)).map(|_| Vec::new()).collect();
            let nw = per_worker.len();
            for (i, v) in views.into_iter().enumerate() {
                per_worker[i % nw].push(v);
            }
            std::thread::scope(|scope| {
                for work in per_worker {
                    scope.spawn(move || {
                        for v in work {
                            insertion_sort(v);
                        }
                    });
                }
            });
        }
    }

    // Phase 2 — bottom-up parallel merging, ping-pong between buffers.
    merge_runs_bottom_up(data, chunk, tuning);
}

/// Bottom-up parallel merge of an array already composed of sorted runs of
/// `run_width` elements (the last run may be shorter). Shared by the refined
/// parallel mergesort (runs from insertion sort) and the XLA tile backend
/// (runs from the Pallas bitonic kernel).
pub fn merge_runs_bottom_up<T: Copy + Ord + Send + Sync + Default>(
    data: &mut [T],
    run_width: usize,
    tuning: &MergeTuning,
) {
    let n = data.len();
    if run_width >= n || n <= 1 {
        return;
    }
    let mut scratch: Vec<T> = vec![T::default(); n];
    let mut src_is_data = true;
    let mut width = run_width.max(1);
    while width < n {
        {
            let (src, dst): (&[T], &mut [T]) = if src_is_data {
                (&*data, &mut scratch[..])
            } else {
                (&scratch[..], &mut *data)
            };
            merge_pass(src, dst, width, tuning);
        }
        src_is_data = !src_is_data;
        width *= 2;
    }
    if !src_is_data {
        data.copy_from_slice(&scratch);
    }
}

/// One width-doubling pass: merge every adjacent pair of `width`-sized runs
/// from `src` into `dst`.
fn merge_pass<T: Copy + Ord + Send + Sync>(
    src: &[T],
    dst: &mut [T],
    width: usize,
    tuning: &MergeTuning,
) {
    let n = src.len();
    // Collect (pair range) jobs. A pair is [lo, mid) + [mid, hi).
    struct Pair {
        lo: usize,
        mid: usize,
        hi: usize,
    }
    let mut pairs = Vec::new();
    let mut lo = 0usize;
    while lo < n {
        let mid = (lo + width).min(n);
        let hi = (lo + 2 * width).min(n);
        pairs.push(Pair { lo, mid, hi });
        lo = hi;
    }

    // Carve dst into per-pair output slices.
    let mut outs: Vec<&mut [T]> = Vec::with_capacity(pairs.len());
    let mut rest = dst;
    for p in &pairs {
        let (head, tail) = rest.split_at_mut(p.hi - p.lo);
        outs.push(head);
        rest = tail;
    }

    let threads = tuning.threads.max(1);
    let big = tuning.parallel_merge_threshold.max(1024);

    // Small pass (many pairs): one thread per group of pairs.
    // Large pass (few pairs): split each merge with merge-path.
    if pairs.len() >= threads * 2 || threads == 1 {
        let nw = threads.min(pairs.len());
        let mut per_worker: Vec<Vec<(usize, &mut [T])>> = (0..nw).map(|_| Vec::new()).collect();
        for (i, o) in outs.into_iter().enumerate() {
            per_worker[i % nw].push((i, o));
        }
        std::thread::scope(|scope| {
            for work in per_worker {
                let pairs = &pairs;
                scope.spawn(move || {
                    for (i, out) in work {
                        let p = &pairs[i];
                        merge_one(&src[p.lo..p.mid], &src[p.mid..p.hi], out, tuning);
                    }
                });
            }
        });
    } else {
        // Few big pairs: give each pair a share of the thread budget and use
        // merge-path splitting inside pairs whose output exceeds `T_merge`.
        let share = (threads / pairs.len()).max(1);
        std::thread::scope(|scope| {
            for (i, out) in outs.into_iter().enumerate() {
                let p = &pairs[i];
                let a = &src[p.lo..p.mid];
                let b = &src[p.mid..p.hi];
                scope.spawn(move || {
                    if out.len() > big && share > 1 {
                        parallel_merge_into(a, b, out, share, tuning.tile);
                    } else {
                        merge_one(a, b, out, tuning);
                    }
                });
            }
        });
    }
}

/// Merge a single pair with the optimized sequential kernel: tiled when the
/// output is large (cache blocking), galloping otherwise.
fn merge_one<T: Copy + Ord>(a: &[T], b: &[T], dst: &mut [T], tuning: &MergeTuning) {
    if b.is_empty() {
        dst.copy_from_slice(a);
    } else if a.is_empty() {
        dst.copy_from_slice(b);
    } else if dst.len() >= tuning.tile.max(16) * 4 {
        merge_tiled_into(a, b, dst, tuning.tile);
    } else {
        merge_gallop_into(a, b, dst);
    }
}

/// Split one merge into `parts` independent sub-merges (merge-path) and run
/// them on scoped threads.
pub fn parallel_merge_into<T: Copy + Ord + Send + Sync>(
    a: &[T],
    b: &[T],
    dst: &mut [T],
    parts: usize,
    tile: usize,
) {
    debug_assert_eq!(a.len() + b.len(), dst.len());
    let jobs = merge_path_split(a, b, parts);
    // Carve dst according to job output ranges (contiguous, in order).
    let mut outs: Vec<&mut [T]> = Vec::with_capacity(jobs.len());
    let mut rest = dst;
    for (_, _, rd) in &jobs {
        let (head, tail) = rest.split_at_mut(rd.len());
        outs.push(head);
        rest = tail;
    }
    std::thread::scope(|scope| {
        for ((ra, rb, _), out) in jobs.into_iter().zip(outs) {
            let sa = &a[ra];
            let sb = &b[rb];
            scope.spawn(move || {
                merge_tiled_into(sa, sb, out, tile.max(16));
            });
        }
    });
}

/// Convenience: sort with default tuning and an explicit thread count.
pub fn parallel_merge_sort_default<T: Copy + Ord + Send + Sync + Default>(
    data: &mut [T],
    threads: usize,
) {
    let tuning = MergeTuning { threads, ..MergeTuning::default() };
    parallel_merge_sort(data, &tuning);
}

/// Because exec helpers are shared, re-export partition for tests.
#[allow(unused_imports)]
pub(crate) use exec::partition_even as _partition_even_for_tests;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_i64, Distribution};

    fn check(data: &[i64], tuning: &MergeTuning) {
        let mut got = data.to_vec();
        parallel_merge_sort(&mut got, tuning);
        let mut expect = data.to_vec();
        expect.sort_unstable();
        assert_eq!(got, expect);
    }

    #[test]
    fn sorts_small_and_edge() {
        let t = MergeTuning { threads: 4, ..Default::default() };
        check(&[], &t);
        check(&[1], &t);
        check(&[2, 1], &t);
        check(&[5, 5, 5, 5], &t);
        check(&[3, 1, 4, 1, 5, 9, 2, 6], &t);
    }

    #[test]
    fn sorts_various_distributions() {
        for dist in [
            Distribution::Uniform,
            Distribution::Zipf,
            Distribution::Sorted,
            Distribution::Reverse,
            Distribution::FewUnique,
            Distribution::OrganPipe,
        ] {
            let data = generate_i64(20_000, dist, 11, 4);
            check(&data, &MergeTuning { threads: 4, ..Default::default() });
        }
    }

    #[test]
    fn sorts_across_tunings() {
        let data = generate_i64(30_000, Distribution::Uniform, 13, 4);
        for ins in [8usize, 100, 1000, 50_000] {
            for tile in [16usize, 1000, 100_000] {
                for pmt in [1024usize, 4096, 1 << 20] {
                    let t = MergeTuning {
                        insertion_threshold: ins,
                        parallel_merge_threshold: pmt,
                        tile,
                        threads: 4,
                    };
                    check(&data, &t);
                }
            }
        }
    }

    #[test]
    fn sorts_odd_sizes() {
        // Non-power-of-two sizes exercise short final runs at every pass.
        for n in [3usize, 1000, 1023, 1025, 12_345] {
            let data = generate_i64(n, Distribution::Uniform, 17, 2);
            check(
                &data,
                &MergeTuning { insertion_threshold: 64, threads: 3, ..Default::default() },
            );
        }
    }

    #[test]
    fn single_thread_path() {
        let data = generate_i64(5000, Distribution::Uniform, 19, 1);
        check(&data, &MergeTuning { threads: 1, ..Default::default() });
    }

    #[test]
    fn parallel_merge_into_direct() {
        let mut a = generate_i64(4096, Distribution::Uniform, 23, 2);
        let mut b = generate_i64(2048, Distribution::Uniform, 29, 2);
        a.sort_unstable();
        b.sort_unstable();
        let mut dst = vec![0i64; a.len() + b.len()];
        parallel_merge_into(&a, &b, &mut dst, 5, 256);
        let mut expect: Vec<i64> = a.iter().chain(b.iter()).copied().collect();
        expect.sort_unstable();
        assert_eq!(dst, expect);
    }
}
