//! Refined parallel mergesort — Algorithm 3 of the paper.
//!
//! Bottom-up: partition into base chunks of `T_insertion` elements, insertion
//! sort each chunk in parallel, then repeatedly merge adjacent runs of width
//! `w` into runs of width `2w`, in parallel, ping-ponging between the input
//! buffer and one scratch buffer. Two parallelism levels:
//!
//! * across run pairs — every pair merge at a given width is independent;
//! * within a pair — once a single merge's output exceeds `T_merge`, it is
//!   split with merge-path partitioning into near-equal sub-merges (this is
//!   what keeps all cores busy in the last passes when only a few giant runs
//!   remain).
//!
//! All parallel sections run as fork-join batches on the [`MergeTuning`]'s
//! executor (the process-wide parked pool by default, a service-owned pool
//! when dispatched through `AdaptiveSorter`) — no per-pass thread spawns —
//! and the ping-pong scratch comes from the caller
//! ([`parallel_merge_sort_with_scratch`]), so steady-state service traffic
//! allocates nothing here.
//!
//! The inner merge kernel is the tiled/galloping `MergeStandardOpt`
//! (see [`super::merge`]), with `T_tile` bounding the live working set.

use std::ops::Range;
use std::sync::Arc;

use super::insertion::insertion_sort;
use super::merge::{merge_gallop_into, merge_path_split, merge_tiled_into};
use crate::exec::{self, Executor};
use crate::obs::{Phase, PhaseTimer};

/// Tuning knobs for the refined parallel mergesort (a projection of the full
/// [`crate::params::SortParams`] genome) plus the executor the parallel
/// sections run on.
#[derive(Debug, Clone)]
pub struct MergeTuning {
    /// Base chunk size sorted with insertion sort (`T_insertion`).
    pub insertion_threshold: usize,
    /// Output size above which a single merge is split across threads
    /// (`T_merge`).
    pub parallel_merge_threshold: usize,
    /// Cache tile for the blocked merge kernel (`T_tile`).
    pub tile: usize,
    /// Worker thread budget (chunk geometry; concurrency is additionally
    /// bounded by the executor's width).
    pub threads: usize,
    /// The fork-join pool every parallel section of the sort runs on.
    pub exec: Arc<Executor>,
}

impl Default for MergeTuning {
    fn default() -> Self {
        MergeTuning {
            insertion_threshold: 2048,
            parallel_merge_threshold: 1 << 16,
            tile: 4096,
            threads: crate::util::default_threads(),
            exec: Arc::clone(exec::global()),
        }
    }
}

/// Sort `data` in place with the refined parallel mergesort (internal
/// scratch; see [`parallel_merge_sort_with_scratch`] for the zero-alloc hot
/// path).
pub fn parallel_merge_sort<T: Copy + Ord + Send + Sync + Default>(
    data: &mut [T],
    tuning: &MergeTuning,
) {
    parallel_merge_sort_with_scratch(data, tuning, &mut Vec::new())
}

/// Sort `data` in place, ping-ponging through the caller's `scratch` buffer
/// (grown as needed, reused across calls) so repeated sorts allocate
/// nothing.
pub fn parallel_merge_sort_with_scratch<T: Copy + Ord + Send + Sync + Default>(
    data: &mut [T],
    tuning: &MergeTuning,
    scratch: &mut Vec<T>,
) {
    parallel_merge_sort_timed(data, tuning, scratch, &mut PhaseTimer::disabled())
}

/// [`parallel_merge_sort_with_scratch`] with per-phase timing: the base-run
/// insertion sort accumulates into `MergeRunSort`, the width-doubling merge
/// levels into `MergeLevels`. With a disabled timer the brackets are
/// branches — this *is* the untimed hot path.
pub fn parallel_merge_sort_timed<T: Copy + Ord + Send + Sync + Default>(
    data: &mut [T],
    tuning: &MergeTuning,
    scratch: &mut Vec<T>,
    timer: &mut PhaseTimer,
) {
    let n = data.len();
    if n <= 1 {
        return;
    }
    let chunk = tuning.insertion_threshold.clamp(8, n.max(8));
    if n <= chunk {
        let started = timer.begin();
        insertion_sort(data);
        timer.end(Phase::MergeRunSort, started);
        return;
    }

    // Phase 1 — parallel insertion sort of base chunks, grouped into at
    // most `threads` executor tasks so the caller's budget bounds
    // concurrency (the executor — especially the process-wide one — is
    // usually wider).
    {
        let started = timer.begin();
        let nchunks = n.div_ceil(chunk);
        let ranges: Vec<Range<usize>> =
            (0..nchunks).map(|i| i * chunk..((i + 1) * chunk).min(n)).collect();
        let views = exec::carve_mut(&mut *data, &ranges);
        if tuning.threads <= 1 || views.len() == 1 {
            for v in views {
                insertion_sort(v);
            }
        } else {
            let nw = tuning.threads.min(views.len());
            let mut groups: Vec<Vec<&mut [T]>> = (0..nw).map(|_| Vec::new()).collect();
            for (i, v) in views.into_iter().enumerate() {
                groups[i % nw].push(v);
            }
            tuning.exec.run_consume(groups, |_, group| {
                for v in group {
                    insertion_sort(v);
                }
            });
        }
        timer.end(Phase::MergeRunSort, started);
    }

    // Phase 2 — bottom-up parallel merging, ping-pong between buffers.
    let started = timer.begin();
    merge_runs_bottom_up(data, chunk, tuning, scratch);
    timer.end(Phase::MergeLevels, started);
}

/// Bottom-up parallel merge of an array already composed of sorted runs of
/// `run_width` elements (the last run may be shorter). Shared by the refined
/// parallel mergesort (runs from insertion sort) and the XLA tile backend
/// (runs from the Pallas bitonic kernel). The ping-pong buffer is the
/// caller's `scratch`, grown to `data.len()` once and reused across calls.
pub fn merge_runs_bottom_up<T: Copy + Ord + Send + Sync + Default>(
    data: &mut [T],
    run_width: usize,
    tuning: &MergeTuning,
    scratch: &mut Vec<T>,
) {
    let n = data.len();
    if run_width >= n || n <= 1 {
        return;
    }
    if scratch.len() < n {
        scratch.resize(n, T::default());
    }
    let scratch = &mut scratch[..n];
    let mut src_is_data = true;
    let mut width = run_width.max(1);
    while width < n {
        {
            let (src, dst): (&[T], &mut [T]) = if src_is_data {
                (&*data, &mut *scratch)
            } else {
                (&*scratch, &mut *data)
            };
            merge_pass(src, dst, width, tuning);
        }
        src_is_data = !src_is_data;
        width *= 2;
    }
    if !src_is_data {
        data.copy_from_slice(scratch);
    }
}

/// One width-doubling pass: merge every adjacent pair of `width`-sized runs
/// from `src` into `dst`.
fn merge_pass<T: Copy + Ord + Send + Sync>(
    src: &[T],
    dst: &mut [T],
    width: usize,
    tuning: &MergeTuning,
) {
    let n = src.len();
    // Collect (pair range) jobs. A pair is [lo, mid) + [mid, hi).
    struct Pair {
        lo: usize,
        mid: usize,
        hi: usize,
    }
    let mut pairs = Vec::new();
    let mut lo = 0usize;
    while lo < n {
        let mid = (lo + width).min(n);
        let hi = (lo + 2 * width).min(n);
        pairs.push(Pair { lo, mid, hi });
        lo = hi;
    }

    // Carve dst into per-pair output slices.
    let ranges: Vec<Range<usize>> = pairs.iter().map(|p| p.lo..p.hi).collect();
    let outs = exec::carve_mut(dst, &ranges);

    let threads = tuning.threads.max(1);
    let big = tuning.parallel_merge_threshold.max(1024);

    // Small pass (many pairs): pairs grouped round-robin into at most
    // `threads` executor tasks, so the caller's budget bounds concurrency.
    // Large pass (few pairs): split each merge with merge-path so all
    // budgeted lanes stay busy.
    if pairs.len() >= threads * 2 || threads == 1 {
        if threads == 1 {
            for (i, out) in outs.into_iter().enumerate() {
                let p = &pairs[i];
                merge_one(&src[p.lo..p.mid], &src[p.mid..p.hi], out, tuning);
            }
        } else {
            let nw = threads.min(pairs.len());
            let mut groups: Vec<Vec<(usize, &mut [T])>> = (0..nw).map(|_| Vec::new()).collect();
            for (i, out) in outs.into_iter().enumerate() {
                groups[i % nw].push((i, out));
            }
            tuning.exec.run_consume(groups, |_, group| {
                for (i, out) in group {
                    let p = &pairs[i];
                    merge_one(&src[p.lo..p.mid], &src[p.mid..p.hi], out, tuning);
                }
            });
        }
    } else {
        // Few big pairs: give each pair a share of the thread budget and use
        // merge-path splitting inside pairs whose output exceeds `T_merge`.
        // The inner splits are nested fork-join batches on the same
        // executor.
        let share = (threads / pairs.len()).max(1);
        tuning.exec.run_consume(outs, |i, out| {
            let p = &pairs[i];
            let a = &src[p.lo..p.mid];
            let b = &src[p.mid..p.hi];
            if out.len() > big && share > 1 {
                parallel_merge_into_on(&tuning.exec, a, b, out, share, tuning.tile);
            } else {
                merge_one(a, b, out, tuning);
            }
        });
    }
}

/// Merge a single pair with the optimized sequential kernel: tiled when the
/// output is large (cache blocking), galloping otherwise.
fn merge_one<T: Copy + Ord>(a: &[T], b: &[T], dst: &mut [T], tuning: &MergeTuning) {
    if b.is_empty() {
        dst.copy_from_slice(a);
    } else if a.is_empty() {
        dst.copy_from_slice(b);
    } else if dst.len() >= tuning.tile.max(16) * 4 {
        merge_tiled_into(a, b, dst, tuning.tile);
    } else {
        merge_gallop_into(a, b, dst);
    }
}

/// Split one merge into `parts` independent sub-merges (merge-path) and run
/// them on the process-wide parked executor.
pub fn parallel_merge_into<T: Copy + Ord + Send + Sync>(
    a: &[T],
    b: &[T],
    dst: &mut [T],
    parts: usize,
    tile: usize,
) {
    parallel_merge_into_on(exec::global(), a, b, dst, parts, tile)
}

/// [`parallel_merge_into`] on an explicit executor (nested batches from
/// `merge_pass` reuse the tuning's pool).
fn parallel_merge_into_on<T: Copy + Ord + Send + Sync>(
    exec: &Executor,
    a: &[T],
    b: &[T],
    dst: &mut [T],
    parts: usize,
    tile: usize,
) {
    debug_assert_eq!(a.len() + b.len(), dst.len());
    let jobs = merge_path_split(a, b, parts);
    // Carve dst according to job output ranges (contiguous, in order).
    let ranges: Vec<Range<usize>> = jobs.iter().map(|(_, _, rd)| rd.clone()).collect();
    let outs = crate::exec::carve_mut(dst, &ranges);
    exec.run_consume(outs, |i, out| {
        let (ra, rb, _) = &jobs[i];
        merge_tiled_into(&a[ra.clone()], &b[rb.clone()], out, tile.max(16));
    });
}

/// Convenience: sort with default tuning and an explicit thread count
/// (internal scratch — use [`parallel_merge_sort_with_scratch`] on hot
/// paths).
pub fn parallel_merge_sort_default<T: Copy + Ord + Send + Sync + Default>(
    data: &mut [T],
    threads: usize,
) {
    let tuning = MergeTuning { threads, ..MergeTuning::default() };
    parallel_merge_sort_with_scratch(data, &tuning, &mut Vec::new());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_i64, Distribution};

    fn check(data: &[i64], tuning: &MergeTuning) {
        let mut got = data.to_vec();
        parallel_merge_sort(&mut got, tuning);
        let mut expect = data.to_vec();
        expect.sort_unstable();
        assert_eq!(got, expect);
    }

    #[test]
    fn sorts_small_and_edge() {
        let t = MergeTuning { threads: 4, ..Default::default() };
        check(&[], &t);
        check(&[1], &t);
        check(&[2, 1], &t);
        check(&[5, 5, 5, 5], &t);
        check(&[3, 1, 4, 1, 5, 9, 2, 6], &t);
    }

    #[test]
    fn sorts_various_distributions() {
        for dist in [
            Distribution::Uniform,
            Distribution::Zipf,
            Distribution::Sorted,
            Distribution::Reverse,
            Distribution::FewUnique,
            Distribution::OrganPipe,
        ] {
            let data = generate_i64(20_000, dist, 11, 4);
            check(&data, &MergeTuning { threads: 4, ..Default::default() });
        }
    }

    #[test]
    fn sorts_across_tunings() {
        let data = generate_i64(30_000, Distribution::Uniform, 13, 4);
        for ins in [8usize, 100, 1000, 50_000] {
            for tile in [16usize, 1000, 100_000] {
                for pmt in [1024usize, 4096, 1 << 20] {
                    let t = MergeTuning {
                        insertion_threshold: ins,
                        parallel_merge_threshold: pmt,
                        tile,
                        threads: 4,
                        ..MergeTuning::default()
                    };
                    check(&data, &t);
                }
            }
        }
    }

    #[test]
    fn sorts_odd_sizes() {
        // Non-power-of-two sizes exercise short final runs at every pass.
        for n in [3usize, 1000, 1023, 1025, 12_345] {
            let data = generate_i64(n, Distribution::Uniform, 17, 2);
            check(
                &data,
                &MergeTuning { insertion_threshold: 64, threads: 3, ..Default::default() },
            );
        }
    }

    #[test]
    fn single_thread_path() {
        let data = generate_i64(5000, Distribution::Uniform, 19, 1);
        check(&data, &MergeTuning { threads: 1, ..Default::default() });
    }

    #[test]
    fn timed_variant_reports_merge_phases_only() {
        let tuning = MergeTuning { threads: 3, insertion_threshold: 256, ..Default::default() };
        let mut timer = PhaseTimer::enabled();
        let mut scratch = Vec::new();
        let mut data = generate_i64(30_000, Distribution::Uniform, 21, 2);
        let mut expect = data.clone();
        expect.sort_unstable();
        parallel_merge_sort_timed(&mut data, &tuning, &mut scratch, &mut timer);
        assert_eq!(data, expect);
        let phases = timer.drain();
        assert!(phases.iter().any(|(p, _)| *p == Phase::MergeRunSort), "{phases:?}");
        assert!(phases.iter().any(|(p, _)| *p == Phase::MergeLevels), "{phases:?}");
        assert!(
            phases.iter().all(|(p, _)| p.kernel() == crate::obs::Kernel::Merge),
            "{phases:?}"
        );
    }

    #[test]
    fn scratch_is_reused_across_sorts() {
        let tuning = MergeTuning { threads: 3, insertion_threshold: 128, ..Default::default() };
        let mut scratch = Vec::new();
        for seed in 0..5u64 {
            let mut data = generate_i64(20_000, Distribution::Uniform, seed, 2);
            let mut expect = data.clone();
            expect.sort_unstable();
            parallel_merge_sort_with_scratch(&mut data, &tuning, &mut scratch);
            assert_eq!(data, expect);
        }
        assert!(scratch.capacity() >= 20_000, "scratch kept its high-water capacity");
        // A smaller sort reuses the same (larger) buffer untouched.
        let cap = scratch.capacity();
        let mut small = generate_i64(5_000, Distribution::Reverse, 9, 2);
        let mut expect = small.clone();
        expect.sort_unstable();
        parallel_merge_sort_with_scratch(&mut small, &tuning, &mut scratch);
        assert_eq!(small, expect);
        assert_eq!(scratch.capacity(), cap, "no reallocation for smaller inputs");
    }

    #[test]
    fn merge_runs_bottom_up_with_caller_scratch() {
        // Pre-sorted runs of width 256 (the XLA tile shape) merge correctly
        // through a reused scratch buffer.
        let tuning = MergeTuning { threads: 3, ..Default::default() };
        let mut scratch = Vec::new();
        for seed in [31u64, 32, 33] {
            let mut data = generate_i64(10_000 + seed as usize, Distribution::Uniform, seed, 2);
            for run in data.chunks_mut(256) {
                run.sort_unstable();
            }
            let mut expect = data.clone();
            expect.sort_unstable();
            merge_runs_bottom_up(&mut data, 256, &tuning, &mut scratch);
            assert_eq!(data, expect);
        }
    }

    #[test]
    fn parallel_merge_into_direct() {
        let mut a = generate_i64(4096, Distribution::Uniform, 23, 2);
        let mut b = generate_i64(2048, Distribution::Uniform, 29, 2);
        a.sort_unstable();
        b.sort_unstable();
        let mut dst = vec![0i64; a.len() + b.len()];
        parallel_merge_into(&a, &b, &mut dst, 5, 256);
        let mut expect: Vec<i64> = a.iter().chain(b.iter()).copied().collect();
        expect.sort_unstable();
        assert_eq!(dst, expect);
    }
}
