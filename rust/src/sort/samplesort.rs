//! Parallel samplesort — the related-work comparison algorithm (Sanders &
//! Winkel, "Super Scalar Sample Sort", the paper's reference [3]) and an
//! optional extra strategy for the adaptive dispatcher.
//!
//! Structure:
//! 1. draw an oversampled random sample, sort it, pick `buckets − 1`
//!    splitters;
//! 2. each thread classifies its contiguous block against the splitters
//!    (branch-free binary search) and counts per-bucket occupancy;
//! 3. exclusive prefix sums assign disjoint output ranges per
//!    (thread, bucket) — the same scheme as the radix scatter;
//! 4. threads scatter their blocks; each bucket is then sorted in parallel
//!    with introsort (buckets are independent and cache-sized).
//!
//! Comparison-based (works for any `Ord` key, unlike radix) and one-pass
//! (unlike mergesort's log n passes) — the classic trade-off the ablation
//! bench quantifies.

use super::introsort::introsort;
use crate::exec::{self, Executor};
use crate::obs::{Phase, PhaseTimer};
use crate::rng::Xoshiro256pp;

/// Tuning for samplesort.
#[derive(Debug, Clone, Copy)]
pub struct SampleSortTuning {
    /// Number of buckets (≈ parallel grain; default 4× threads, min 2).
    pub buckets: usize,
    /// Sample size per bucket (oversampling factor).
    pub oversample: usize,
    /// Below this size, fall back to sequential introsort.
    pub sequential_threshold: usize,
    pub threads: usize,
    pub seed: u64,
}

impl SampleSortTuning {
    pub fn for_threads(threads: usize) -> Self {
        SampleSortTuning {
            buckets: (threads * 4).clamp(2, 512),
            oversample: 16,
            sequential_threshold: 8192,
            threads: threads.max(1),
            seed: 0x5A3B1E50,
        }
    }
}

/// Sort in place with parallel samplesort (process-wide executor, internal
/// scratch — see [`sample_sort_with_scratch`] for the zero-alloc hot path).
pub fn sample_sort<T: Copy + Ord + Send + Sync + Default>(data: &mut [T], tuning: &SampleSortTuning) {
    sample_sort_with_scratch(data, tuning, exec::global(), &mut Vec::new())
}

/// Sort in place with parallel samplesort on an explicit executor, using the
/// caller's `scratch` as the bucket scatter buffer (grown once, reused
/// across calls).
pub fn sample_sort_with_scratch<T: Copy + Ord + Send + Sync + Default>(
    data: &mut [T],
    tuning: &SampleSortTuning,
    exec: &Executor,
    scratch: &mut Vec<T>,
) {
    sample_sort_timed(data, tuning, exec, scratch, &mut PhaseTimer::disabled())
}

/// [`sample_sort_with_scratch`] with per-phase timing: splitter sampling
/// accumulates into `SampleSplitters`, classification + offsets + scatter
/// into `SamplePartition`, the per-bucket sorts into `SampleBucketSort`.
/// With a disabled timer the brackets are branches — this *is* the untimed
/// hot path.
pub fn sample_sort_timed<T: Copy + Ord + Send + Sync + Default>(
    data: &mut [T],
    tuning: &SampleSortTuning,
    exec: &Executor,
    scratch: &mut Vec<T>,
    timer: &mut PhaseTimer,
) {
    let n = data.len();
    if n <= tuning.sequential_threshold.max(64) {
        let started = timer.begin();
        introsort(data);
        timer.end(Phase::SampleBucketSort, started);
        return;
    }
    let buckets = tuning.buckets.clamp(2, n / 16);

    // 1. Splitters from an oversampled random sample.
    let started = timer.begin();
    let mut rng = Xoshiro256pp::seeded(tuning.seed);
    let sample_n = (buckets * tuning.oversample.max(1)).min(n);
    let mut sample: Vec<T> = (0..sample_n).map(|_| data[rng.below(n)]).collect();
    sample.sort_unstable();
    let splitters: Vec<T> =
        (1..buckets).map(|i| sample[i * sample_n / buckets]).collect();
    timer.end(Phase::SampleSplitters, started);

    // 2. Per-thread classification + bucket counts.
    let started = timer.begin();
    let bounds = exec::partition_even(n, tuning.threads);
    let nth = bounds.len();
    let data_ro: &[T] = data;
    let classify = |x: &T| -> usize { splitters.partition_point(|s| s <= x) };
    // (`threads <= 1` yields a single range, which the executor runs
    // inline — no special case needed.)
    let counts: Vec<Vec<usize>> = exec.run_map(nth, |t| {
        let mut c = vec![0usize; buckets];
        for x in &data_ro[bounds[t].clone()] {
            c[classify(x)] += 1;
        }
        c
    });

    // 3. Offsets: global bucket starts, then per-(bucket, thread) cursors.
    let mut bucket_sizes = vec![0usize; buckets];
    for c in &counts {
        for (b, &v) in c.iter().enumerate() {
            bucket_sizes[b] += v;
        }
    }
    let mut bucket_start = vec![0usize; buckets + 1];
    for b in 0..buckets {
        bucket_start[b + 1] = bucket_start[b] + bucket_sizes[b];
    }
    let mut cursors: Vec<Vec<usize>> = counts;
    for b in 0..buckets {
        let mut cur = bucket_start[b];
        for c in cursors.iter_mut() {
            let cnt = c[b];
            c[b] = cur;
            cur += cnt;
        }
    }

    // 4. Scatter into the caller's scratch (disjoint (thread, bucket)
    //    ranges — same safety argument as the radix scatter).
    if scratch.len() < n {
        scratch.resize(n, T::default());
    }
    let temp = &mut scratch[..n];
    {
        struct Buf<T>(*mut T);
        // SAFETY: the pointee (`temp`) is owned by this frame and outlives
        // the batch below; sending the pointer only moves `T: Send` writes.
        unsafe impl<T: Send> Send for Buf<T> {}
        // SAFETY: tasks write through disjoint (thread, bucket) cursor
        // ranges from the exclusive prefix sum — no index is written twice
        // and nothing reads `temp` until the batch completes.
        unsafe impl<T: Send> Sync for Buf<T> {}
        let dst = Buf(temp.as_mut_ptr());
        let cursors_ref = &cursors;
        exec.run_indexed(nth, |t| {
            let src = &data_ro[bounds[t].clone()];
            let mut cur = cursors_ref[t].clone();
            let p = dst.0;
            for &x in src {
                let b = classify(&x);
                // SAFETY: cur[b] stays within this task's private
                // (thread, bucket) output range by construction.
                unsafe { p.add(cur[b]).write(x) };
                cur[b] += 1;
            }
        });
    }
    timer.end(Phase::SamplePartition, started);

    // 5. Sort each bucket in parallel, buckets grouped round-robin into at
    //    most `threads` executor tasks (the caller's budget bounds
    //    concurrency), writing back into `data`.
    {
        let started = timer.begin();
        let ranges: Vec<std::ops::Range<usize>> =
            (0..buckets).map(|b| bucket_start[b]..bucket_start[b + 1]).collect();
        let out_views = exec::carve_mut(data, &ranges);
        let temp_ro: &[T] = temp;
        let nw = tuning.threads.max(1).min(buckets);
        let mut groups: Vec<Vec<(usize, &mut [T])>> = (0..nw).map(|_| Vec::new()).collect();
        for (b, out) in out_views.into_iter().enumerate() {
            groups[b % nw].push((b, out));
        }
        exec.run_consume(groups, |_, group| {
            for (b, out) in group {
                out.copy_from_slice(&temp_ro[bucket_start[b]..bucket_start[b + 1]]);
                introsort(out);
            }
        });
        timer.end(Phase::SampleBucketSort, started);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_i64, Distribution};

    fn check(data: &[i64], tuning: &SampleSortTuning) {
        let mut got = data.to_vec();
        sample_sort(&mut got, tuning);
        let mut expect = data.to_vec();
        expect.sort_unstable();
        assert_eq!(got, expect);
    }

    #[test]
    fn edge_cases() {
        let t = SampleSortTuning::for_threads(3);
        check(&[], &t);
        check(&[1], &t);
        check(&[2, 1], &t);
        check(&[7; 100], &t);
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn random_inputs_cross_tunings() {
        let data = generate_i64(60_000, Distribution::Uniform, 71, 3);
        for buckets in [2usize, 8, 64] {
            for threads in [1usize, 3] {
                let t = SampleSortTuning {
                    buckets,
                    sequential_threshold: 1000,
                    threads,
                    ..SampleSortTuning::for_threads(threads)
                };
                check(&data, &t);
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn skewed_and_adversarial() {
        let t = SampleSortTuning {
            sequential_threshold: 500,
            ..SampleSortTuning::for_threads(4)
        };
        for dist in [
            Distribution::Zipf,       // heavy splitter duplication
            Distribution::Constant,   // all one bucket
            Distribution::Sorted,
            Distribution::Reverse,
            Distribution::FewUnique,
        ] {
            check(&generate_i64(30_000, dist, 73, 2), &t);
        }
    }

    #[test]
    fn odd_sizes() {
        let t = SampleSortTuning { sequential_threshold: 100, ..SampleSortTuning::for_threads(2) };
        for n in [101usize, 1009, 9999] {
            check(&generate_i64(n, Distribution::Uniform, 75, 2), &t);
        }
    }

    #[test]
    fn sequential_fallback_small() {
        let t = SampleSortTuning::for_threads(4);
        check(&generate_i64(5000, Distribution::Uniform, 77, 2), &t); // below threshold
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn timed_variant_reports_sample_phases_only() {
        let exec = crate::exec::Executor::new(3);
        let t = SampleSortTuning { sequential_threshold: 1000, ..SampleSortTuning::for_threads(3) };
        let mut timer = PhaseTimer::enabled();
        let mut scratch = Vec::new();
        let mut data = generate_i64(30_000, Distribution::Uniform, 79, 2);
        let mut expect = data.clone();
        expect.sort_unstable();
        sample_sort_timed(&mut data, &t, &exec, &mut scratch, &mut timer);
        assert_eq!(data, expect);
        let phases = timer.drain();
        assert!(phases.iter().any(|(p, _)| *p == Phase::SampleSplitters), "{phases:?}");
        assert!(phases.iter().any(|(p, _)| *p == Phase::SamplePartition), "{phases:?}");
        assert!(phases.iter().any(|(p, _)| *p == Phase::SampleBucketSort), "{phases:?}");
        assert!(
            phases.iter().all(|(p, _)| p.kernel() == crate::obs::Kernel::Sample),
            "{phases:?}"
        );
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn explicit_executor_and_scratch_reuse() {
        let exec = crate::exec::Executor::new(3);
        let t = SampleSortTuning { sequential_threshold: 1000, ..SampleSortTuning::for_threads(3) };
        let mut scratch = Vec::new();
        for seed in 0..4u64 {
            let mut data = generate_i64(25_000, Distribution::Uniform, seed, 2);
            let mut expect = data.clone();
            expect.sort_unstable();
            sample_sort_with_scratch(&mut data, &t, &exec, &mut scratch);
            assert_eq!(data, expect);
        }
        assert!(scratch.capacity() >= 25_000, "scatter buffer retained across sorts");
    }
}
