//! Block-based LSD radix sort — Algorithms 4 and 5 of the paper.
//!
//! Structure (identical for 32- and 64-bit keys, differing only in pass count
//! and sign mask, exactly as the paper describes):
//!
//! 1. XOR every element with the sign mask, mapping signed order onto
//!    unsigned order (`0x8000_0000` / `0x8000_0000_0000_0000`).
//! 2. For each 8-bit digit (4 passes for 32-bit, 8 for 64-bit):
//!    a. each thread builds a **local histogram** over its contiguous block;
//!    b. histograms are reduced into global prefix sums;
//!    c. per-thread write offsets are derived so every thread scatters into
//!       disjoint destination slots;
//!    d. threads redistribute their block into the temporary buffer;
//!    e. buffers are swapped.
//! 3. XOR with the sign mask again to restore values.
//!
//! Two refinements over the paper's pseudocode (both standard, both covered
//! by ablation benches):
//! * **skip trivial passes** — if a digit's histogram puts every element in
//!   one bucket, the pass is a no-op permutation and is skipped;
//! * **fused first-pass histogram** — histograms for *all* digits are
//!   computed in one read sweep before pass 0, halving full-array reads.

use crate::exec;
use crate::obs::{Phase, PhaseTimer};

const RADIX_BITS: usize = 8;
const BUCKETS: usize = 1 << RADIX_BITS;

/// Integer key sortable by the block-based LSD radix sort.
pub trait RadixKey: Copy + Ord + Send + Sync + Default {
    /// Number of 8-bit passes needed (4 for 32-bit, 8 for 64-bit).
    const PASSES: usize;
    /// XOR mask flipping the sign bit (0 for unsigned types).
    const SIGN_MASK: u64;
    /// The key's bit pattern widened to u64.
    fn bits(self) -> u64;
    /// Rebuild the key from a (possibly sign-flipped) bit pattern.
    fn from_bits(bits: u64) -> Self;
}

impl RadixKey for i32 {
    const PASSES: usize = 4;
    const SIGN_MASK: u64 = 0x8000_0000;
    #[inline]
    fn bits(self) -> u64 {
        self as u32 as u64
    }
    #[inline]
    fn from_bits(bits: u64) -> Self {
        bits as u32 as i32
    }
}

impl RadixKey for i64 {
    const PASSES: usize = 8;
    const SIGN_MASK: u64 = 0x8000_0000_0000_0000;
    #[inline]
    fn bits(self) -> u64 {
        self as u64
    }
    #[inline]
    fn from_bits(bits: u64) -> Self {
        bits as i64
    }
}

impl RadixKey for u32 {
    const PASSES: usize = 4;
    const SIGN_MASK: u64 = 0;
    #[inline]
    fn bits(self) -> u64 {
        self as u64
    }
    #[inline]
    fn from_bits(bits: u64) -> Self {
        bits as u32
    }
}

impl RadixKey for u64 {
    const PASSES: usize = 8;
    const SIGN_MASK: u64 = 0;
    #[inline]
    fn bits(self) -> u64 {
        self
    }
    #[inline]
    fn from_bits(bits: u64) -> Self {
        bits
    }
}

/// Shared mutable scatter target: a raw pointer to the scratch (or data)
/// buffer that every scatter task writes through concurrently.
///
/// The aliasing discipline is positional, not locked: each (thread, bucket)
/// pair owns a private, non-overlapping destination interval produced by the
/// exclusive prefix sum over the per-thread histograms, and a task only ever
/// writes inside its own intervals. The buffer is allocated to full length
/// before the batch, and the submitter keeps it alive while parked on the
/// batch, so writes are always in-bounds into live memory.
struct ScatterBuf<T>(*mut T);
// SAFETY: sending the pointer moves `T: Send` payload writes to another
// thread; the pointee buffer outlives the batch (owned by the parked
// submitter), so the pointer never dangles on the receiving thread.
unsafe impl<T: Send> Send for ScatterBuf<T> {}
// SAFETY: concurrent `&ScatterBuf` use is write-only through disjoint
// (thread, bucket) intervals per the prefix-sum construction above — no two
// tasks write one index, and nobody reads until the batch completes, so no
// `&T` is ever shared while writes are in flight.
unsafe impl<T: Send> Sync for ScatterBuf<T> {}

/// Sort `data` in place with the block-based LSD radix sort using up to
/// `threads` threads.
pub fn radix_sort<T: RadixKey>(data: &mut [T], threads: usize) {
    radix_sort_with_scratch(data, threads, &mut Vec::new());
}

/// Variant reusing a caller-provided scratch buffer (grown as needed) so the
/// hot path allocates nothing — used by the service and the benches. Runs on
/// the process-wide parked executor.
pub fn radix_sort_with_scratch<T: RadixKey>(
    data: &mut [T],
    threads: usize,
    scratch: &mut Vec<T>,
) {
    radix_sort_with_executor(data, threads, scratch, exec::global())
}

/// The effective worker count for an `n`-element radix sort: at least one
/// thread, and no more than one per 4096 elements (below that, per-thread
/// histogram and offset bookkeeping outweighs the parallel gain). `n < 64`
/// never reaches this clamp — those arrays fall back to `sort_unstable`.
pub(crate) fn effective_threads(threads: usize, n: usize) -> usize {
    threads.max(1).min(n.div_ceil(4096))
}

/// Fully explicit variant: caller-provided scratch *and* executor — the form
/// the adaptive dispatcher uses so every service worker's jobs share one
/// parked pool and one arena.
pub fn radix_sort_with_executor<T: RadixKey>(
    data: &mut [T],
    threads: usize,
    scratch: &mut Vec<T>,
    exec: &exec::Executor,
) {
    radix_sort_timed(data, threads, scratch, exec, &mut PhaseTimer::disabled())
}

/// [`radix_sort_with_executor`] with per-phase timing: the coordinating
/// thread brackets each fan-out (min/max reduce, per-pass histograms,
/// scatters, final copy-back) into `timer`'s accumulators. With a disabled
/// timer every bracket is a branch — this *is* the untimed hot path.
pub fn radix_sort_timed<T: RadixKey>(
    data: &mut [T],
    threads: usize,
    scratch: &mut Vec<T>,
    exec: &exec::Executor,
    timer: &mut PhaseTimer,
) {
    let n = data.len();
    if n <= 1 {
        return;
    }
    if n < 64 {
        // Tiny arrays: pass overhead dominates.
        data.sort_unstable();
        return;
    }
    let threads = effective_threads(threads, n);
    if scratch.len() < n {
        scratch.resize(n, T::default());
    }
    let scratch = &mut scratch[..n];

    // Phase 1 — sign flip (parallel) fused with a min/max reduction over the
    // flipped (unsigned-ordered) bit patterns. The min/max range drives
    // *range narrowing*: keys are subsequently viewed as `bits - min`, so
    // only `ceil(log256(max - min + 1))` digit passes carry information and
    // the rest are skipped outright — no histogram sweep, no scatter. For
    // the paper's workload (i64 in [-1e9, 1e9]) this halves the pass count
    // from 8 to 4 (§Perf iteration 2; iteration 1 removed a redundant fused
    // all-pass histogram pre-sweep that cost O(PASSES·n) increments).
    let bounds = exec::partition_even(n, threads);
    let nth = bounds.len();
    let started = timer.begin();
    let (min_bits, max_bits) = {
        let views = exec::carve_mut(&mut *data, &bounds);
        // Each executor task owns one view and returns its (lo, hi) into a
        // private result slot — lock-free, results already in thread order.
        let minmax: Vec<(u64, u64)> = exec.run_consume_map(views, |_, view| {
            let mut lo = u64::MAX;
            let mut hi = 0u64;
            if T::SIGN_MASK != 0 {
                for x in view.iter_mut() {
                    let b = x.bits() ^ T::SIGN_MASK;
                    *x = T::from_bits(b);
                    lo = lo.min(b);
                    hi = hi.max(b);
                }
            } else {
                for x in view.iter() {
                    let b = x.bits();
                    lo = lo.min(b);
                    hi = hi.max(b);
                }
            }
            (lo, hi)
        });
        minmax.iter().fold((u64::MAX, 0u64), |(lo, hi), &(l, h)| (lo.min(l), hi.max(h)))
    };
    timer.end(Phase::RadixMinMax, started);
    let delta = max_bits - min_bits;

    let mut src_is_data = true;
    for pass in 0..T::PASSES {
        let shift = RADIX_BITS * pass;
        if (delta >> shift) == 0 {
            // No key differs at or above this digit: all remaining passes
            // are the identity permutation on `bits - min`.
            break;
        }

        // Per-thread local histograms of the *current* source layout
        // (Algorithm 4, line 5). These must be recomputed each pass: the
        // scatter permutes data, so block contents change.
        let started = timer.begin();
        let src_now: &[T] = if src_is_data { &*data } else { &*scratch };
        let mut hists: Vec<[usize; BUCKETS]> = exec.run_map(nth, |t| {
            let chunk = &src_now[bounds[t].clone()];
            let mut h = [0usize; BUCKETS];
            for &x in chunk {
                h[(((x.bits() - min_bits) >> shift) & 0xFF) as usize] += 1;
            }
            h
        });

        // Global histogram for this pass + single-bucket skip (all keys can
        // still share a digit inside the informative range).
        let mut global = [0usize; BUCKETS];
        for h in hists.iter() {
            for b in 0..BUCKETS {
                global[b] += h[b];
            }
        }
        timer.end(Phase::RadixHistogram, started);
        if global.iter().any(|&c| c == n) {
            continue;
        }

        // Exclusive prefix over buckets, then per-(bucket, thread) offsets:
        // offset[t][b] = global_prefix[b] + sum_{t' < t} hist[t'][b].
        let mut bucket_start = [0usize; BUCKETS];
        let mut acc = 0usize;
        for b in 0..BUCKETS {
            bucket_start[b] = acc;
            acc += global[b];
        }
        // Convert each thread's histogram into its private write cursors.
        for b in 0..BUCKETS {
            let mut cursor = bucket_start[b];
            for h in hists.iter_mut() {
                let count = h[b];
                h[b] = cursor;
                cursor += count;
            }
        }

        // Scatter.
        {
            let started = timer.begin();
            let (src, dst): (&[T], &mut [T]) = if src_is_data {
                (&*data, &mut *scratch)
            } else {
                (&*scratch, &mut *data)
            };
            let dst_ptr = ScatterBuf(dst.as_mut_ptr());
            let hists_ref: &Vec<[usize; BUCKETS]> = &hists;
            exec.run_indexed(nth, |t| {
                let src = &src[bounds[t].clone()];
                let mut cursors = hists_ref[t];
                let p = dst_ptr.0;
                for &x in src {
                    let b = (((x.bits() - min_bits) >> shift) & 0xFF) as usize;
                    // SAFETY: cursors[b] ranges over this task's private
                    // (thread, bucket) output interval only.
                    unsafe { p.add(cursors[b]).write(x) };
                    cursors[b] += 1;
                }
            });
            timer.end(Phase::RadixScatter, started);
        }
        src_is_data = !src_is_data;
    }

    // If the last scatter landed in scratch, copy back (parallel). Views
    // are carved from the same `bounds2` the source is indexed with, so the
    // geometry coupling is structural.
    let started = timer.begin();
    if !src_is_data {
        let bounds2 = exec::partition_even(n, threads);
        let src: &[T] = scratch;
        let views = exec::carve_mut(&mut *data, &bounds2);
        exec.run_consume(views, |i, view| view.copy_from_slice(&src[bounds2[i].clone()]));
    }

    // Phase 3 — undo the sign flip.
    if T::SIGN_MASK != 0 {
        exec.run_chunks(data, threads, |_, chunk| {
            for x in chunk.iter_mut() {
                *x = T::from_bits(x.bits() ^ T::SIGN_MASK);
            }
        });
    }
    timer.end(Phase::RadixCopyback, started);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_i32, generate_i64, Distribution};

    fn check_i64(data: &[i64], threads: usize) {
        let mut got = data.to_vec();
        radix_sort(&mut got, threads);
        let mut expect = data.to_vec();
        expect.sort_unstable();
        assert_eq!(got, expect);
    }

    #[test]
    fn edge_cases() {
        check_i64(&[], 4);
        check_i64(&[1], 4);
        check_i64(&[2, 1], 4);
        check_i64(&[i64::MIN, i64::MAX, 0, -1, 1], 4);
        check_i64(&[0; 100], 4);
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn negative_handling_i32() {
        let data = generate_i32(50_000, Distribution::Uniform, 41, 4);
        assert!(data.iter().any(|&x| x < 0), "workload must contain negatives");
        let mut got = data.clone();
        radix_sort(&mut got, 4);
        let mut expect = data;
        expect.sort_unstable();
        assert_eq!(got, expect);
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn negative_handling_i64() {
        let data = generate_i64(50_000, Distribution::Uniform, 43, 4);
        assert!(data.iter().any(|&x| x < 0));
        check_i64(&data, 4);
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn unsigned_types() {
        let src = generate_i64(20_000, Distribution::Uniform, 45, 4);
        let u32s: Vec<u32> = src.iter().map(|&x| x as u32).collect();
        let mut got = u32s.clone();
        radix_sort(&mut got, 4);
        let mut expect = u32s;
        expect.sort_unstable();
        assert_eq!(got, expect);

        let u64s: Vec<u64> = src.iter().map(|&x| x as u64).collect();
        let mut got = u64s.clone();
        radix_sort(&mut got, 4);
        let mut expect = u64s;
        expect.sort_unstable();
        assert_eq!(got, expect);
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn distributions_and_thread_counts() {
        for dist in [
            Distribution::Uniform,
            Distribution::Zipf,
            Distribution::Sorted,
            Distribution::Reverse,
            Distribution::FewUnique,
            Distribution::Constant,
        ] {
            let data = generate_i64(30_000, dist, 47, 4);
            for threads in [1usize, 2, 8] {
                check_i64(&data, threads);
            }
        }
    }

    #[test]
    fn narrow_range_skips_passes() {
        // Top 7 bytes identical -> 7 of 8 passes skipped; must still sort.
        let data = generate_i64(10_000, Distribution::UniformRange(0, 255), 49, 4);
        check_i64(&data, 4);
    }

    #[test]
    fn odd_sizes() {
        for n in [63usize, 64, 65, 4095, 4097, 10_001] {
            let data = generate_i64(n, Distribution::Uniform, 51, 2);
            check_i64(&data, 3);
        }
    }

    #[test]
    fn thread_clamp_at_the_64_and_4096_boundaries() {
        // One thread per 4096 elements, never zero. n < 64 never reaches the
        // clamp (sort_unstable fallback), so 64 is the smallest clamped n.
        assert_eq!(effective_threads(8, 64), 1, "smallest clamped n uses one thread");
        assert_eq!(effective_threads(8, 4096), 1, "exactly one grain is still one thread");
        assert_eq!(effective_threads(8, 4097), 2, "one element past the grain adds a thread");
        assert_eq!(effective_threads(8, 8 * 4096), 8, "thread budget is the ceiling");
        assert_eq!(effective_threads(8, 8 * 4096 + 1), 8, "never exceeds the budget");
        assert_eq!(effective_threads(2, 1 << 20), 2, "large n still respects the budget");
        assert_eq!(effective_threads(0, 10_000), 1, "a zero budget clamps up to one");
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn executor_variant_matches_std_sort() {
        let exec = crate::exec::Executor::new(3);
        let mut scratch = Vec::new();
        let data = generate_i64(30_000, Distribution::Zipf, 53, 2);
        let mut got = data.clone();
        radix_sort_with_executor(&mut got, 4, &mut scratch, &exec);
        let mut expect = data;
        expect.sort_unstable();
        assert_eq!(got, expect);
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn timed_variant_reports_radix_phases_only() {
        let exec = crate::exec::Executor::new(3);
        let mut timer = PhaseTimer::enabled();
        let mut scratch = Vec::new();
        let mut data = generate_i64(30_000, Distribution::Uniform, 55, 2);
        let mut expect = data.clone();
        expect.sort_unstable();
        radix_sort_timed(&mut data, 4, &mut scratch, &exec, &mut timer);
        assert_eq!(data, expect);
        let phases = timer.drain();
        assert!(phases.iter().any(|(p, _)| *p == Phase::RadixMinMax), "{phases:?}");
        assert!(phases.iter().any(|(p, _)| *p == Phase::RadixHistogram), "{phases:?}");
        assert!(phases.iter().any(|(p, _)| *p == Phase::RadixScatter), "{phases:?}");
        assert!(
            phases.iter().all(|(p, _)| p.kernel() == crate::obs::Kernel::Radix),
            "{phases:?}"
        );
        assert!(phases.iter().all(|&(_, secs)| secs > 0.0));
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn scratch_reuse() {
        let mut scratch = Vec::new();
        for seed in 0..5u64 {
            let mut data = generate_i64(10_000, Distribution::Uniform, seed, 2);
            let mut expect = data.clone();
            expect.sort_unstable();
            radix_sort_with_scratch(&mut data, 4, &mut scratch);
            assert_eq!(data, expect);
        }
        assert!(scratch.len() >= 10_000);
    }
}
