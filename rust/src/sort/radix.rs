//! Block-based LSD radix sort — Algorithms 4 and 5 of the paper — in
//! explicit **count → scan → scatter** form.
//!
//! Structure (identical for 32- and 64-bit keys, differing only in key width
//! and sign mask, exactly as the paper describes):
//!
//! 1. XOR every element with the sign mask, mapping signed order onto
//!    unsigned order (`0x8000_0000` / `0x8000_0000_0000_0000`), fused with a
//!    min/max reduction that drives range narrowing.
//! 2. For each digit of `W_radix` bits (a GA gene: 6, 8, or 11):
//!    a. **count** — each thread histograms its contiguous block into its own
//!       row of a flat `threads × buckets` matrix. Digit extraction runs
//!       8-wide over fixed-stride blocks with no per-element branching, so
//!       the `(bits - min) >> shift & mask` pipeline autovectorizes; only
//!       the bucket increments stay scalar.
//!    b. **scan** — one serial exclusive scan over the histogram matrix
//!       (O(threads·buckets), negligible next to the O(n) sweeps) turns
//!       every `(thread, bucket)` cell into that task's private write
//!       cursor, so scatter destinations are disjoint by construction.
//!    c. **scatter** — threads redistribute their block through the
//!       [`ScatterBuf`] seam into the temporary buffer; buffers swap.
//! 3. XOR with the sign mask again to restore values (copy-back first if the
//!    last scatter landed in scratch).
//!
//! Two refinements over the paper's pseudocode (both standard, both covered
//! by ablation benches):
//! * **skip trivial passes** — range narrowing skips passes above the
//!   min/max delta outright, and a pass whose scan finds every element in
//!   one bucket is a no-op permutation and is skipped;
//! * **one histogram allocation** — the flat matrix is allocated once per
//!   sort and reused across passes (count re-zeroes its own row), instead of
//!   a fresh `Vec` of per-thread histograms every pass.
//!
//! All three phases share one [`RadixPlan`] — the effective thread count and
//! per-thread block bounds are computed exactly once per sort, so count,
//! scatter, and copy-back can never disagree on geometry.

use std::ops::Range;

use crate::exec;
use crate::obs::{Phase, PhaseTimer};
use crate::params::RadixWidth;

/// Integer key sortable by the block-based LSD radix sort.
pub trait RadixKey: Copy + Ord + Send + Sync + Default {
    /// Width of the key's bit pattern (32 or 64); with the digit width it
    /// determines the pass count (`KEY_BITS.div_ceil(width.bits())`).
    const KEY_BITS: usize;
    /// XOR mask flipping the sign bit (0 for unsigned types).
    const SIGN_MASK: u64;
    /// The key's bit pattern widened to u64.
    fn bits(self) -> u64;
    /// Rebuild the key from a (possibly sign-flipped) bit pattern.
    fn from_bits(bits: u64) -> Self;
}

impl RadixKey for i32 {
    const KEY_BITS: usize = 32;
    const SIGN_MASK: u64 = 0x8000_0000;
    #[inline]
    fn bits(self) -> u64 {
        self as u32 as u64
    }
    #[inline]
    fn from_bits(bits: u64) -> Self {
        bits as u32 as i32
    }
}

impl RadixKey for i64 {
    const KEY_BITS: usize = 64;
    const SIGN_MASK: u64 = 0x8000_0000_0000_0000;
    #[inline]
    fn bits(self) -> u64 {
        self as u64
    }
    #[inline]
    fn from_bits(bits: u64) -> Self {
        bits as i64
    }
}

impl RadixKey for u32 {
    const KEY_BITS: usize = 32;
    const SIGN_MASK: u64 = 0;
    #[inline]
    fn bits(self) -> u64 {
        self as u64
    }
    #[inline]
    fn from_bits(bits: u64) -> Self {
        bits as u32
    }
}

impl RadixKey for u64 {
    const KEY_BITS: usize = 64;
    const SIGN_MASK: u64 = 0;
    #[inline]
    fn bits(self) -> u64 {
        self
    }
    #[inline]
    fn from_bits(bits: u64) -> Self {
        bits
    }
}

/// The geometry of one radix sort, computed **once** and shared by every
/// phase: count, scan, scatter, and copy-back all index the same per-thread
/// block bounds, so no phase can re-derive a different thread count.
pub(crate) struct RadixPlan {
    /// Effective worker count (always `bounds.len()`).
    pub(crate) threads: usize,
    /// Contiguous per-thread block bounds tiling `0..n`.
    pub(crate) bounds: Vec<Range<usize>>,
    /// Digit width of every pass.
    pub(crate) width: RadixWidth,
    /// Buckets per digit (`1 << width.bits()`).
    pub(crate) buckets: usize,
    /// Maximum pass count covering the key (range narrowing may use fewer).
    pub(crate) passes: usize,
}

impl RadixPlan {
    pub(crate) fn new(n: usize, threads: usize, width: RadixWidth, key_bits: usize) -> RadixPlan {
        let threads = effective_threads(threads, n);
        let bounds = exec::partition_even(n, threads);
        RadixPlan {
            threads: bounds.len(),
            bounds,
            width,
            buckets: width.buckets(),
            passes: key_bits.div_ceil(width.bits()),
        }
    }
}

/// Shared mutable scatter target: a raw pointer to the scratch (or data)
/// buffer that every scatter task writes through concurrently.
///
/// The aliasing discipline is positional, not locked: each (thread, bucket)
/// pair owns a private, non-overlapping destination interval produced by the
/// exclusive prefix sum over the per-thread histograms, and a task only ever
/// writes inside its own intervals. The buffer is allocated to full length
/// before the batch, and the submitter keeps it alive while parked on the
/// batch, so writes are always in-bounds into live memory.
struct ScatterBuf<T>(*mut T);
// SAFETY: sending the pointer moves `T: Send` payload writes to another
// thread; the pointee buffer outlives the batch (owned by the parked
// submitter), so the pointer never dangles on the receiving thread.
unsafe impl<T: Send> Send for ScatterBuf<T> {}
// SAFETY: concurrent `&ScatterBuf` use is write-only through disjoint
// (thread, bucket) intervals per the prefix-sum construction above — no two
// tasks write one index, and nobody reads until the batch completes, so no
// `&T` is ever shared while writes are in flight.
unsafe impl<T: Send> Sync for ScatterBuf<T> {}

/// Sort `data` in place with the block-based LSD radix sort using up to
/// `threads` threads (default 8-bit digits).
pub fn radix_sort<T: RadixKey>(data: &mut [T], threads: usize) {
    radix_sort_with_scratch(data, threads, &mut Vec::new());
}

/// Variant reusing a caller-provided scratch buffer (grown as needed) so the
/// hot path allocates nothing — used by the service and the benches. Runs on
/// the process-wide parked executor.
pub fn radix_sort_with_scratch<T: RadixKey>(
    data: &mut [T],
    threads: usize,
    scratch: &mut Vec<T>,
) {
    radix_sort_with_executor(data, threads, scratch, exec::global())
}

/// The effective worker count for an `n`-element radix sort: at least one
/// thread, and no more than one per 4096 elements (below that, per-thread
/// histogram and offset bookkeeping outweighs the parallel gain). `n < 64`
/// never reaches this clamp — those arrays fall back to `sort_unstable`.
pub(crate) fn effective_threads(threads: usize, n: usize) -> usize {
    threads.max(1).min(n.div_ceil(4096))
}

/// Fully explicit variant: caller-provided scratch *and* executor — the form
/// the adaptive dispatcher uses so every service worker's jobs share one
/// parked pool and one arena. Default 8-bit digits.
pub fn radix_sort_with_executor<T: RadixKey>(
    data: &mut [T],
    threads: usize,
    scratch: &mut Vec<T>,
    exec: &exec::Executor,
) {
    radix_sort_timed(
        data,
        threads,
        RadixWidth::W8,
        scratch,
        exec,
        &mut PhaseTimer::disabled(),
    )
}

/// [`radix_sort_with_executor`] with an explicit digit width (the `W_radix`
/// gene) and per-phase timing: the coordinating thread brackets each phase
/// (min/max reduce, per-pass count/scan/scatter, final copy-back) into
/// `timer`'s accumulators. With a disabled timer every bracket is a branch —
/// this *is* the untimed hot path.
pub fn radix_sort_timed<T: RadixKey>(
    data: &mut [T],
    threads: usize,
    width: RadixWidth,
    scratch: &mut Vec<T>,
    exec: &exec::Executor,
    timer: &mut PhaseTimer,
) {
    let n = data.len();
    if n <= 1 {
        return;
    }
    if n < 64 {
        // Tiny arrays: pass overhead dominates.
        data.sort_unstable();
        return;
    }
    let plan = RadixPlan::new(n, threads, width, T::KEY_BITS);
    if scratch.len() < n {
        scratch.resize(n, T::default());
    }
    let scratch = &mut scratch[..n];

    // Phase 1 — sign flip (parallel) fused with a min/max reduction over the
    // flipped (unsigned-ordered) bit patterns. The min/max range drives
    // *range narrowing*: keys are subsequently viewed as `bits - min`, so
    // only `ceil(log_buckets(max - min + 1))` digit passes carry information
    // and the rest are skipped outright — no count sweep, no scatter. For
    // the paper's workload (i64 in [-1e9, 1e9]) this halves the 8-bit pass
    // count from 8 to 4 (§Perf iteration 2; iteration 1 removed a redundant
    // fused all-pass histogram pre-sweep that cost O(passes·n) increments).
    let nth = plan.threads;
    let started = timer.begin();
    let (min_bits, max_bits) = {
        let views = exec::carve_mut(&mut *data, &plan.bounds);
        // Each executor task owns one view and returns its (lo, hi) into a
        // private result slot — lock-free, results already in thread order.
        let minmax: Vec<(u64, u64)> = exec.run_consume_map(views, |_, view| {
            let mut lo = u64::MAX;
            let mut hi = 0u64;
            if T::SIGN_MASK != 0 {
                for x in view.iter_mut() {
                    let b = x.bits() ^ T::SIGN_MASK;
                    *x = T::from_bits(b);
                    lo = lo.min(b);
                    hi = hi.max(b);
                }
            } else {
                for x in view.iter() {
                    let b = x.bits();
                    lo = lo.min(b);
                    hi = hi.max(b);
                }
            }
            (lo, hi)
        });
        minmax.iter().fold((u64::MAX, 0u64), |(lo, hi), &(l, h)| (lo.min(l), hi.max(h)))
    };
    timer.end(Phase::RadixMinMax, started);
    let delta = max_bits - min_bits;

    // One flat `threads × buckets` histogram matrix for the whole sort; row
    // `t` is thread `t`'s histogram during count and its write cursors after
    // scan. Row bounds let the executor carve disjoint `&mut` rows.
    let buckets = plan.buckets;
    let mask = (buckets - 1) as u64;
    let mut hist = vec![0usize; nth * buckets];
    let row_bounds: Vec<Range<usize>> =
        (0..nth).map(|t| t * buckets..(t + 1) * buckets).collect();
    let mut totals = vec![0usize; buckets];

    let mut src_is_data = true;
    for pass in 0..plan.passes {
        let shift = plan.width.bits() * pass;
        if (delta >> shift) == 0 {
            // No key differs at or above this digit: all remaining passes
            // are the identity permutation on `bits - min`.
            break;
        }

        // Phase (a) — count. Per-thread local histograms of the *current*
        // source layout (Algorithm 4, line 5). These must be recomputed each
        // pass: the scatter permutes data, so block contents change.
        let started = timer.begin();
        let src_now: &[T] = if src_is_data { &*data } else { &*scratch };
        {
            let rows = exec::carve_mut(&mut hist[..], &row_bounds);
            let bounds = &plan.bounds;
            exec.run_consume(rows, |t, row| {
                count_digits(&src_now[bounds[t].clone()], min_bits, shift, mask, row);
            });
        }
        timer.end(Phase::RadixCount, started);

        // Phase (b) — scan. Column totals, single-bucket skip (all keys can
        // still share a digit inside the informative range), then one
        // exclusive scan turning every (thread, bucket) cell into that
        // task's private write cursor: cell[t][b] becomes
        // bucket_prefix[b] + sum_{t' < t} count[t'][b].
        let started = timer.begin();
        totals.fill(0);
        for t in 0..nth {
            let row = &hist[t * buckets..(t + 1) * buckets];
            for (total, &c) in totals.iter_mut().zip(row) {
                *total += c;
            }
        }
        let single_bucket = totals.iter().any(|&c| c == n);
        if !single_bucket {
            let mut acc = 0usize;
            for b in 0..buckets {
                let mut cursor = acc;
                acc += totals[b];
                for t in 0..nth {
                    let cell = &mut hist[t * buckets + b];
                    let count = *cell;
                    *cell = cursor;
                    cursor += count;
                }
            }
        }
        timer.end(Phase::RadixScan, started);
        if single_bucket {
            continue;
        }

        // Phase (c) — scatter. Fully independent per-thread partitions: each
        // task advances its own cursor row in place and writes through the
        // shared destination pointer.
        {
            let started = timer.begin();
            let (src, dst): (&[T], &mut [T]) = if src_is_data {
                (&*data, &mut *scratch)
            } else {
                (&*scratch, &mut *data)
            };
            let dst_ptr = ScatterBuf(dst.as_mut_ptr());
            let rows = exec::carve_mut(&mut hist[..], &row_bounds);
            let bounds = &plan.bounds;
            exec.run_consume(rows, |t, cursors| {
                let chunk = &src[bounds[t].clone()];
                let p = dst_ptr.0;
                for &x in chunk {
                    let b = (((x.bits() - min_bits) >> shift) & mask) as usize;
                    // SAFETY: cursors[b] ranges over this task's private
                    // (thread, bucket) output interval only.
                    unsafe { p.add(cursors[b]).write(x) };
                    cursors[b] += 1;
                }
            });
            timer.end(Phase::RadixScatter, started);
        }
        src_is_data = !src_is_data;
    }

    // If the last scatter landed in scratch, copy back (parallel) — through
    // the *same* plan bounds every other phase used, so the geometry
    // coupling is structural.
    let started = timer.begin();
    if !src_is_data {
        let src: &[T] = scratch;
        let bounds = &plan.bounds;
        let views = exec::carve_mut(&mut *data, bounds);
        exec.run_consume(views, |i, view| view.copy_from_slice(&src[bounds[i].clone()]));
    }

    // Phase 3 — undo the sign flip.
    if T::SIGN_MASK != 0 {
        exec.run_chunks(data, nth, |_, chunk| {
            for x in chunk.iter_mut() {
                *x = T::from_bits(x.bits() ^ T::SIGN_MASK);
            }
        });
    }
    timer.end(Phase::RadixCopyback, started);
}

/// Count-phase inner loop. Digit extraction runs 8-wide over fixed-stride
/// blocks — no branch-per-element bucket math, so the subtract/shift/mask
/// pipeline autovectorizes; only the bucket increments (a gather/scatter the
/// hardware cannot vectorize profitably) stay scalar.
#[inline]
fn count_digits<T: RadixKey>(
    chunk: &[T],
    min_bits: u64,
    shift: usize,
    mask: u64,
    row: &mut [usize],
) {
    row.fill(0);
    let mut blocks = chunk.chunks_exact(8);
    for block in blocks.by_ref() {
        let mut digits = [0usize; 8];
        for (d, x) in digits.iter_mut().zip(block) {
            *d = (((x.bits() - min_bits) >> shift) & mask) as usize;
        }
        for d in digits {
            row[d] += 1;
        }
    }
    for x in blocks.remainder() {
        row[(((x.bits() - min_bits) >> shift) & mask) as usize] += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_i32, generate_i64, Distribution};

    fn check_i64(data: &[i64], threads: usize) {
        let mut got = data.to_vec();
        radix_sort(&mut got, threads);
        let mut expect = data.to_vec();
        expect.sort_unstable();
        assert_eq!(got, expect);
    }

    #[test]
    fn edge_cases() {
        check_i64(&[], 4);
        check_i64(&[1], 4);
        check_i64(&[2, 1], 4);
        check_i64(&[i64::MIN, i64::MAX, 0, -1, 1], 4);
        check_i64(&[0; 100], 4);
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn negative_handling_i32() {
        let data = generate_i32(50_000, Distribution::Uniform, 41, 4);
        assert!(data.iter().any(|&x| x < 0), "workload must contain negatives");
        let mut got = data.clone();
        radix_sort(&mut got, 4);
        let mut expect = data;
        expect.sort_unstable();
        assert_eq!(got, expect);
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn negative_handling_i64() {
        let data = generate_i64(50_000, Distribution::Uniform, 43, 4);
        assert!(data.iter().any(|&x| x < 0));
        check_i64(&data, 4);
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn unsigned_types() {
        let src = generate_i64(20_000, Distribution::Uniform, 45, 4);
        let u32s: Vec<u32> = src.iter().map(|&x| x as u32).collect();
        let mut got = u32s.clone();
        radix_sort(&mut got, 4);
        let mut expect = u32s;
        expect.sort_unstable();
        assert_eq!(got, expect);

        let u64s: Vec<u64> = src.iter().map(|&x| x as u64).collect();
        let mut got = u64s.clone();
        radix_sort(&mut got, 4);
        let mut expect = u64s;
        expect.sort_unstable();
        assert_eq!(got, expect);
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn distributions_and_thread_counts() {
        for dist in [
            Distribution::Uniform,
            Distribution::Zipf,
            Distribution::Sorted,
            Distribution::Reverse,
            Distribution::FewUnique,
            Distribution::Constant,
        ] {
            let data = generate_i64(30_000, dist, 47, 4);
            for threads in [1usize, 2, 8] {
                check_i64(&data, threads);
            }
        }
    }

    #[test]
    fn narrow_range_skips_passes() {
        // Top 7 bytes identical -> 7 of 8 passes skipped; must still sort.
        let data = generate_i64(10_000, Distribution::UniformRange(0, 255), 49, 4);
        check_i64(&data, 4);
    }

    #[test]
    fn odd_sizes() {
        for n in [63usize, 64, 65, 4095, 4097, 10_001] {
            let data = generate_i64(n, Distribution::Uniform, 51, 2);
            check_i64(&data, 3);
        }
    }

    #[test]
    fn thread_clamp_at_the_64_and_4096_boundaries() {
        // One thread per 4096 elements, never zero. n < 64 never reaches the
        // clamp (sort_unstable fallback), so 64 is the smallest clamped n.
        assert_eq!(effective_threads(8, 64), 1, "smallest clamped n uses one thread");
        assert_eq!(effective_threads(8, 4096), 1, "exactly one grain is still one thread");
        assert_eq!(effective_threads(8, 4097), 2, "one element past the grain adds a thread");
        assert_eq!(effective_threads(8, 8 * 4096), 8, "thread budget is the ceiling");
        assert_eq!(effective_threads(8, 8 * 4096 + 1), 8, "never exceeds the budget");
        assert_eq!(effective_threads(2, 1 << 20), 2, "large n still respects the budget");
        assert_eq!(effective_threads(0, 10_000), 1, "a zero budget clamps up to one");
    }

    #[test]
    fn radix_plan_computes_geometry_once() {
        // The plan must agree with `effective_threads` at the 64/4096
        // boundaries, and its bounds must tile 0..n contiguously — every
        // phase (count, scatter, copy-back) indexes these same bounds.
        for (threads, n, expect) in [
            (8usize, 64usize, 1usize),
            (8, 4096, 1),
            (8, 4097, 2),
            (8, 8 * 4096, 8),
            (8, 8 * 4096 + 1, 8),
            (2, 1 << 20, 2),
            (0, 10_000, 1),
        ] {
            let plan = RadixPlan::new(n, threads, RadixWidth::W8, 64);
            assert_eq!(plan.threads, expect, "threads={threads} n={n}");
            assert_eq!(plan.bounds.len(), plan.threads, "threads is always bounds.len()");
            let mut next = 0;
            for r in &plan.bounds {
                assert_eq!(r.start, next, "bounds must tile contiguously");
                next = r.end;
            }
            assert_eq!(next, n, "bounds must cover 0..n");
        }
        // Width drives buckets and the worst-case pass count.
        let p6 = RadixPlan::new(1 << 20, 4, RadixWidth::W6, 64);
        assert_eq!((p6.buckets, p6.passes), (64, 11));
        let p8 = RadixPlan::new(1 << 20, 4, RadixWidth::W8, 64);
        assert_eq!((p8.buckets, p8.passes), (256, 8));
        let p11 = RadixPlan::new(1 << 20, 4, RadixWidth::W11, 64);
        assert_eq!((p11.buckets, p11.passes), (2048, 6));
        let p11_32 = RadixPlan::new(1 << 20, 4, RadixWidth::W11, 32);
        assert_eq!((p11_32.buckets, p11_32.passes), (2048, 3));
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn every_digit_width_matches_std_sort() {
        let exec = crate::exec::Executor::new(3);
        for width in [RadixWidth::W6, RadixWidth::W8, RadixWidth::W11] {
            let data = generate_i64(10_000, Distribution::Zipf, 57, 4);
            let mut got = data.clone();
            let mut scratch = Vec::new();
            radix_sort_timed(&mut got, 4, width, &mut scratch, &exec, &mut PhaseTimer::disabled());
            let mut expect = data;
            expect.sort_unstable();
            assert_eq!(got, expect, "{width:?}");
        }
    }

    #[test]
    fn digit_widths_small_n_all_dtypes() {
        // Miri-sized: exercises count/scan/scatter at every width and every
        // RadixKey dtype without the minutes-long big-n sweeps.
        fn check<T: RadixKey + std::fmt::Debug>(data: Vec<T>, width: RadixWidth) {
            let exec = crate::exec::Executor::new(2);
            let mut got = data.clone();
            radix_sort_timed(
                &mut got,
                2,
                width,
                &mut Vec::new(),
                &exec,
                &mut PhaseTimer::disabled(),
            );
            let mut expect = data;
            expect.sort_unstable();
            assert_eq!(got, expect, "{width:?}");
        }
        let i64s = generate_i64(300, Distribution::Uniform, 59, 2);
        for width in [RadixWidth::W6, RadixWidth::W8, RadixWidth::W11] {
            check(i64s.clone(), width);
            check(i64s.iter().map(|&x| x as i32).collect::<Vec<i32>>(), width);
            check(i64s.iter().map(|&x| x as u32).collect::<Vec<u32>>(), width);
            check(i64s.iter().map(|&x| x as u64).collect::<Vec<u64>>(), width);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn executor_variant_matches_std_sort() {
        let exec = crate::exec::Executor::new(3);
        let mut scratch = Vec::new();
        let data = generate_i64(30_000, Distribution::Zipf, 53, 2);
        let mut got = data.clone();
        radix_sort_with_executor(&mut got, 4, &mut scratch, &exec);
        let mut expect = data;
        expect.sort_unstable();
        assert_eq!(got, expect);
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn timed_variant_reports_radix_phases_only() {
        let exec = crate::exec::Executor::new(3);
        let mut timer = PhaseTimer::enabled();
        let mut scratch = Vec::new();
        let mut data = generate_i64(30_000, Distribution::Uniform, 55, 2);
        let mut expect = data.clone();
        expect.sort_unstable();
        radix_sort_timed(&mut data, 4, RadixWidth::W8, &mut scratch, &exec, &mut timer);
        assert_eq!(data, expect);
        let phases = timer.drain();
        assert!(phases.iter().any(|(p, _)| *p == Phase::RadixMinMax), "{phases:?}");
        assert!(phases.iter().any(|(p, _)| *p == Phase::RadixCount), "{phases:?}");
        assert!(phases.iter().any(|(p, _)| *p == Phase::RadixScan), "{phases:?}");
        assert!(phases.iter().any(|(p, _)| *p == Phase::RadixScatter), "{phases:?}");
        assert!(
            phases.iter().all(|(p, _)| p.kernel() == crate::obs::Kernel::Radix),
            "{phases:?}"
        );
        assert!(phases.iter().all(|&(_, secs)| secs > 0.0));
    }

    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn scratch_reuse() {
        let mut scratch = Vec::new();
        for seed in 0..5u64 {
            let mut data = generate_i64(10_000, Distribution::Uniform, seed, 2);
            let mut expect = data.clone();
            expect.sort_unstable();
            radix_sort_with_scratch(&mut data, 4, &mut scratch);
            assert_eq!(data, expect);
        }
        assert!(scratch.len() >= 10_000);
    }
}
