//! Least-squares polynomial fitting from scratch (no linear-algebra crates
//! offline): Vandermonde normal equations solved by Gaussian elimination
//! with partial pivoting. Degree is small (the paper fixes degree 2 —
//! "limiting model complexity to degree 2 prevents overfitting", §7.3), so
//! the normal equations are perfectly conditioned enough in x = log10 n.

/// Fit `ys ≈ Σ coeffs[k] · xs^k` of degree `degree`; returns coefficients
/// lowest-order first. `None` if there are fewer points than coefficients or
/// the system is singular.
pub fn polyfit(xs: &[f64], ys: &[f64], degree: usize) -> Option<Vec<f64>> {
    assert_eq!(xs.len(), ys.len());
    let m = degree + 1;
    if xs.len() < m {
        return None;
    }
    // Normal equations: A^T A c = A^T y with A the Vandermonde matrix.
    // ata[i][j] = Σ x^(i+j), aty[i] = Σ y·x^i.
    let mut pow_sums = vec![0.0f64; 2 * degree + 1];
    let mut aty = vec![0.0f64; m];
    for (&x, &y) in xs.iter().zip(ys) {
        let mut xp = 1.0;
        for p in pow_sums.iter_mut() {
            *p += xp;
            xp *= x;
        }
        let mut xp = 1.0;
        for a in aty.iter_mut() {
            *a += y * xp;
            xp *= x;
        }
    }
    let mut mat: Vec<Vec<f64>> =
        (0..m).map(|i| (0..m).map(|j| pow_sums[i + j]).collect()).collect();
    solve_linear(&mut mat, &mut aty).then_some(aty)
}

/// In-place Gaussian elimination with partial pivoting: solves `mat·x = rhs`,
/// leaving the solution in `rhs`. Returns false on a (near-)singular system.
pub fn solve_linear(mat: &mut [Vec<f64>], rhs: &mut [f64]) -> bool {
    let n = rhs.len();
    debug_assert!(mat.len() == n && mat.iter().all(|r| r.len() == n));
    for col in 0..n {
        // Partial pivot.
        let pivot = (col..n)
            .max_by(|&a, &b| mat[a][col].abs().partial_cmp(&mat[b][col].abs()).unwrap())
            .unwrap();
        if mat[pivot][col].abs() < 1e-12 {
            return false;
        }
        mat.swap(col, pivot);
        rhs.swap(col, pivot);
        // Eliminate below.
        for row in col + 1..n {
            let f = mat[row][col] / mat[col][col];
            if f == 0.0 {
                continue;
            }
            for k in col..n {
                mat[row][k] -= f * mat[col][k];
            }
            rhs[row] -= f * rhs[col];
        }
    }
    // Back substitution.
    for col in (0..n).rev() {
        let mut acc = rhs[col];
        for k in col + 1..n {
            acc -= mat[col][k] * rhs[k];
        }
        rhs[col] = acc / mat[col][col];
    }
    true
}

/// Evaluate a polynomial (lowest-order-first coefficients) at `x`.
pub fn polyval(coeffs: &[f64], x: f64) -> f64 {
    coeffs.iter().rev().fold(0.0, |acc, &c| acc * x + c)
}

/// Residuals `y_i − p(x_i)`.
pub fn residuals(coeffs: &[f64], xs: &[f64], ys: &[f64]) -> Vec<f64> {
    xs.iter().zip(ys).map(|(&x, &y)| y - polyval(coeffs, x)).collect()
}

/// Coefficient of determination R².
pub fn r_squared(coeffs: &[f64], xs: &[f64], ys: &[f64]) -> f64 {
    let mean = ys.iter().sum::<f64>() / ys.len() as f64;
    let ss_tot: f64 = ys.iter().map(|&y| (y - mean) * (y - mean)).sum();
    let ss_res: f64 = residuals(coeffs, xs, ys).iter().map(|r| r * r).sum();
    if ss_tot == 0.0 {
        if ss_res == 0.0 {
            1.0
        } else {
            0.0
        }
    } else {
        1.0 - ss_res / ss_tot
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_exact_quadratic() {
        // y = 2 - 3x + 0.5x²
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| 2.0 - 3.0 * x + 0.5 * x * x).collect();
        let c = polyfit(&xs, &ys, 2).unwrap();
        assert!((c[0] - 2.0).abs() < 1e-8, "{c:?}");
        assert!((c[1] + 3.0).abs() < 1e-8);
        assert!((c[2] - 0.5).abs() < 1e-8);
        assert!((r_squared(&c, &xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fits_noisy_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64 * 0.2).collect();
        // y = 1 + 4x with deterministic "noise".
        let ys: Vec<f64> = xs.iter().enumerate().map(|(i, &x)| 1.0 + 4.0 * x + ((i % 3) as f64 - 1.0) * 0.01).collect();
        let c = polyfit(&xs, &ys, 1).unwrap();
        assert!((c[0] - 1.0).abs() < 0.02, "{c:?}");
        assert!((c[1] - 4.0).abs() < 0.01);
        assert!(r_squared(&c, &xs, &ys) > 0.999);
    }

    #[test]
    fn underdetermined_returns_none() {
        assert!(polyfit(&[1.0, 2.0], &[1.0, 2.0], 2).is_none());
    }

    #[test]
    fn singular_returns_none() {
        // All x identical → singular Vandermonde.
        let xs = [3.0f64; 5];
        let ys = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert!(polyfit(&xs, &ys, 2).is_none());
    }

    #[test]
    fn polyval_horner() {
        assert_eq!(polyval(&[1.0, 2.0, 3.0], 2.0), 1.0 + 4.0 + 12.0);
        assert_eq!(polyval(&[], 5.0), 0.0);
        assert_eq!(polyval(&[7.0], 100.0), 7.0);
    }

    #[test]
    fn solve_linear_3x3() {
        let mut m = vec![
            vec![2.0, 1.0, -1.0],
            vec![-3.0, -1.0, 2.0],
            vec![-2.0, 1.0, 2.0],
        ];
        let mut b = vec![8.0, -11.0, -3.0];
        assert!(solve_linear(&mut m, &mut b));
        assert!((b[0] - 2.0).abs() < 1e-10);
        assert!((b[1] - 3.0).abs() < 1e-10);
        assert!((b[2] + 1.0).abs() < 1e-10);
    }

    #[test]
    fn residuals_zero_for_exact_fit() {
        let xs = [0.0, 1.0, 2.0];
        let ys = [1.0, 3.0, 5.0]; // y = 1 + 2x
        let c = polyfit(&xs, &ys, 1).unwrap();
        for r in residuals(&c, &xs, &ys) {
            assert!(r.abs() < 1e-10);
        }
    }
}
