//! Symbolic-regression performance model — paper §7.
//!
//! Replaces the GA loop with closed-form quadratics in `x = log10 n` for
//! each threshold: `T(n) = a·x² + b·x + c` (Eqs. 1–4). Two sources of
//! coefficients:
//!
//! * [`SymbolicModel::paper`] — the paper's exact rational coefficients;
//! * [`SymbolicModel::fit`] — degree-2 least squares over a GA tuning sweep
//!   on *this* machine (the honest reproduction path; the harness for
//!   Figures 7–11 regenerates it).
//!
//! The categorical gene is fixed to the LSD radix sort, as §7 does
//! ("we fixed the categorical choice to Block-Based LSD Radix Sort").

pub mod polyfit;

use crate::params::{ACode, Bounds, SortParams};

/// One quadratic threshold model `T(x) = a·x² + b·x + c`, `x = log10 n`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Quadratic {
    pub a: f64,
    pub b: f64,
    pub c: f64,
}

impl Quadratic {
    pub fn eval_x(&self, x: f64) -> f64 {
        (self.a * x + self.b) * x + self.c
    }

    pub fn eval_n(&self, n: usize) -> f64 {
        self.eval_x((n.max(1) as f64).log10())
    }

    /// Extremum location x* = −b / 2a (paper §7.4).
    pub fn vertex_x(&self) -> f64 {
        -self.b / (2.0 * self.a)
    }

    /// Dataset size at the extremum, n* = 10^x*.
    pub fn vertex_n(&self) -> f64 {
        10f64.powf(self.vertex_x())
    }

    /// Convex (a > 0) → minimum; concave (a < 0) → maximum.
    pub fn is_convex(&self) -> bool {
        self.a > 0.0
    }

    /// Least-squares fit from (n, value) observations.
    pub fn fit(points: &[(usize, f64)]) -> Option<Quadratic> {
        let xs: Vec<f64> = points.iter().map(|(n, _)| (*n as f64).log10()).collect();
        let ys: Vec<f64> = points.iter().map(|(_, v)| *v).collect();
        let c = polyfit::polyfit(&xs, &ys, 2)?;
        Some(Quadratic { a: c[2], b: c[1], c: c[0] })
    }

    /// R² of this model against (n, value) observations.
    pub fn r_squared(&self, points: &[(usize, f64)]) -> f64 {
        let xs: Vec<f64> = points.iter().map(|(n, _)| (*n as f64).log10()).collect();
        let ys: Vec<f64> = points.iter().map(|(_, v)| *v).collect();
        polyfit::r_squared(&[self.c, self.b, self.a], &xs, &ys)
    }

    /// Residuals against observations (paper §7.3).
    pub fn residuals(&self, points: &[(usize, f64)]) -> Vec<f64> {
        points.iter().map(|&(n, v)| v - self.eval_n(n)).collect()
    }
}

/// The four-threshold symbolic model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SymbolicModel {
    pub insertion: Quadratic,
    pub parallel_merge: Quadratic,
    pub fallback: Quadratic,
    pub tile: Quadratic,
}

impl SymbolicModel {
    /// The paper's Eqs. (1)–(4), exact rational coefficients.
    pub fn paper() -> SymbolicModel {
        SymbolicModel {
            // Eq. (1): T_ins
            insertion: Quadratic {
                a: 18_093_685.0 / 726_826.0,
                b: -227_830_214.0 / 693_565.0,
                c: 1_730_747_635.0 / 502_001.0,
            },
            // Eq. (2): T_par
            parallel_merge: Quadratic {
                a: -4_279_813_193.0 / 907_161.0,
                b: 79_199_394_278.0 / 983_501.0,
                c: -309_812_890_693.0 / 956_422.0,
            },
            // Eq. (3): T_np
            fallback: Quadratic {
                a: -3_680_680_444.0 / 890_339.0,
                b: 39_413_203_286.0 / 521_933.0,
                c: -219_719_696_809.0 / 785_367.0,
            },
            // Eq. (4): T_tile
            tile: Quadratic {
                a: 2_451_303_315.0 / 877_429.0,
                b: -7_878_849_997.0 / 184_645.0,
                c: 157_328_357_967.0 / 943_252.0,
            },
        }
    }

    /// Fit all four models from a GA tuning sweep: `(n, best_params)` pairs.
    pub fn fit(sweep: &[(usize, SortParams)]) -> Option<SymbolicModel> {
        let pick =
            |f: fn(&SortParams) -> usize| -> Vec<(usize, f64)> {
                sweep.iter().map(|(n, p)| (*n, f(p) as f64)).collect()
            };
        Some(SymbolicModel {
            insertion: Quadratic::fit(&pick(|p| p.insertion_threshold))?,
            parallel_merge: Quadratic::fit(&pick(|p| p.parallel_merge_threshold))?,
            fallback: Quadratic::fit(&pick(|p| p.fallback_threshold))?,
            tile: Quadratic::fit(&pick(|p| p.tile))?,
        })
    }

    /// Closed-form parameters for size `n` — the zero-overhead deployment
    /// path of §7.5. Values are clamped into the genome bounds; the
    /// algorithm code is fixed to LSD radix sort per §7.
    pub fn params_for(&self, n: usize) -> SortParams {
        let b = Bounds::default();
        let clamp = |q: &Quadratic, r: crate::params::GeneRange| -> usize {
            r.clamp_val(q.eval_n(n).round() as i64)
        };
        SortParams {
            insertion_threshold: clamp(&self.insertion, b.insertion),
            parallel_merge_threshold: clamp(&self.parallel_merge, b.parallel_merge),
            algorithm: ACode::Radix,
            fallback_threshold: clamp(&self.fallback, b.fallback),
            tile: clamp(&self.tile, b.tile),
            radix_width: crate::params::RadixWidth::W8,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_vertices_match_section_7_4() {
        let m = SymbolicModel::paper();
        // T_ins: convex, minimum at x* ≈ 6.60 (n ≈ 4e6).
        assert!(m.insertion.is_convex());
        assert!((m.insertion.vertex_x() - 6.60).abs() < 0.02, "{}", m.insertion.vertex_x());
        // T_par: concave, maximum at x* ≈ 8.54 (n ≈ 3.5e8).
        assert!(!m.parallel_merge.is_convex());
        assert!((m.parallel_merge.vertex_x() - 8.54).abs() < 0.02);
        // T_np: concave, maximum at x* ≈ 9.14 (n ≈ 1.4e9).
        assert!(!m.fallback.is_convex());
        assert!((m.fallback.vertex_x() - 9.14).abs() < 0.02);
        // T_tile: convex, minimum at x* ≈ 7.63 (n ≈ 4.3e7).
        assert!(m.tile.is_convex());
        assert!((m.tile.vertex_x() - 7.63).abs() < 0.02);
    }

    #[test]
    fn paper_model_params_reasonable_at_paper_sizes() {
        let m = SymbolicModel::paper();
        for n in [10_000_000usize, 100_000_000, 1_000_000_000] {
            let p = m.params_for(n);
            assert_eq!(p.algorithm, ACode::Radix);
            // Magnitudes in the same bands the GA found (§6).
            assert!(p.insertion_threshold >= 16 && p.insertion_threshold <= 100_000);
            assert!(p.tile >= 64 && p.tile <= 100_000);
            assert!(Bounds::default().validate(&p.to_genes()));
        }
    }

    #[test]
    fn fit_recovers_known_quadratic() {
        let truth = Quadratic { a: 100.0, b: -1200.0, c: 5000.0 };
        let points: Vec<(usize, f64)> = [1e5, 1e6, 1e7, 1e8, 1e9]
            .iter()
            .map(|&n| (n as usize, truth.eval_n(n as usize)))
            .collect();
        let fit = Quadratic::fit(&points).unwrap();
        assert!((fit.a - truth.a).abs() < 1e-6, "{fit:?}");
        assert!((fit.b - truth.b).abs() < 1e-5);
        assert!((fit.c - truth.c).abs() < 1e-4);
        assert!(fit.r_squared(&points) > 1.0 - 1e-9);
    }

    #[test]
    fn fit_model_from_sweep() {
        // Synthesise a sweep from the paper model, re-fit, compare curves.
        let m = SymbolicModel::paper();
        let sweep: Vec<(usize, SortParams)> = [1e6, 1e7, 1e8, 1e9, 1e10]
            .iter()
            .map(|&n| (n as usize, m.params_for(n as usize)))
            .collect();
        let refit = SymbolicModel::fit(&sweep).unwrap();
        for n in [3_000_000usize, 50_000_000, 2_000_000_000] {
            let a = m.params_for(n);
            let b = refit.params_for(n);
            // Clamping can move values near bounds; allow modest deviation.
            let rel = |x: usize, y: usize| {
                (x as f64 - y as f64).abs() / (x.max(y).max(1) as f64)
            };
            assert!(rel(a.insertion_threshold, b.insertion_threshold) < 0.25);
            assert!(rel(a.tile, b.tile) < 0.25);
        }
    }

    #[test]
    fn residuals_of_perfect_fit_are_zero() {
        let q = Quadratic { a: 1.0, b: 2.0, c: 3.0 };
        let pts: Vec<(usize, f64)> =
            [1e3, 1e5, 1e7].iter().map(|&n| (n as usize, q.eval_n(n as usize))).collect();
        for r in q.residuals(&pts) {
            assert!(r.abs() < 1e-9);
        }
    }

    #[test]
    fn eval_n_guards_zero() {
        let q = Quadratic { a: 1.0, b: 0.0, c: 0.0 };
        assert_eq!(q.eval_n(0), 0.0); // log10(1) = 0
    }
}
