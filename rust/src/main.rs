//! EvoSort launcher: `evosort <command> [flags]` (see `cli::USAGE`).

use evosort::cli::{commands, Args, USAGE};

fn main() {
    evosort::util::logging::init();
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&raw) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match args.command.as_str() {
        "sort" => commands::cmd_sort(&args),
        "tune" => commands::cmd_tune(&args),
        "pipeline" => commands::cmd_pipeline(&args),
        "symbolic" => commands::cmd_symbolic(&args),
        "repro" => commands::cmd_repro(&args),
        "bench" => commands::cmd_bench(&args),
        "serve" => commands::cmd_serve(&args),
        "trace" => commands::cmd_trace(&args),
        // Internal: the child-process side of `serve --shards N` (spawned by
        // the shard router, not meant for direct use).
        "shard-worker" => commands::cmd_shard_worker(&args),
        "info" => commands::cmd_info(&args),
        "" | "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
