//! The persistent parked executor: fork-join data parallelism without
//! per-call thread spawns.
//!
//! Every sort kernel in this crate is built from short scoped fork-join
//! sections (histogram per block, scatter per block, merge per run pair, …).
//! Spawning OS threads for each section costs ~10–20 µs per thread on Linux,
//! and one i64 radix sort crosses such a section up to ~20 times — under
//! service traffic of many mid-sized jobs the spawn overhead rivals the
//! sorting itself. An [`Executor`] replaces the spawns with a fixed set of
//! workers parked on a condvar:
//!
//! * [`Executor::new`] spawns `width - 1` parked workers once; every batch
//!   after that is queue-push + condvar-notify + claim. The **submitting
//!   thread always participates** in its own batch, which gives two
//!   properties for free: an executor of width 1 runs everything inline, and
//!   nested fork-join can never deadlock (the inner submitter makes progress
//!   on its own tasks even when every parked worker is busy).
//! * Batches are scoped: the submitter blocks until every task of its batch
//!   has finished, so tasks may borrow from the submitting stack frame
//!   (the lifetime is erased internally; see the safety notes on
//!   [`Batch`]).
//! * A panicking task does not poison the pool: the panic payload is
//!   captured, the rest of the batch still runs, and the payload is
//!   re-raised on the **submitting** thread once the batch is over. Sibling
//!   batches and later batches are unaffected.
//! * [`Executor::spawn_per_call`] is the measurement baseline: same API,
//!   but every batch spawns scoped OS threads exactly like the pre-executor
//!   code did. `evosort bench` runs the service workload in both modes and
//!   reports the ratio; [`thread_spawn_count`] lets tests assert that the
//!   steady-state sort path stops spawning entirely.

use std::ops::Range;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::AtomicU64;
use std::sync::{OnceLock, PoisonError};

// Synchronization primitives come from the feature-switched shim so the
// `loom_model` tests below can model-check the batch latch and the park/
// unpark hand-off; in a normal build these are exactly the std types.
use crate::util::sync::{thread, Arc, AtomicUsize, Condvar, Mutex, Ordering};

/// Process-wide count of OS threads ever spawned by this module: parked
/// workers at executor construction plus every scoped thread in
/// spawn-per-call mode. Steady-state tests assert this stays flat across
/// sort traffic. (Deliberately `std`, not the loom shim: loom atomics cannot
/// be `const`-constructed, and a process-global counter is metrics plumbing,
/// not part of the modeled protocol.)
static THREAD_SPAWNS: AtomicU64 = AtomicU64::new(0);

/// See [`THREAD_SPAWNS`].
pub fn thread_spawn_count() -> u64 {
    THREAD_SPAWNS.load(Ordering::Relaxed)
}

/// Execution backend selector (the `[service] exec` knob): `Parked` is the
/// persistent executor, `SpawnPerCall` the scoped-spawn baseline it replaced
/// (kept for A/B benchmarking and as a debugging escape hatch).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    #[default]
    Parked,
    SpawnPerCall,
}

impl ExecMode {
    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Parked => "parked",
            ExecMode::SpawnPerCall => "spawn",
        }
    }

    /// Parse a CLI/config name.
    pub fn parse(s: &str) -> Option<ExecMode> {
        match s {
            "parked" => Some(ExecMode::Parked),
            "spawn" | "spawn-per-call" => Some(ExecMode::SpawnPerCall),
            _ => None,
        }
    }
}

/// One fork-join batch: `total` independent tasks drained by index-claiming.
///
/// # Safety
///
/// `task` is a borrow of the submitter's closure with its lifetime erased to
/// `'static`. Soundness rests on two invariants:
///
/// 1. the submitter does not return from [`Executor::run_indexed`] until
///    `finished == total` (it parks on `done` even when the batch panicked),
///    so the closure outlives every dereference;
/// 2. a worker only dereferences `task` after claiming an index `< total`,
///    and an unfinished claimed index keeps the submitter parked.
///
/// Workers may hold the `Arc<Batch>` itself after completion (the struct
/// stays alive), but a post-completion [`Batch::claim`] returns `None` and
/// never touches `task`.
struct Batch {
    task: &'static (dyn Fn(usize) + Sync),
    total: usize,
    next: AtomicUsize,
    finished: AtomicUsize,
    /// First panic payload of the batch (re-raised on the submitter).
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    done_flag: Mutex<bool>,
    done: Condvar,
}

impl Batch {
    fn claim(&self) -> Option<usize> {
        // `fetch_add` hands every index out exactly once; indexes past the
        // end are harmless (usize wraparound would need 2^64 claims).
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        (i < self.total).then_some(i)
    }

    fn exhausted(&self) -> bool {
        self.next.load(Ordering::Relaxed) >= self.total
    }

    fn run_one(&self, i: usize) {
        // SAFETY: `i < total` (claimed), so the submitter is still parked
        // and the borrowed closure is alive (see the struct docs).
        if let Err(payload) = panic::catch_unwind(AssertUnwindSafe(|| (self.task)(i))) {
            let mut slot = self.panic.lock().unwrap_or_else(PoisonError::into_inner);
            slot.get_or_insert(payload);
        }
        // AcqRel: the final increment acquires every earlier finisher's
        // writes (release sequence on `finished`), and the mutex hand-off
        // below publishes them to the parked submitter.
        if self.finished.fetch_add(1, Ordering::AcqRel) + 1 == self.total {
            let mut flag = self.done_flag.lock().unwrap_or_else(PoisonError::into_inner);
            *flag = true;
            self.done.notify_all();
        }
    }
}

struct ExecQueue {
    batches: std::collections::VecDeque<Arc<Batch>>,
    shutdown: bool,
}

struct Inner {
    queue: Mutex<ExecQueue>,
    work_ready: Condvar,
}

enum Mode {
    Parked { inner: Arc<Inner>, workers: Vec<thread::JoinHandle<()>> },
    SpawnPerCall,
}

/// A fixed-width fork-join executor (see the module docs).
pub struct Executor {
    width: usize,
    /// OS threads this executor has spawned: fixed at construction in parked
    /// mode, growing per batch in spawn-per-call mode. The per-instance twin
    /// of [`thread_spawn_count`] (which is process-global and therefore only
    /// meaningful when nothing else is constructing executors concurrently).
    spawns: AtomicU64,
    mode: Mode,
}

impl Executor {
    /// Persistent executor of the given width: `width - 1` workers are
    /// spawned now and parked on a condvar; the submitting thread is the
    /// width'th lane of every batch it submits.
    pub fn new(width: usize) -> Executor {
        let width = width.max(1);
        let inner = Arc::new(Inner {
            queue: Mutex::new(ExecQueue {
                batches: std::collections::VecDeque::new(),
                shutdown: false,
            }),
            work_ready: Condvar::new(),
        });
        let workers = (0..width - 1)
            .map(|i| {
                THREAD_SPAWNS.fetch_add(1, Ordering::Relaxed);
                let inner = Arc::clone(&inner);
                thread::Builder::new()
                    .name(format!("evosort-exec-{i}"))
                    .spawn(move || worker_loop(inner))
                    .expect("spawn executor worker")
            })
            .collect();
        Executor {
            width,
            spawns: AtomicU64::new(width as u64 - 1),
            mode: Mode::Parked { inner, workers },
        }
    }

    /// The pre-executor baseline: identical API, but every batch spawns
    /// scoped OS threads. Kept so `evosort bench` can measure the executor
    /// against the exact behaviour it replaced.
    pub fn spawn_per_call(width: usize) -> Executor {
        Executor { width: width.max(1), spawns: AtomicU64::new(0), mode: Mode::SpawnPerCall }
    }

    /// The executor's thread budget (parked workers + the submitting lane).
    pub fn width(&self) -> usize {
        self.width
    }

    /// OS threads spawned by this executor so far (see the field docs).
    pub fn spawn_count(&self) -> u64 {
        self.spawns.load(Ordering::Relaxed)
    }

    /// Run `total` independent tasks `f(0..total)` and return once all have
    /// finished. Panics in tasks are re-raised here after the batch drains.
    pub fn run_indexed<F>(&self, total: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        self.run_batch_dyn(total, &f);
    }

    fn run_batch_dyn(&self, total: usize, f: &(dyn Fn(usize) + Sync)) {
        if total == 0 {
            return;
        }
        if total == 1 || self.width == 1 {
            for i in 0..total {
                f(i);
            }
            return;
        }
        match &self.mode {
            Mode::SpawnPerCall => {
                let lanes = self.width.min(total);
                THREAD_SPAWNS.fetch_add(lanes as u64, Ordering::Relaxed);
                self.spawns.fetch_add(lanes as u64, Ordering::Relaxed);
                // Same panic semantics as parked mode: every task runs, the
                // first payload is re-raised on the submitter — so an A/B
                // run sees identical side effects from a panicking batch.
                let panic_slot: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
                std::thread::scope(|scope| {
                    for lane in 0..lanes {
                        let panic_slot = &panic_slot;
                        scope.spawn(move || {
                            let mut i = lane;
                            while i < total {
                                let r = panic::catch_unwind(AssertUnwindSafe(|| f(i)));
                                if let Err(payload) = r {
                                    let mut slot = panic_slot
                                        .lock()
                                        .unwrap_or_else(PoisonError::into_inner);
                                    slot.get_or_insert(payload);
                                }
                                i += lanes;
                            }
                        });
                    }
                });
                let payload = panic_slot.into_inner().unwrap_or_else(PoisonError::into_inner);
                if let Some(payload) = payload {
                    panic::resume_unwind(payload);
                }
            }
            Mode::Parked { inner, workers } => {
                // SAFETY: lifetime erasure only — `run_batch_dyn` does not
                // return until every task has finished (the park below), so
                // the borrow outlives all uses. See `Batch` docs.
                let task = unsafe { erase_task_lifetime(f) };
                let batch = Arc::new(Batch {
                    task,
                    total,
                    next: AtomicUsize::new(0),
                    finished: AtomicUsize::new(0),
                    panic: Mutex::new(None),
                    done_flag: Mutex::new(false),
                    done: Condvar::new(),
                });
                {
                    let mut q = inner.queue.lock().unwrap_or_else(PoisonError::into_inner);
                    q.batches.push_back(Arc::clone(&batch));
                }
                // Wake at most as many workers as there are tasks beyond the
                // submitter's own lane (a woken worker with nothing to claim
                // just re-parks, but not waking it at all is cheaper).
                for _ in 0..(total - 1).min(workers.len()) {
                    inner.work_ready.notify_one();
                }
                // The submitter is a full participant in its own batch.
                while let Some(i) = batch.claim() {
                    batch.run_one(i);
                }
                let mut flag = batch.done_flag.lock().unwrap_or_else(PoisonError::into_inner);
                while !*flag {
                    flag = batch.done.wait(flag).unwrap_or_else(PoisonError::into_inner);
                }
                drop(flag);
                let payload = batch.panic.lock().unwrap_or_else(PoisonError::into_inner).take();
                if let Some(payload) = payload {
                    panic::resume_unwind(payload);
                }
            }
        }
    }

    /// Run `f(index, item)` once per item, moving each item into its task.
    /// The workhorse behind the chunk/zip/view helpers: items are typically
    /// `&mut` sub-slices carved by the caller, so every task owns disjoint
    /// data.
    pub fn run_consume<I, F>(&self, items: Vec<I>, f: F)
    where
        I: Send,
        F: Fn(usize, I) + Sync,
    {
        let total = items.len();
        if total == 0 {
            return;
        }
        let mut slots: Vec<Option<I>> = items.into_iter().map(Some).collect();
        let list = SlotList::new(&mut slots);
        self.run_batch_dyn(total, &|i| {
            // SAFETY: index `i` is claimed exactly once per batch, so this
            // element is taken by exactly one task.
            let item = unsafe { list.take(i) }.expect("item taken once");
            f(i, item);
        });
    }

    /// [`run_consume`](Self::run_consume) that also collects one result per
    /// item, returned in item order.
    pub fn run_consume_map<I, R, F>(&self, items: Vec<I>, f: F) -> Vec<R>
    where
        I: Send,
        R: Send,
        F: Fn(usize, I) -> R + Sync,
    {
        let total = items.len();
        if total == 0 {
            return Vec::new();
        }
        let mut slots: Vec<Option<I>> = items.into_iter().map(Some).collect();
        let mut results: Vec<Option<R>> = (0..total).map(|_| None).collect();
        {
            let in_list = SlotList::new(&mut slots);
            let out_list = SlotList::new(&mut results);
            self.run_batch_dyn(total, &|i| {
                // SAFETY: as in `run_consume` — one claimant per index, for
                // both the input take and the output put.
                let item = unsafe { in_list.take(i) }.expect("item taken once");
                let r = f(i, item);
                // SAFETY: one claimant per index, as above.
                unsafe { out_list.put(i, r) };
            });
        }
        results.into_iter().map(|r| r.expect("task completed")).collect()
    }

    /// Run `tasks` indexed jobs and return their results in task order —
    /// the executor-backed form of [`super::parallel_map`].
    pub fn run_map<R, F>(&self, tasks: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        if tasks == 0 {
            return Vec::new();
        }
        let mut results: Vec<Option<R>> = (0..tasks).map(|_| None).collect();
        {
            let out_list = SlotList::new(&mut results);
            self.run_batch_dyn(tasks, &|i| {
                let r = f(i);
                // SAFETY: one claimant per index.
                unsafe { out_list.put(i, r) };
            });
        }
        results.into_iter().map(|r| r.expect("task completed")).collect()
    }

    /// Process near-equal contiguous chunks of `data` (at most `parts`) in
    /// parallel — the executor-backed form of [`super::parallel_for_chunks`].
    pub fn run_chunks<T, F>(&self, data: &mut [T], parts: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let ranges = super::partition_even(data.len(), parts.max(1));
        if ranges.len() <= 1 {
            if !data.is_empty() {
                f(0, data);
            }
            return;
        }
        let chunks = carve_mut(data, &ranges);
        self.run_consume(chunks, f);
    }

    /// Process pairs of equally-partitioned mutable slices in parallel — the
    /// executor-backed form of [`super::parallel_for_zip`].
    pub fn run_zip<T, U, F>(&self, a: &mut [T], b: &mut [U], bounds: &[Range<usize>], f: F)
    where
        T: Send,
        U: Send,
        F: Fn(usize, &mut [T], &mut [U]) + Sync,
    {
        assert_eq!(a.len(), b.len(), "zip slices must match");
        if bounds.is_empty() {
            return;
        }
        if bounds.len() == 1 {
            f(0, a, b);
            return;
        }
        let pairs: Vec<(&mut [T], &mut [U])> =
            carve_mut(a, bounds).into_iter().zip(carve_mut(b, bounds)).collect();
        self.run_consume(pairs, |i, (ca, cb)| f(i, ca, cb));
    }
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mode = match &self.mode {
            Mode::Parked { .. } => "parked",
            Mode::SpawnPerCall => "spawn-per-call",
        };
        f.debug_struct("Executor")
            .field("width", &self.width)
            .field("mode", &mode)
            .field("spawns", &self.spawn_count())
            .finish()
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        if let Mode::Parked { inner, workers } = &mut self.mode {
            {
                let mut q = inner.queue.lock().unwrap_or_else(PoisonError::into_inner);
                q.shutdown = true;
            }
            inner.work_ready.notify_all();
            for w in workers.drain(..) {
                let _ = w.join();
            }
        }
    }
}

fn worker_loop(inner: Arc<Inner>) {
    loop {
        let batch = {
            let mut q = inner.queue.lock().unwrap_or_else(PoisonError::into_inner);
            loop {
                // Retire batches whose every index has been claimed; their
                // completion is tracked by the batch latch, not the queue.
                while q.batches.front().is_some_and(|b| b.exhausted()) {
                    q.batches.pop_front();
                }
                if let Some(b) = q.batches.front() {
                    break Arc::clone(b);
                }
                if q.shutdown {
                    return;
                }
                q = inner.work_ready.wait(q).unwrap_or_else(PoisonError::into_inner);
            }
        };
        while let Some(i) = batch.claim() {
            batch.run_one(i);
        }
    }
}

/// Raw indexed access into a `Vec<Option<T>>` for one batch: each index is
/// touched by exactly one claimant (the batch's `fetch_add` hands indices
/// out uniquely), so element accesses never alias. Pointer-based so no
/// `&mut Vec` is ever formed concurrently.
struct SlotList<T> {
    ptr: *mut Option<T>,
    len: usize,
}

// SAFETY: the list is a pointer into a `Vec<Option<T>>` owned by the parked
// submitter; sending it to a worker moves elements of type `T: Send` across
// threads, nothing else.
unsafe impl<T: Send> Send for SlotList<T> {}
// SAFETY: shared access is per-element disjoint — the batch's `fetch_add`
// hands each index to exactly one claimant, so two threads never touch the
// same slot (see `take`/`put` contracts). `T: Send` suffices because no `&T`
// is ever shared across threads, only whole elements moved.
unsafe impl<T: Send> Sync for SlotList<T> {}

impl<T> SlotList<T> {
    fn new(slots: &mut Vec<Option<T>>) -> SlotList<T> {
        SlotList { ptr: slots.as_mut_ptr(), len: slots.len() }
    }

    /// # Safety
    /// `i` must be accessed by exactly one task of the batch, and the backing
    /// vector must outlive the batch (guaranteed: the submitter owns it and
    /// parks until the batch completes).
    unsafe fn take(&self, i: usize) -> Option<T> {
        assert!(i < self.len);
        // SAFETY: in-bounds (asserted above); exclusive by the caller's
        // one-claimant-per-index contract; backing vec alive per the contract.
        unsafe { (*self.ptr.add(i)).take() }
    }

    /// # Safety
    /// As [`take`](Self::take).
    unsafe fn put(&self, i: usize, value: T) {
        assert!(i < self.len);
        // SAFETY: as in `take`.
        unsafe { *self.ptr.add(i) = Some(value) };
    }
}

/// Erase the lifetime of a batch closure borrow.
///
/// # Safety
/// The caller must not return (or otherwise invalidate `f`) until the batch
/// built on the result has fully completed — see the [`Batch`] safety notes.
unsafe fn erase_task_lifetime(f: &(dyn Fn(usize) + Sync)) -> &'static (dyn Fn(usize) + Sync) {
    // SAFETY: a pure lifetime transmute on a fat reference (same layout both
    // sides); the caller guarantees the referent outlives every use.
    let erased: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
    erased
}

/// Carve a mutable slice into the given contiguous, in-order ranges (the
/// alignment-sensitive split_at_mut walk every kernel shares).
pub(crate) fn carve_mut<'a, T>(data: &'a mut [T], ranges: &[Range<usize>]) -> Vec<&'a mut [T]> {
    let mut out: Vec<&mut [T]> = Vec::with_capacity(ranges.len());
    let mut rest = data;
    let mut consumed = 0usize;
    for r in ranges {
        let (head, tail) = rest.split_at_mut(r.end - consumed);
        consumed = r.end;
        out.push(head);
        rest = tail;
    }
    out
}

/// The process-wide default executor, sized to the hardware. Library entry
/// points that are not handed an explicit executor (the free functions in
/// [`super`], `AdaptiveSorter::new`, direct kernel calls) share it; the sort
/// service builds its own so a deployment's width follows its
/// `workers × sort_threads` budget.
pub fn global() -> &'static Arc<Executor> {
    static GLOBAL: OnceLock<Arc<Executor>> = OnceLock::new();
    GLOBAL.get_or_init(|| Arc::new(Executor::new(crate::util::default_threads())))
}

#[cfg(all(test, not(feature = "loom")))]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn run_map_ordered_results() {
        let exec = Executor::new(4);
        let out = exec.run_map(100, |i| i * i);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn width_one_runs_inline() {
        let exec = Executor::new(1);
        let main_id = std::thread::current().id();
        let ids = exec.run_map(8, |_| std::thread::current().id());
        assert!(ids.iter().all(|id| *id == main_id), "width-1 executor must run inline");
    }

    #[test]
    fn nested_fork_join_completes() {
        // Inner batches submitted from worker threads must make progress
        // even when every parked worker is already busy on the outer batch.
        let exec = Executor::new(2);
        let outer = exec.run_map(4, |i| {
            let inner: usize = exec.run_map(4, |j| i * 10 + j).into_iter().sum();
            inner
        });
        for (i, v) in outer.iter().enumerate() {
            assert_eq!(*v, i * 40 + 6, "outer task {i}");
        }
    }

    #[test]
    fn panic_propagates_without_poisoning_the_pool() {
        let exec = Executor::new(3);
        let survivors = AtomicUsize::new(0);
        let result = panic::catch_unwind(AssertUnwindSafe(|| {
            exec.run_indexed(8, |i| {
                if i == 3 {
                    panic!("task 3 boom");
                }
                survivors.fetch_add(1, Ordering::Relaxed);
            });
        }));
        assert!(result.is_err(), "the batch panic must reach the submitter");
        // Sibling tasks of the panicking batch still ran.
        assert_eq!(survivors.load(Ordering::Relaxed), 7);
        // The pool is not poisoned: later batches run normally.
        let out = exec.run_map(16, |i| i + 1);
        assert_eq!(out.iter().sum::<usize>(), (1..=16).sum::<usize>());
    }

    #[test]
    fn panicking_batch_does_not_sink_a_sibling_batch() {
        let exec = Arc::new(Executor::new(4));
        let exec2 = Arc::clone(&exec);
        let sibling =
            std::thread::spawn(move || exec2.run_map(64, |i| i).into_iter().sum::<usize>());
        let result = panic::catch_unwind(AssertUnwindSafe(|| {
            exec.run_indexed(16, |i| {
                if i % 2 == 0 {
                    panic!("even tasks panic");
                }
            });
        }));
        assert!(result.is_err());
        assert_eq!(sibling.join().expect("sibling batch unaffected"), (0..64).sum::<usize>());
    }

    #[test]
    fn oversubscription_more_tasks_than_workers() {
        let exec = Executor::new(3);
        let counter = AtomicUsize::new(0);
        exec.run_indexed(500, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn drop_while_parked_shuts_down() {
        let exec = Executor::new(8);
        // Workers are parked (nothing submitted); drop must join them all
        // without hanging.
        drop(exec);
        // And after serving work, too.
        let exec = Executor::new(4);
        exec.run_indexed(16, |_| {});
        drop(exec);
    }

    #[test]
    fn parked_mode_never_spawns_after_construction() {
        // The per-executor counter is used (the process-global one is bumped
        // by other tests constructing executors concurrently).
        let exec = Executor::new(4);
        exec.run_indexed(8, |_| {}); // warm
        assert_eq!(exec.spawn_count(), 3, "width 4 = 3 parked workers + the submitter");
        for _ in 0..50 {
            exec.run_indexed(32, |_| {});
            let _ = exec.run_map(16, |i| i);
        }
        assert_eq!(exec.spawn_count(), 3, "parked batches must not spawn");
    }

    #[test]
    fn spawn_mode_panic_parity_with_parked() {
        // Both modes run every task and re-raise the first panic on the
        // submitter, so A/B runs see identical batch side effects.
        let exec = Executor::spawn_per_call(3);
        let survivors = AtomicUsize::new(0);
        let result = panic::catch_unwind(AssertUnwindSafe(|| {
            exec.run_indexed(8, |i| {
                if i == 3 {
                    panic!("boom");
                }
                survivors.fetch_add(1, Ordering::Relaxed);
            });
        }));
        assert!(result.is_err());
        assert_eq!(survivors.load(Ordering::Relaxed), 7, "all sibling tasks still ran");
    }

    #[test]
    fn spawn_per_call_mode_counts_spawns() {
        let exec = Executor::spawn_per_call(4);
        assert_eq!(exec.spawn_count(), 0, "no parked workers in baseline mode");
        exec.run_indexed(8, |_| {});
        exec.run_indexed(8, |_| {});
        assert_eq!(exec.spawn_count(), 8, "baseline mode spawns per batch (4 lanes x 2)");
    }

    #[test]
    fn run_chunks_and_zip_parity() {
        let exec = Executor::new(4);
        let mut data = vec![0u64; 10_000];
        exec.run_chunks(&mut data, 8, |idx, chunk| {
            for x in chunk.iter_mut() {
                *x = idx as u64 + 1;
            }
        });
        assert!(data.iter().all(|&x| x >= 1));

        let mut a: Vec<u32> = (0..1000).collect();
        let mut b = vec![0u32; 1000];
        let bounds = super::super::partition_even(1000, 4);
        exec.run_zip(&mut a, &mut b, &bounds, |_, ca, cb| {
            for (x, y) in ca.iter().zip(cb.iter_mut()) {
                *y = *x * 2;
            }
        });
        for i in 0..1000u32 {
            assert_eq!(b[i as usize], i * 2);
        }
    }

    #[test]
    fn run_consume_map_moves_items_and_orders_results() {
        let exec = Executor::new(3);
        let items: Vec<String> = (0..40).map(|i| format!("item-{i}")).collect();
        let out = exec.run_consume_map(items, |i, s| (i, s.len()));
        for (i, (idx, len)) in out.iter().enumerate() {
            assert_eq!(*idx, i);
            assert_eq!(*len, format!("item-{i}").len());
        }
    }

    #[test]
    fn exec_mode_parse_roundtrip() {
        assert_eq!(ExecMode::parse("parked"), Some(ExecMode::Parked));
        assert_eq!(ExecMode::parse("spawn"), Some(ExecMode::SpawnPerCall));
        assert_eq!(ExecMode::parse("spawn-per-call"), Some(ExecMode::SpawnPerCall));
        assert_eq!(ExecMode::parse("nope"), None);
        assert_eq!(ExecMode::default(), ExecMode::Parked);
        assert_eq!(ExecMode::Parked.name(), "parked");
    }
}

/// Loom models for the batch latch, the claim protocol, and the panic
/// hand-off. Run with:
///
/// ```text
/// cargo test --features loom --lib -- loom_model
/// ```
///
/// Each body constructs its own tiny executor (width 2 = one parked worker
/// plus the submitter) so the model stays within loom's thread budget; the
/// vendored shim replays each body as a bounded stress loop instead (see
/// `rust/vendor/loom`).
#[cfg(all(test, feature = "loom"))]
mod loom_model {
    use super::*;

    /// The done-latch: the submitter must observe every task's effects after
    /// `run_indexed` returns, under every interleaving of claim order and
    /// finish order (the AcqRel on `finished` plus the mutex hand-off is what
    /// makes the Relaxed increments below visible).
    #[test]
    fn batch_latch_publishes_every_task_effect() {
        loom::model(|| {
            let exec = Executor::new(2);
            let hits = AtomicUsize::new(0);
            exec.run_indexed(3, |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed), 3, "every task ran exactly once");
        });
    }

    /// The park/unpark hand-off: with exactly one task per lane, whichever
    /// side finishes last must wake the submitter — the `done_flag` mutex
    /// guarantees the flag store and the condvar wait cannot miss each other.
    #[test]
    fn submitter_park_cannot_miss_the_last_finisher() {
        loom::model(|| {
            let exec = Executor::new(2);
            let hits = AtomicUsize::new(0);
            exec.run_indexed(2, |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed), 2);
        });
    }

    /// Panic capture and re-raise: under any interleaving, a panicking task
    /// still counts toward the latch (the submitter is released, not hung),
    /// the sibling task runs, the payload surfaces on the submitter, and the
    /// pool survives for the next batch.
    #[test]
    fn panic_reraised_on_submitter_without_hanging_or_poisoning() {
        loom::model(|| {
            let exec = Executor::new(2);
            let survivors = AtomicUsize::new(0);
            let result = panic::catch_unwind(AssertUnwindSafe(|| {
                exec.run_indexed(2, |i| {
                    if i == 0 {
                        panic!("model boom");
                    }
                    survivors.fetch_add(1, Ordering::Relaxed);
                });
            }));
            assert!(result.is_err(), "the panic must reach the submitter");
            assert_eq!(survivors.load(Ordering::Relaxed), 1, "the sibling task still ran");
            let hits = AtomicUsize::new(0);
            exec.run_indexed(2, |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed), 2, "pool usable after the panic");
        });
    }
}
