//! A persistent worker pool with a bounded job queue.
//!
//! Used by the coordinator's sort service: long-lived worker threads pull
//! boxed jobs from a shared queue; a bounded queue provides backpressure so a
//! flood of submissions cannot exhaust memory. Data-parallel inner loops use
//! the scoped helpers in [`super`] instead — this pool is for *task*
//! parallelism (whole sort jobs, tuning runs).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<QueueState>,
    /// Signalled when a job is pushed (workers wait) …
    job_ready: Condvar,
    /// … and when a slot frees up (submitters wait — backpressure).
    slot_ready: Condvar,
    /// Signalled when in-flight count returns to zero.
    idle: Condvar,
    capacity: usize,
    in_flight: AtomicUsize,
}

struct QueueState {
    queue: VecDeque<Job>,
    shutdown: bool,
}

/// A fixed-size pool of worker threads executing boxed jobs FIFO.
pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Create a pool with `threads` workers and a job queue bounded at
    /// `capacity` pending jobs (submissions block when full).
    pub fn with_capacity(threads: usize, capacity: usize) -> Self {
        let threads = threads.max(1);
        let queue = Arc::new(Queue {
            jobs: Mutex::new(QueueState { queue: VecDeque::new(), shutdown: false }),
            job_ready: Condvar::new(),
            slot_ready: Condvar::new(),
            idle: Condvar::new(),
            capacity: capacity.max(1),
            in_flight: AtomicUsize::new(0),
        });
        let workers = (0..threads)
            .map(|i| {
                let q = Arc::clone(&queue);
                std::thread::Builder::new()
                    .name(format!("evosort-worker-{i}"))
                    .spawn(move || worker_loop(q))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { queue, workers }
    }

    pub fn new(threads: usize) -> Self {
        Self::with_capacity(threads, 1024)
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Number of jobs queued or running.
    pub fn in_flight(&self) -> usize {
        self.queue.in_flight.load(Ordering::SeqCst)
    }

    /// Submit a job; blocks while the queue is at capacity (backpressure).
    /// Returns `false` if the pool is shutting down.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) -> bool {
        let mut state = self.queue.jobs.lock().unwrap();
        loop {
            if state.shutdown {
                return false;
            }
            if state.queue.len() < self.queue.capacity {
                break;
            }
            state = self.queue.slot_ready.wait(state).unwrap();
        }
        self.queue.in_flight.fetch_add(1, Ordering::SeqCst);
        state.queue.push_back(Box::new(f));
        drop(state);
        self.queue.job_ready.notify_one();
        true
    }

    /// Block until every submitted job has finished. Parks on the `idle`
    /// condvar — zero CPU while waiting (workers notify when the in-flight
    /// count returns to zero).
    pub fn wait_idle(&self) {
        let mut state = self.queue.jobs.lock().unwrap();
        while self.queue.in_flight.load(Ordering::SeqCst) > 0 {
            state = self.queue.idle.wait(state).unwrap();
        }
        drop(state);
    }

    /// Bounded [`wait_idle`](Self::wait_idle): parks for at most `timeout`,
    /// returning `true` if the pool went idle in time.
    pub fn wait_idle_timeout(&self, timeout: std::time::Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        let mut state = self.queue.jobs.lock().unwrap();
        while self.queue.in_flight.load(Ordering::SeqCst) > 0 {
            let Some(remaining) = deadline.checked_duration_since(std::time::Instant::now())
            else {
                return false;
            };
            let (next, timed_out) = self.queue.idle.wait_timeout(state, remaining).unwrap();
            state = next;
            if timed_out.timed_out() && self.queue.in_flight.load(Ordering::SeqCst) > 0 {
                return false;
            }
        }
        true
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut state = self.queue.jobs.lock().unwrap();
            state.shutdown = true;
        }
        self.queue.job_ready.notify_all();
        self.queue.slot_ready.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(q: Arc<Queue>) {
    loop {
        let job = {
            let mut state = q.jobs.lock().unwrap();
            loop {
                if let Some(job) = state.queue.pop_front() {
                    q.slot_ready.notify_one();
                    break job;
                }
                if state.shutdown {
                    return;
                }
                state = q.job_ready.wait(state).unwrap();
            }
        };
        // Run outside the lock. A panicking job poisons nothing because the
        // queue lock is released; catch to keep the worker alive.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
        if result.is_err() {
            crate::log_warn!("pool job panicked; worker continues");
        }
        if q.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _guard = q.jobs.lock().unwrap();
            q.idle.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            assert!(pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            }));
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(pool.in_flight(), 0);
    }

    #[test]
    fn backpressure_bounded_queue() {
        // Capacity 1, single slow worker: submissions must still all complete.
        let pool = ThreadPool::with_capacity(1, 1);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn survives_panicking_job() {
        let pool = ThreadPool::new(2);
        pool.submit(|| panic!("boom"));
        let done = Arc::new(AtomicU64::new(0));
        let d = Arc::clone(&done);
        pool.submit(move || {
            d.store(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn rejects_after_shutdown() {
        let pool = ThreadPool::new(1);
        pool.wait_idle();
        drop(pool);
        // Can't submit after drop by construction (pool moved); this test
        // documents the contract via a fresh pool's shutdown flag instead.
        let pool2 = ThreadPool::new(1);
        {
            let mut st = pool2.queue.jobs.lock().unwrap();
            st.shutdown = true;
        }
        assert!(!pool2.submit(|| {}));
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(3);
        pool.wait_idle(); // must not deadlock
    }

    #[test]
    fn wait_idle_timeout_bounds_the_park() {
        let pool = ThreadPool::new(1);
        assert!(pool.wait_idle_timeout(std::time::Duration::from_millis(5)), "idle pool");
        pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(120)));
        assert!(
            !pool.wait_idle_timeout(std::time::Duration::from_millis(5)),
            "busy pool must time out"
        );
        assert!(pool.wait_idle_timeout(std::time::Duration::from_secs(30)), "then drains");
        assert_eq!(pool.in_flight(), 0);
    }
}
