//! Parallel-execution substrate, built on `std::thread` only.
//!
//! The paper's implementation relies on Numba's `prange` (an OpenMP-style
//! parallel-for over chunks with thread-local state). No `rayon` crate is
//! available in the offline build environment, so this module provides the
//! equivalent primitives from scratch:
//!
//! * [`executor::Executor`] — the **persistent parked executor**: a fixed
//!   set of workers parked on a condvar running scoped fork-join batches
//!   (`run_chunks`, `run_map`, `run_zip`, `run_indexed`). Since PR 5 this is
//!   how every data-parallel section in the crate executes — batches cost a
//!   queue push and a condvar notify instead of OS thread spawns.
//! * [`parallel_for_chunks`] / [`parallel_map`] / [`parallel_for_zip`] —
//!   the historical free-function API, now thin wrappers over the
//!   process-wide [`executor::global`] executor. Existing callers keep their
//!   signatures and stop spawning.
//! * [`partition_even`] — the chunk geometry helper shared by the sorts.
//! * [`pool::ThreadPool`] — a persistent worker pool with a job queue, used
//!   by the coordinator's sort service (long-lived jobs, backpressure).
//!   The pool is *task* parallelism (whole sort jobs); the executor is
//!   *data* parallelism inside one job.
//!
//! The `threads` parameter on the free functions still controls the chunk
//! geometry (how many tasks a slice is cut into, `<= 1` forcing the
//! sequential path); actual concurrency is bounded by the executor width.

pub mod executor;
pub mod pool;

pub use executor::{global, thread_spawn_count, ExecMode, Executor};
pub(crate) use executor::carve_mut;

use std::ops::Range;

/// Split `len` items into at most `parts` contiguous ranges of near-equal
/// size (the first `len % parts` ranges get one extra element). Never returns
/// empty ranges; may return fewer than `parts` ranges when `len < parts`.
pub fn partition_even(len: usize, parts: usize) -> Vec<Range<usize>> {
    if len == 0 || parts == 0 {
        return Vec::new();
    }
    let parts = parts.min(len);
    let base = len / parts;
    let extra = len % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let sz = base + usize::from(i < extra);
        out.push(start..start + sz);
        start += sz;
    }
    debug_assert_eq!(start, len);
    out
}

/// Run `f(chunk_index, chunk)` over near-equal contiguous chunks of `data`
/// (at most `threads` chunks) on the process-wide parked executor.
/// Sequential fallback when `threads <= 1` or there is only one chunk.
pub fn parallel_for_chunks<T, F>(data: &mut [T], threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    global().run_chunks(data, threads, f)
}

/// Run `tasks` independent indexed jobs on the process-wide parked executor
/// and return their results in task order. `threads` still bounds
/// concurrency (parity with the historical spawning implementation): tasks
/// are distributed over at most `threads` strided lanes, each one executor
/// task, so at most `threads` run at once whatever the executor's width.
pub fn parallel_map<R, F>(tasks: usize, threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    if tasks == 0 {
        return Vec::new();
    }
    let lanes = threads.max(1).min(tasks);
    if lanes == 1 {
        return (0..tasks).map(f).collect();
    }
    let mut slots: Vec<Option<R>> = (0..tasks).map(|_| None).collect();
    {
        // Distribute result slots to lanes in the same strided pattern as
        // the task indices, so each lane writes only its own slots.
        let mut slot_refs: Vec<(usize, &mut Option<R>)> = slots.iter_mut().enumerate().collect();
        let mut per_lane: Vec<Vec<(usize, &mut Option<R>)>> =
            (0..lanes).map(|_| Vec::new()).collect();
        for (i, slot) in slot_refs.drain(..) {
            per_lane[i % lanes].push((i, slot));
        }
        let f = &f;
        global().run_consume(per_lane, |_, lane| {
            for (i, slot) in lane {
                *slot = Some(f(i));
            }
        });
    }
    slots.into_iter().map(|s| s.expect("task completed")).collect()
}

/// Process pairs `(a_chunk, b_chunk)` of two equally-partitioned mutable
/// slices in parallel — used by merge passes that read one buffer and write
/// the other with matching geometry.
pub fn parallel_for_zip<T, U, F>(a: &mut [T], b: &mut [U], bounds: &[Range<usize>], f: F)
where
    T: Send,
    U: Send,
    F: Fn(usize, &mut [T], &mut [U]) + Sync,
{
    global().run_zip(a, b, bounds, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_even_covers_everything() {
        for len in [0usize, 1, 7, 100, 101] {
            for parts in [1usize, 2, 3, 8, 200] {
                let rs = partition_even(len, parts);
                let total: usize = rs.iter().map(|r| r.len()).sum();
                assert_eq!(total, len, "len={len} parts={parts}");
                for w in rs.windows(2) {
                    assert_eq!(w[0].end, w[1].start, "contiguous");
                }
                for r in &rs {
                    assert!(!r.is_empty(), "no empty ranges");
                }
                if !rs.is_empty() {
                    let sizes: Vec<usize> = rs.iter().map(|r| r.len()).collect();
                    let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                    assert!(mx - mn <= 1, "balanced: {sizes:?}");
                }
            }
        }
    }

    #[test]
    fn parallel_for_chunks_touches_all() {
        let mut data = vec![0u64; 10_000];
        parallel_for_chunks(&mut data, 8, |idx, chunk| {
            for x in chunk.iter_mut() {
                *x = idx as u64 + 1;
            }
        });
        assert!(data.iter().all(|&x| x >= 1));
        // Chunk 0 exists and later chunks too.
        assert_eq!(data[0], 1);
        assert!(*data.last().unwrap() >= 1);
    }

    #[test]
    fn parallel_for_chunks_sequential_fallback() {
        let mut data = vec![1i32; 5];
        parallel_for_chunks(&mut data, 1, |_, chunk| {
            for x in chunk.iter_mut() {
                *x += 1;
            }
        });
        assert_eq!(data, vec![2; 5]);
        let mut empty: Vec<i32> = vec![];
        parallel_for_chunks(&mut empty, 4, |_, _| panic!("no chunks for empty data"));
    }

    #[test]
    fn parallel_map_ordered_results() {
        let out = parallel_map(100, 7, |i| i * i);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn parallel_map_zero_tasks() {
        let out: Vec<u32> = parallel_map(0, 4, |_| unreachable!());
        assert!(out.is_empty());
    }

    #[test]
    fn parallel_map_single_thread_is_sequential() {
        let main_id = std::thread::current().id();
        let ids = parallel_map(6, 1, |_| std::thread::current().id());
        assert!(ids.iter().all(|id| *id == main_id));
    }

    #[test]
    fn parallel_for_zip_matched_geometry() {
        let mut a: Vec<u32> = (0..1000).collect();
        let mut b = vec![0u32; 1000];
        let bounds = partition_even(1000, 4);
        parallel_for_zip(&mut a, &mut b, &bounds, |_, ca, cb| {
            for (x, y) in ca.iter().zip(cb.iter_mut()) {
                *y = *x * 2;
            }
        });
        for i in 0..1000u32 {
            assert_eq!(b[i as usize], i * 2);
        }
    }
}
