//! Parallel-execution substrate, built on `std::thread` only.
//!
//! The paper's implementation relies on Numba's `prange` (an OpenMP-style
//! parallel-for over chunks with thread-local state). No `rayon` crate is
//! available in the offline build environment, so this module provides the
//! equivalent primitives from scratch:
//!
//! * [`parallel_for_chunks`] — split a mutable slice into contiguous chunks
//!   and process each on its own OS thread (scoped; zero `unsafe`).
//! * [`parallel_map`] — run an indexed task set across a bounded number of
//!   threads and collect per-task results (used for thread-local histograms).
//! * [`partition_even`] — the chunk geometry helper shared by the sorts.
//! * [`pool::ThreadPool`] — a persistent worker pool with a job queue, used
//!   by the coordinator's sort service (long-lived jobs, backpressure).
//!
//! Scoped spawning costs ~10–20 µs per thread on Linux; the sorting hot paths
//! only cross into these helpers for chunks of ≥10⁴ elements, so the spawn
//! cost is noise relative to the work (measured in benches/micro_kernels.rs).

pub mod pool;

use std::ops::Range;

/// Split `len` items into at most `parts` contiguous ranges of near-equal
/// size (the first `len % parts` ranges get one extra element). Never returns
/// empty ranges; may return fewer than `parts` ranges when `len < parts`.
pub fn partition_even(len: usize, parts: usize) -> Vec<Range<usize>> {
    if len == 0 || parts == 0 {
        return Vec::new();
    }
    let parts = parts.min(len);
    let base = len / parts;
    let extra = len % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let sz = base + usize::from(i < extra);
        out.push(start..start + sz);
        start += sz;
    }
    debug_assert_eq!(start, len);
    out
}

/// Run `f(chunk_index, chunk)` over near-equal contiguous chunks of `data`,
/// one OS thread per chunk (bounded by `threads`). Sequential fallback when
/// `threads <= 1` or there is only one chunk.
pub fn parallel_for_chunks<T, F>(data: &mut [T], threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let ranges = partition_even(data.len(), threads.max(1));
    if ranges.len() <= 1 {
        if !data.is_empty() {
            f(0, data);
        }
        return;
    }
    // Carve the slice into disjoint &mut chunks up front, then hand one to
    // each scoped thread. split_at_mut keeps this safe.
    let mut chunks: Vec<&mut [T]> = Vec::with_capacity(ranges.len());
    let mut rest = data;
    let mut consumed = 0usize;
    for r in &ranges {
        let (head, tail) = rest.split_at_mut(r.end - consumed);
        consumed = r.end;
        chunks.push(head);
        rest = tail;
    }
    std::thread::scope(|scope| {
        for (idx, chunk) in chunks.into_iter().enumerate() {
            let f = &f;
            scope.spawn(move || f(idx, chunk));
        }
    });
}

/// Run `tasks` independent indexed jobs on up to `threads` worker threads and
/// return their results in task order. Each worker owns a strided subset of
/// task indices, so no queue synchronisation is needed.
pub fn parallel_map<R, F>(tasks: usize, threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    if tasks == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(tasks);
    if threads == 1 {
        return (0..tasks).map(f).collect();
    }
    let mut slots: Vec<Option<R>> = (0..tasks).map(|_| None).collect();
    {
        // Distribute result slots to workers in the same strided pattern as
        // the task indices, so each worker writes only its own slots.
        let mut slot_refs: Vec<(usize, &mut Option<R>)> = slots.iter_mut().enumerate().collect();
        let mut per_worker: Vec<Vec<(usize, &mut Option<R>)>> =
            (0..threads).map(|_| Vec::new()).collect();
        for (i, slot) in slot_refs.drain(..) {
            per_worker[i % threads].push((i, slot));
        }
        std::thread::scope(|scope| {
            for worker_slots in per_worker {
                let f = &f;
                scope.spawn(move || {
                    for (i, slot) in worker_slots {
                        *slot = Some(f(i));
                    }
                });
            }
        });
    }
    slots.into_iter().map(|s| s.expect("task completed")).collect()
}

/// Process pairs `(a_chunk, b_chunk)` of two equally-partitioned mutable
/// slices in parallel — used by merge passes that read one buffer and write
/// the other with matching geometry.
pub fn parallel_for_zip<T, U, F>(a: &mut [T], b: &mut [U], bounds: &[Range<usize>], f: F)
where
    T: Send,
    U: Send,
    F: Fn(usize, &mut [T], &mut [U]) + Sync,
{
    assert_eq!(a.len(), b.len(), "zip slices must match");
    if bounds.is_empty() {
        return;
    }
    if bounds.len() == 1 {
        f(0, a, b);
        return;
    }
    let mut pairs: Vec<(&mut [T], &mut [U])> = Vec::with_capacity(bounds.len());
    let (mut ra, mut rb) = (a, b);
    let mut consumed = 0usize;
    for r in bounds {
        let (ha, ta) = ra.split_at_mut(r.end - consumed);
        let (hb, tb) = rb.split_at_mut(r.end - consumed);
        consumed = r.end;
        pairs.push((ha, hb));
        ra = ta;
        rb = tb;
    }
    std::thread::scope(|scope| {
        for (idx, (ca, cb)) in pairs.into_iter().enumerate() {
            let f = &f;
            scope.spawn(move || f(idx, ca, cb));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_even_covers_everything() {
        for len in [0usize, 1, 7, 100, 101] {
            for parts in [1usize, 2, 3, 8, 200] {
                let rs = partition_even(len, parts);
                let total: usize = rs.iter().map(|r| r.len()).sum();
                assert_eq!(total, len, "len={len} parts={parts}");
                for w in rs.windows(2) {
                    assert_eq!(w[0].end, w[1].start, "contiguous");
                }
                for r in &rs {
                    assert!(!r.is_empty(), "no empty ranges");
                }
                if !rs.is_empty() {
                    let sizes: Vec<usize> = rs.iter().map(|r| r.len()).collect();
                    let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                    assert!(mx - mn <= 1, "balanced: {sizes:?}");
                }
            }
        }
    }

    #[test]
    fn parallel_for_chunks_touches_all() {
        let mut data = vec![0u64; 10_000];
        parallel_for_chunks(&mut data, 8, |idx, chunk| {
            for x in chunk.iter_mut() {
                *x = idx as u64 + 1;
            }
        });
        assert!(data.iter().all(|&x| x >= 1));
        // Chunk 0 exists and later chunks too.
        assert_eq!(data[0], 1);
        assert!(*data.last().unwrap() >= 1);
    }

    #[test]
    fn parallel_for_chunks_sequential_fallback() {
        let mut data = vec![1i32; 5];
        parallel_for_chunks(&mut data, 1, |_, chunk| {
            for x in chunk.iter_mut() {
                *x += 1;
            }
        });
        assert_eq!(data, vec![2; 5]);
        let mut empty: Vec<i32> = vec![];
        parallel_for_chunks(&mut empty, 4, |_, _| panic!("no chunks for empty data"));
    }

    #[test]
    fn parallel_map_ordered_results() {
        let out = parallel_map(100, 7, |i| i * i);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn parallel_map_zero_tasks() {
        let out: Vec<u32> = parallel_map(0, 4, |_| unreachable!());
        assert!(out.is_empty());
    }

    #[test]
    fn parallel_for_zip_matched_geometry() {
        let mut a: Vec<u32> = (0..1000).collect();
        let mut b = vec![0u32; 1000];
        let bounds = partition_even(1000, 4);
        parallel_for_zip(&mut a, &mut b, &bounds, |_, ca, cb| {
            for (x, y) in ca.iter().zip(cb.iter_mut()) {
                *y = *x * 2;
            }
        });
        for i in 0..1000u32 {
            assert_eq!(b[i as usize], i * 2);
        }
    }
}
