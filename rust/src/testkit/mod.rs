//! Minimal property-based testing framework (proptest is unavailable in the
//! offline build environment).
//!
//! Provides seeded random case generation with **shrinking**: when a case
//! fails, the runner tries progressively simpler inputs (shorter vectors,
//! smaller magnitudes) and reports the smallest failure it finds. Used by the
//! integration tests in `rust/tests/` to check coordinator and sorting
//! invariants across thousands of random cases.

use crate::rng::Xoshiro256pp;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 100, seed: 0x7E57, max_shrink_steps: 200 }
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum PropResult<T> {
    Ok,
    Failed {
        /// The original failing case.
        original: T,
        /// The smallest failing case found by shrinking.
        minimal: T,
        /// Shrink iterations performed.
        shrink_steps: usize,
    },
}

impl<T: std::fmt::Debug> PropResult<T> {
    /// Panic with a readable report on failure (for use inside `#[test]`s).
    pub fn unwrap_ok(self) {
        if let PropResult::Failed { original, minimal, shrink_steps } = self {
            panic!(
                "property failed.\n  minimal case ({shrink_steps} shrinks): {minimal:?}\n  original case: {original:?}"
            );
        }
    }

    pub fn is_ok(&self) -> bool {
        matches!(self, PropResult::Ok)
    }
}

/// A value generator with an associated shrinker.
pub trait Arbitrary: Sized + Clone {
    fn generate(rng: &mut Xoshiro256pp) -> Self;
    /// Candidate simplifications, *simplest first*. Empty = fully shrunk.
    fn shrink(&self) -> Vec<Self>;
}

/// Run `prop` over `config.cases` random cases; on failure, shrink.
pub fn check<T: Arbitrary + std::fmt::Debug>(
    config: PropConfig,
    prop: impl Fn(&T) -> bool,
) -> PropResult<T> {
    let mut rng = Xoshiro256pp::seeded(config.seed);
    for _ in 0..config.cases {
        let case = T::generate(&mut rng);
        if !prop(&case) {
            let (minimal, steps) = shrink_loop(case.clone(), &prop, config.max_shrink_steps);
            return PropResult::Failed { original: case, minimal, shrink_steps: steps };
        }
    }
    PropResult::Ok
}

fn shrink_loop<T: Arbitrary>(
    mut current: T,
    prop: &impl Fn(&T) -> bool,
    max_steps: usize,
) -> (T, usize) {
    let mut steps = 0;
    'outer: while steps < max_steps {
        for cand in current.shrink() {
            steps += 1;
            if !prop(&cand) {
                current = cand;
                continue 'outer;
            }
            if steps >= max_steps {
                break 'outer;
            }
        }
        break; // no shrink candidate fails -> minimal
    }
    (current, steps)
}

// ---------------------------------------------------------------------------
// Built-in Arbitrary instances used by the test suites.
// ---------------------------------------------------------------------------

/// Random i64 vector (length 0..=512, values spanning the full range with a
/// bias toward small magnitudes and duplicates — the interesting cases).
impl Arbitrary for Vec<i64> {
    fn generate(rng: &mut Xoshiro256pp) -> Self {
        let len = rng.below(513);
        (0..len)
            .map(|_| match rng.below(5) {
                0 => rng.range_i64(-3, 3), // duplicates
                1 => rng.next_u64() as i64, // full range
                2 => i64::MIN + rng.range_i64(0, 2),
                3 => i64::MAX - rng.range_i64(0, 2),
                _ => rng.range_i64(-1_000_000_000, 1_000_000_000), // paper interval
            })
            .collect()
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        let n = self.len();
        if n == 0 {
            return out;
        }
        // Halves first (aggressive), then drop-one, then zero-out values.
        out.push(self[..n / 2].to_vec());
        out.push(self[n / 2..].to_vec());
        if n <= 16 {
            for i in 0..n {
                let mut v = self.clone();
                v.remove(i);
                out.push(v);
            }
            for i in 0..n {
                if self[i] != 0 {
                    let mut v = self.clone();
                    v[i] = 0;
                    out.push(v);
                }
            }
        }
        out
    }
}

/// Random genome within default bounds (occasionally out-of-bounds to test
/// clamping at API boundaries).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArbGenome(pub [i64; 6]);

impl Arbitrary for ArbGenome {
    fn generate(rng: &mut Xoshiro256pp) -> Self {
        let bounds = crate::params::Bounds::default();
        let mut g =
            crate::ga::individual::random_genome(&bounds, rng);
        // 10% of cases: perturb one gene out of bounds.
        if rng.below(10) == 0 {
            let i = rng.below(6);
            g[i] = if rng.below(2) == 0 { -1 } else { i64::MAX / 2 };
        }
        ArbGenome(g)
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        for i in 0..6 {
            let lo = crate::params::Bounds::default().gene(i).lo;
            if self.0[i] != lo {
                let mut g = self.0;
                g[i] = lo;
                out.push(ArbGenome(g));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_ok() {
        let r = check::<Vec<i64>>(PropConfig::default(), |v| {
            let mut s = v.clone();
            s.sort_unstable();
            s.len() == v.len()
        });
        assert!(r.is_ok());
    }

    #[test]
    fn failing_property_shrinks() {
        // Property: "no vector contains a negative number" — false; the
        // minimal counterexample should be tiny.
        let r = check::<Vec<i64>>(
            PropConfig { cases: 200, ..Default::default() },
            |v| v.iter().all(|&x| x >= 0),
        );
        match r {
            PropResult::Failed { minimal, .. } => {
                assert!(minimal.len() <= 2, "shrunk to {minimal:?}");
            }
            PropResult::Ok => panic!("property should fail"),
        }
    }

    #[test]
    fn shrinking_respects_budget() {
        let r = check::<Vec<i64>>(
            PropConfig { cases: 10, max_shrink_steps: 3, ..Default::default() },
            |v| v.len() < 2,
        );
        if let PropResult::Failed { shrink_steps, .. } = r {
            assert!(shrink_steps <= 3 + 16); // one final pass may overshoot per-candidate
        }
    }

    #[test]
    fn genome_generator_mostly_valid() {
        let mut rng = Xoshiro256pp::seeded(1);
        let bounds = crate::params::Bounds::default();
        let mut valid = 0;
        for _ in 0..100 {
            if bounds.validate(&ArbGenome::generate(&mut rng).0) {
                valid += 1;
            }
        }
        assert!(valid > 70, "{valid}");
        assert!(valid < 100, "should sometimes generate out-of-bounds");
    }
}
