//! Typed run configuration: map a config [`Document`](super::Document) onto
//! the pipeline / GA / service settings the launcher consumes.
//!
//! ```toml
//! threads = 8
//!
//! [pipeline]
//! sizes    = [1e6, 1e7]
//! dist     = uniform
//! seed     = 42
//! params   = ga            # ga | symbolic | fixed
//! baselines = true
//! sample_cap = 2e6
//!
//! [ga]
//! population  = 30
//! generations = 10
//! crossover   = 0.7
//! mutation    = 0.3
//! elitism     = 2
//!
//! [service]
//! workers        = 2
//! sort_threads   = 4
//! queue_capacity = 64
//! autotune       = false   # online fingerprint-keyed GA refinement
//! shards         = 1       # >= 2: cross-process (router + worker processes)
//! exec           = parked  # kernel execution backend: parked (persistent
//!                          # executor, default) | spawn (per-call scoped
//!                          # threads — the A/B baseline)
//! transport      = unix    # local-shard link: unix (default) | tcp
//! listen         = "tcp://127.0.0.1:0"   # local-shard listen base (quoted —
//!                                        # endpoint syntax needs `://`)
//! connect        = "tcp://10.0.0.7:7070,tcp://10.0.0.8:7070"
//!                          # externally started `shard-worker --listen`
//!                          # processes to dial (comma-separated, quoted)
//! memory_budget  = 0       # bytes; > 0 escalates bigger jobs to the
//!                          # out-of-core spill sorter (0 = never)
//! spill_dir      = "/tmp"  # spill-run root (default: the OS temp dir)
//! ```

use anyhow::{bail, Context, Result};

use super::Document;
use crate::coordinator::{Endpoint, ParamSource, PipelineConfig, ServiceConfig, TransportKind};
use crate::data::Distribution;
use crate::ga::GaConfig;
use crate::sort::Baseline;
use crate::symbolic::SymbolicModel;

/// Everything a launcher invocation needs.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub threads: usize,
    pub pipeline: PipelineConfig,
    pub service: ServiceSettings,
}

/// Plain-data mirror of [`ServiceConfig`], validated at config-parse time
/// (endpoints are typed here, not raw strings).
#[derive(Debug, Clone)]
pub struct ServiceSettings {
    pub workers: usize,
    pub sort_threads: usize,
    pub queue_capacity: usize,
    /// Attach the online autotuner (fingerprint observations + background
    /// GA refinement) with default policy knobs.
    pub autotune: bool,
    /// Local worker **processes**: `1` (with no [`connect`](Self::connect))
    /// serves in-process, otherwise a shard router spawns that many
    /// `shard-worker` children (each of which gets `workers` pool threads).
    pub shards: usize,
    /// Kernel execution backend: the persistent parked executor (default)
    /// or the spawn-per-call baseline.
    pub exec: crate::exec::ExecMode,
    /// Link transport for local shards (`unix` default; `tcp` exercises
    /// the cross-host path on loopback).
    pub transport: TransportKind,
    /// Listen-address base for local shards; its scheme must match
    /// [`transport`](Self::transport) (it *sets* the transport when the
    /// `transport` key is absent).
    pub listen: Option<Endpoint>,
    /// Externally started `shard-worker --listen` endpoints to dial into
    /// the fleet.
    pub connect: Vec<Endpoint>,
    /// Out-of-core escalation budget in bytes: jobs whose payload exceeds
    /// it run through the spill-to-disk external sorter. `0` disables
    /// escalation (the historical always-in-RAM behaviour).
    pub memory_budget: usize,
    /// Spill-run root directory; `None` uses the OS temp dir.
    pub spill_dir: Option<std::path::PathBuf>,
}

impl ServiceSettings {
    /// Per-process service configuration (one shard's worth).
    pub fn to_config(&self) -> ServiceConfig {
        let external = (self.memory_budget > 0).then(|| {
            let mut x = crate::extsort::ExternalConfig::new(self.memory_budget);
            if let Some(dir) = &self.spill_dir {
                x = x.with_spill_dir(dir.clone());
            }
            x
        });
        ServiceConfig::sized(self.workers, self.sort_threads, self.queue_capacity)
            .with_autotune(self.autotune.then(crate::autotune::AutotunePolicy::default))
            .with_exec(self.exec)
            .with_external(external)
    }

    /// Deployment-level spec for [`ShardedService::spawn`] — a thin shim
    /// over [`ShardedService::builder`]; routes in-process when the fleet
    /// is one local shard, cross-process otherwise.
    ///
    /// [`ShardedService::spawn`]: crate::coordinator::ShardedService::spawn
    /// [`ShardedService::builder`]: crate::coordinator::ShardedService::builder
    #[cfg(unix)]
    pub fn to_shard_spec(&self) -> crate::coordinator::ShardSpec {
        let mut b = crate::coordinator::ShardedService::builder()
            .shards(self.shards.max(1))
            .workers_per_shard(self.workers)
            .sort_threads(self.sort_threads)
            .queue_capacity(self.queue_capacity)
            .exec(self.exec)
            .transport(self.transport);
        if self.autotune {
            b = b.autotune(crate::autotune::AutotunePolicy::default());
        }
        if let Some(listen) = &self.listen {
            b = b.endpoint(listen.clone());
        }
        for remote in &self.connect {
            b = b.connect(remote.clone());
        }
        b.build()
    }
}

impl RunConfig {
    pub fn from_document(doc: &Document) -> Result<RunConfig> {
        let threads = doc.count("", "threads", crate::util::default_threads())?;

        // [ga]
        let ga = GaConfig {
            population: doc.count("ga", "population", 30)?,
            generations: doc.count("ga", "generations", 10)?,
            crossover_prob: doc.f64("ga", "crossover", 0.7)?,
            mutation_prob: doc.f64("ga", "mutation", 0.3)?,
            elitism: doc.count("ga", "elitism", 2)?,
            tournament_k: doc.count("ga", "tournament_k", 3)?,
            seed: doc.count("ga", "seed", 0xE50_50E7)? as u64,
            repeats: doc.count("ga", "repeats", 1)?,
            ..GaConfig::default()
        };
        if !(0.0..=1.0).contains(&ga.crossover_prob) || !(0.0..=1.0).contains(&ga.mutation_prob) {
            bail!("[ga] crossover/mutation must be probabilities in [0, 1]");
        }
        if ga.population < 2 {
            bail!("[ga] population must be >= 2");
        }

        // [pipeline]
        let dist_name = doc.str("pipeline", "dist", "uniform")?;
        let Some(dist) = Distribution::parse(&dist_name) else {
            bail!("[pipeline] unknown dist {dist_name:?}");
        };
        let params = match doc.str("pipeline", "params", "ga")?.as_str() {
            "ga" => ParamSource::Ga(ga),
            "symbolic" => ParamSource::Symbolic(SymbolicModel::paper()),
            "fixed" => ParamSource::Fixed(crate::params::SortParams::paper_1e7()),
            other => bail!("[pipeline] params must be ga|symbolic|fixed, got {other:?}"),
        };
        let baselines = if doc.bool("pipeline", "baselines", true)? {
            vec![Baseline::Quicksort, Baseline::Mergesort]
        } else {
            vec![]
        };
        let pipeline = PipelineConfig {
            sizes: doc.counts("pipeline", "sizes", &[1_000_000, 10_000_000])?,
            dist,
            seed: doc.count("pipeline", "seed", 42)? as u64,
            threads,
            params,
            sample_cap: doc.count("pipeline", "sample_cap", 4_000_000)?,
            baselines,
        };
        if pipeline.sizes.is_empty() {
            bail!("[pipeline] sizes must not be empty");
        }

        // [service]
        let exec_name = doc.str("service", "exec", "parked")?;
        let Some(exec) = crate::exec::ExecMode::parse(&exec_name) else {
            bail!("[service] exec must be parked|spawn, got {exec_name:?}");
        };
        let listen = match doc.get("service", "listen") {
            None => None,
            Some(v) => {
                let text = v.as_str().context("[service] listen must be a quoted endpoint")?;
                Some(text.parse::<Endpoint>().map_err(|e| anyhow::anyhow!("[service] {e}"))?)
            }
        };
        let mut connect = Vec::new();
        if let Some(v) = doc.get("service", "connect") {
            let text = v
                .as_str()
                .context("[service] connect must be a quoted, comma-separated endpoint list")?;
            for part in text.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue;
                }
                connect.push(part.parse::<Endpoint>().map_err(|e| anyhow::anyhow!("[service] {e}"))?);
            }
        }
        // An explicit transport must agree with the listen endpoint; an
        // absent one is inferred from it (default: unix).
        let transport = match doc.get("service", "transport") {
            Some(v) => {
                let name = v.as_str().context("[service] transport must be unix|tcp")?;
                let Some(t) = TransportKind::parse(name) else {
                    bail!("[service] transport must be unix|tcp, got {name:?}");
                };
                if let Some(ep) = &listen {
                    if ep.transport() != t {
                        bail!("[service] listen endpoint {ep} does not match transport {t}");
                    }
                }
                t
            }
            None => listen.as_ref().map(Endpoint::transport).unwrap_or_default(),
        };
        let spill_dir = match doc.get("service", "spill_dir") {
            None => None,
            Some(v) => {
                let text = v.as_str().context("[service] spill_dir must be a quoted path")?;
                Some(std::path::PathBuf::from(text))
            }
        };
        let service = ServiceSettings {
            workers: doc.count("service", "workers", 2)?.max(1),
            sort_threads: doc.count("service", "sort_threads", threads.div_ceil(2))?.max(1),
            queue_capacity: doc.count("service", "queue_capacity", 64)?.max(1),
            autotune: doc.bool("service", "autotune", false)?,
            shards: doc.count("service", "shards", 1)?.max(1),
            exec,
            transport,
            listen,
            connect,
            memory_budget: doc.count("service", "memory_budget", 0)?,
            spill_dir,
        };

        Ok(RunConfig { threads, pipeline, service })
    }

    pub fn load(path: &std::path::Path) -> Result<RunConfig> {
        Self::from_document(&Document::load(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> Result<RunConfig> {
        RunConfig::from_document(&Document::parse(text).unwrap())
    }

    #[test]
    fn full_config_round_trip() {
        let rc = parse(
            r#"
threads = 3
[pipeline]
sizes = [1e5, 1e6]
dist = zipf
seed = 7
params = symbolic
baselines = false
[service]
workers = 4
queue_capacity = 16
"#,
        )
        .unwrap();
        assert_eq!(rc.threads, 3);
        assert_eq!(rc.pipeline.sizes, vec![100_000, 1_000_000]);
        assert_eq!(rc.pipeline.dist, Distribution::Zipf);
        assert!(matches!(rc.pipeline.params, ParamSource::Symbolic(_)));
        assert!(rc.pipeline.baselines.is_empty());
        assert_eq!(rc.service.workers, 4);
        assert_eq!(rc.service.queue_capacity, 16);
        assert!(!rc.service.autotune, "autotune defaults off");
        assert_eq!(rc.service.shards, 1, "sharding defaults off");
        assert_eq!(rc.service.exec, crate::exec::ExecMode::Parked, "parked executor by default");
        let sc = rc.service.to_config();
        assert_eq!(sc.workers, 4);
        assert!(sc.autotune.is_none());
        // The spawn-per-call baseline is opt-in.
        let rc = parse("[service]\nexec = spawn").unwrap();
        assert_eq!(rc.service.to_config().exec, crate::exec::ExecMode::SpawnPerCall);
        // Opting in yields a default policy.
        let rc = parse("[service]\nautotune = true").unwrap();
        assert!(rc.service.to_config().autotune.is_some());
    }

    #[test]
    #[cfg(unix)]
    fn shards_flow_into_the_shard_spec() {
        let rc = parse("[service]\nshards = 3\nworkers = 2\nautotune = true\nexec = spawn").unwrap();
        assert_eq!(rc.service.shards, 3);
        let spec = rc.service.to_shard_spec();
        assert_eq!(spec.shards, 3);
        assert_eq!(spec.workers_per_shard, 2);
        assert!(spec.autotune.is_some());
        assert_eq!(spec.exec, crate::exec::ExecMode::SpawnPerCall, "exec knob reaches the spec");
        // shards = 0 clamps to the in-process path.
        let rc = parse("[service]\nshards = 0").unwrap();
        assert_eq!(rc.service.shards, 1);
    }

    #[test]
    #[cfg(unix)]
    fn endpoints_flow_into_the_shard_spec() {
        let rc = parse(
            r#"
[service]
shards = 2
listen = "tcp://127.0.0.1:0"
connect = "tcp://10.0.0.7:7070, tcp://10.0.0.8:7070"
"#,
        )
        .unwrap();
        // Transport inferred from the listen endpoint's scheme.
        assert_eq!(rc.service.transport, TransportKind::Tcp);
        let spec = rc.service.to_shard_spec();
        assert_eq!(spec.shards, 2);
        assert_eq!(spec.transport, TransportKind::Tcp);
        assert_eq!(spec.listen.as_ref().unwrap().to_string(), "tcp://127.0.0.1:0");
        let remotes: Vec<String> = spec.remotes.iter().map(|e| e.to_string()).collect();
        assert_eq!(remotes, vec!["tcp://10.0.0.7:7070", "tcp://10.0.0.8:7070"]);
        // Plain `shards = N` configs keep working: unix transport, no
        // listen base, no remotes — exactly the pre-endpoint behavior.
        let rc = parse("[service]\nshards = 3").unwrap();
        assert_eq!(rc.service.transport, TransportKind::Unix);
        let spec = rc.service.to_shard_spec();
        assert_eq!(spec.transport, TransportKind::Unix);
        assert!(spec.listen.is_none());
        assert!(spec.remotes.is_empty());
        // An explicit transport key works without a listen base.
        let rc = parse("[service]\ntransport = tcp").unwrap();
        assert_eq!(rc.service.transport, TransportKind::Tcp);
    }

    #[test]
    fn memory_budget_flows_into_the_external_config() {
        // Default: no budget, no out-of-core escalation.
        let rc = parse("").unwrap();
        assert_eq!(rc.service.memory_budget, 0);
        assert!(rc.service.spill_dir.is_none());
        assert!(rc.service.to_config().external.is_none(), "escalation defaults off");
        // Budget + spill root flow through to the service config.
        let rc = parse(
            r#"
[service]
memory_budget = 1048576
spill_dir = "/tmp/evosort-spill"
"#,
        )
        .unwrap();
        let ext = rc.service.to_config().external.expect("budget > 0 turns escalation on");
        assert_eq!(ext.memory_budget, 1_048_576);
        assert_eq!(ext.spill_dir, std::path::PathBuf::from("/tmp/evosort-spill"));
        // A budget without a spill_dir falls back to the OS temp dir.
        let rc = parse("[service]\nmemory_budget = 4096").unwrap();
        let ext = rc.service.to_config().external.unwrap();
        assert_eq!(ext.spill_dir, std::env::temp_dir());
        // An unquoted path is a parse error, not a silent ignore.
        assert!(parse("[service]\nspill_dir = 7").is_err());
    }

    #[test]
    fn defaults_when_empty() {
        let rc = parse("").unwrap();
        assert!(matches!(rc.pipeline.params, ParamSource::Ga(_)));
        assert_eq!(rc.pipeline.sizes, vec![1_000_000, 10_000_000]);
        assert_eq!(rc.pipeline.baselines.len(), 2);
    }

    #[test]
    fn ga_settings_flow_through() {
        let rc = parse(
            r#"
[pipeline]
params = ga
[ga]
population = 12
generations = 4
crossover = 0.9
"#,
        )
        .unwrap();
        match &rc.pipeline.params {
            ParamSource::Ga(cfg) => {
                assert_eq!(cfg.population, 12);
                assert_eq!(cfg.generations, 4);
                assert_eq!(cfg.crossover_prob, 0.9);
                assert_eq!(cfg.mutation_prob, 0.3); // default
            }
            other => panic!("expected GA source, got {other:?}"),
        }
    }

    #[test]
    fn validation_errors() {
        assert!(parse("[pipeline]\ndist = nope").is_err());
        assert!(parse("[pipeline]\nparams = magic").is_err());
        assert!(parse("[pipeline]\nsizes = []").is_err());
        assert!(parse("[ga]\ncrossover = 1.5").is_err());
        assert!(parse("[ga]\npopulation = 1").is_err());
        assert!(parse("[service]\nexec = turbo").is_err());
        // Endpoint validation happens at parse time, with actionable errors.
        let err = parse("[service]\nlisten = \"tcp://no-port\"").unwrap_err();
        assert!(err.to_string().contains("[service]"), "namespaced: {err}");
        assert!(parse("[service]\ntransport = carrier-pigeon").is_err());
        assert!(
            parse("[service]\ntransport = unix\nlisten = \"tcp://127.0.0.1:1\"").is_err(),
            "transport/listen scheme mismatch must fail"
        );
        assert!(parse("[service]\nconnect = \"tcp://a:1,nonsense\"").is_err());
    }
}
