//! Configuration system: a TOML-subset parser (no `serde`/`toml` crates in
//! the offline environment) plus typed run configurations for the launcher.
//!
//! Supported syntax — the subset real deployments of this framework need:
//!
//! ```toml
//! # comment
//! [section]
//! int       = 42
//! count     = 1e7            # scientific counts, like the CLI
//! float     = 0.7
//! flag      = true
//! name      = "uniform"      # or bare-word strings
//! sizes     = [1e6, 1e7]     # arrays of counts
//! ```
//!
//! Typed views: [`RunConfig`] maps a file onto pipeline / GA / service
//! settings, used by `evosort pipeline --config run.toml`.

// Enforced boundary of the unsafe audit surface (see README
// “Correctness tooling”): a config parser has no business with raw memory.
#![forbid(unsafe_code)]

pub mod run;

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    IntList(Vec<i64>),
}

impl Value {
    pub fn as_count(&self) -> Option<usize> {
        match self {
            Value::Int(v) if *v >= 0 => Some(*v as usize),
            Value::Float(f) if *f >= 0.0 && f.fract() == 0.0 => Some(*f as usize),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(v) => Some(*v as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_counts(&self) -> Option<Vec<usize>> {
        match self {
            Value::IntList(v) => {
                v.iter().map(|&x| (x >= 0).then_some(x as usize)).collect()
            }
            _ => self.as_count().map(|c| vec![c]),
        }
    }
}

/// A parsed config document: `section.key -> Value` (top-level keys live in
/// the `""` section).
#[derive(Debug, Clone, Default)]
pub struct Document {
    values: HashMap<(String, String), Value>,
}

impl Document {
    pub fn parse(text: &str) -> Result<Document> {
        let mut doc = Document::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let Some((key, val)) = line.split_once('=') else {
                bail!("line {}: expected `key = value`, got {raw:?}", lineno + 1);
            };
            let value = parse_value(val.trim())
                .with_context(|| format!("line {}: bad value {:?}", lineno + 1, val.trim()))?;
            doc.values.insert((section.clone(), key.trim().to_string()), value);
        }
        Ok(doc)
    }

    pub fn load(path: &std::path::Path) -> Result<Document> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.values.get(&(section.to_string(), key.to_string()))
    }

    pub fn count(&self, section: &str, key: &str, default: usize) -> Result<usize> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .as_count()
                .ok_or_else(|| anyhow::anyhow!("[{section}] {key}: expected a count")),
        }
    }

    pub fn f64(&self, section: &str, key: &str, default: f64) -> Result<f64> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => {
                v.as_f64().ok_or_else(|| anyhow::anyhow!("[{section}] {key}: expected a number"))
            }
        }
    }

    pub fn bool(&self, section: &str, key: &str, default: bool) -> Result<bool> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => {
                v.as_bool().ok_or_else(|| anyhow::anyhow!("[{section}] {key}: expected a bool"))
            }
        }
    }

    pub fn str(&self, section: &str, key: &str, default: &str) -> Result<String> {
        match self.get(section, key) {
            None => Ok(default.to_string()),
            Some(v) => v
                .as_str()
                .map(|s| s.to_string())
                .ok_or_else(|| anyhow::anyhow!("[{section}] {key}: expected a string")),
        }
    }

    pub fn counts(&self, section: &str, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(section, key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .as_counts()
                .ok_or_else(|| anyhow::anyhow!("[{section}] {key}: expected counts")),
        }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

fn strip_comment(line: &str) -> &str {
    // A `#` outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').context("unterminated array")?;
        let mut items = Vec::new();
        for tok in body.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            match parse_scalar(tok)? {
                Value::Int(v) => items.push(v),
                Value::Float(f) if f.fract() == 0.0 => items.push(f as i64),
                other => bail!("array element {tok:?} not an integer count ({other:?})"),
            }
        }
        return Ok(Value::IntList(items));
    }
    parse_scalar(s)
}

fn parse_scalar(s: &str) -> Result<Value> {
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"').context("unterminated string")?;
        return Ok(Value::Str(body.to_string()));
    }
    if let Ok(v) = s.parse::<i64>() {
        return Ok(Value::Int(v));
    }
    if let Ok(f) = s.parse::<f64>() {
        // Scientific counts (1e7) arrive here; keep integral floats exact.
        return Ok(Value::Float(f));
    }
    // Bare words are strings ("uniform", "radix").
    if s.chars().all(|c| c.is_alphanumeric() || c == '-' || c == '_') {
        return Ok(Value::Str(s.to_string()));
    }
    bail!("cannot parse {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top-level
threads = 4

[pipeline]
sizes = [1e6, 2.5e6, 1000]
dist = uniform          # bare word
seed = 42
symbolic = true

[ga]
population = 30
crossover = 0.7
label = "paper defaults"
"#;

    #[test]
    fn parses_sections_and_types() {
        let doc = Document::parse(SAMPLE).unwrap();
        assert_eq!(doc.count("", "threads", 0).unwrap(), 4);
        assert_eq!(
            doc.counts("pipeline", "sizes", &[]).unwrap(),
            vec![1_000_000, 2_500_000, 1000]
        );
        assert_eq!(doc.str("pipeline", "dist", "x").unwrap(), "uniform");
        assert!(doc.bool("pipeline", "symbolic", false).unwrap());
        assert_eq!(doc.f64("ga", "crossover", 0.0).unwrap(), 0.7);
        assert_eq!(doc.str("ga", "label", "").unwrap(), "paper defaults");
    }

    #[test]
    fn defaults_for_missing_keys() {
        let doc = Document::parse("").unwrap();
        assert!(doc.is_empty());
        assert_eq!(doc.count("a", "b", 9).unwrap(), 9);
        assert_eq!(doc.str("a", "b", "z").unwrap(), "z");
    }

    #[test]
    fn type_errors_are_reported() {
        let doc = Document::parse("x = true").unwrap();
        assert!(doc.count("", "x", 0).is_err());
        assert!(doc.f64("", "x", 0.0).is_err());
        assert!(doc.str("", "x", "").is_err());
        assert!(doc.bool("", "x", false).unwrap());
    }

    #[test]
    fn comments_inside_strings_kept() {
        let doc = Document::parse("s = \"a # b\"").unwrap();
        assert_eq!(doc.str("", "s", "").unwrap(), "a # b");
    }

    #[test]
    fn malformed_lines_rejected() {
        assert!(Document::parse("just a line").is_err());
        assert!(Document::parse("x = [1, 2").is_err());
        assert!(Document::parse("x = \"unterminated").is_err());
        assert!(Document::parse("x = @?!").is_err());
    }

    #[test]
    fn scientific_counts() {
        let doc = Document::parse("n = 1e7").unwrap();
        assert_eq!(doc.count("", "n", 0).unwrap(), 10_000_000);
    }
}
