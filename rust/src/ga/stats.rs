//! Per-generation statistics — the data behind the paper's Figures 2–6
//! (best / worst / average execution time per GA generation).

use super::individual::{Genome, Individual};

/// One generation's snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct GenStats {
    pub generation: usize,
    pub best: f64,
    pub worst: f64,
    pub average: f64,
    pub best_genome: Genome,
}

impl GenStats {
    /// Summarise an evaluated population. Individuals disqualified with +inf
    /// fitness are excluded from the average but counted in `worst` via the
    /// worst *finite* value (the paper's plots are finite).
    pub fn of(generation: usize, pop: &[Individual]) -> GenStats {
        let finite: Vec<&Individual> = pop.iter().filter(|i| i.fitness.is_finite()).collect();
        assert!(!finite.is_empty(), "population has no valid individuals");
        let mut best = finite[0];
        let mut worst = finite[0];
        let mut sum = 0.0;
        for ind in &finite {
            if ind.better_than(best) {
                best = ind;
            }
            if ind.fitness > worst.fitness {
                worst = ind;
            }
            sum += ind.fitness;
        }
        GenStats {
            generation,
            best: best.fitness,
            worst: worst.fitness,
            average: sum / finite.len() as f64,
            best_genome: best.genome,
        }
    }

    /// Render one line of the convergence table (Figures 2–6 data series).
    pub fn row(&self) -> String {
        format!(
            "gen {:>2}  best {:>9.4}s  avg {:>9.4}s  worst {:>9.4}s  best_genome {:?}",
            self.generation, self.best, self.average, self.worst, self.best_genome
        )
    }
}

/// Convergence detector: the paper observes convergence "in 10 to 12
/// generations", evidenced by the best value stalling. We call the search
/// converged after `patience` generations without relative improvement
/// better than `rel_tol`.
#[derive(Debug, Clone)]
pub struct Convergence {
    best_so_far: f64,
    stall: usize,
    patience: usize,
    rel_tol: f64,
}

impl Convergence {
    pub fn new(patience: usize, rel_tol: f64) -> Self {
        Convergence { best_so_far: f64::INFINITY, stall: 0, patience, rel_tol }
    }

    /// Feed a generation's best; returns `true` once converged.
    pub fn update(&mut self, best: f64) -> bool {
        if best < self.best_so_far * (1.0 - self.rel_tol) {
            self.best_so_far = best;
            self.stall = 0;
        } else {
            self.best_so_far = self.best_so_far.min(best);
            self.stall += 1;
        }
        self.stall >= self.patience
    }

    pub fn best(&self) -> f64 {
        self.best_so_far
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ind(f: f64) -> Individual {
        Individual { genome: [0; 6], fitness: f }
    }

    #[test]
    fn stats_basic() {
        let pop = vec![ind(2.0), ind(1.0), ind(3.0)];
        let s = GenStats::of(7, &pop);
        assert_eq!(s.generation, 7);
        assert_eq!(s.best, 1.0);
        assert_eq!(s.worst, 3.0);
        assert!((s.average - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stats_ignore_invalid() {
        let pop = vec![ind(2.0), ind(f64::INFINITY), ind(4.0)];
        let s = GenStats::of(0, &pop);
        assert_eq!(s.best, 2.0);
        assert_eq!(s.worst, 4.0);
        assert!((s.average - 3.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "no valid individuals")]
    fn stats_all_invalid_panics() {
        GenStats::of(0, &[ind(f64::INFINITY)]);
    }

    #[test]
    fn convergence_detects_stall() {
        let mut c = Convergence::new(3, 0.01);
        assert!(!c.update(10.0));
        assert!(!c.update(5.0)); // improving
        assert!(!c.update(5.0)); // stall 1
        assert!(!c.update(4.99)); // < 1% improvement: stall 2
        assert!(c.update(5.01)); // stall 3 -> converged
        assert_eq!(c.best(), 4.99);
    }

    #[test]
    fn convergence_resets_on_improvement() {
        let mut c = Convergence::new(2, 0.01);
        c.update(10.0);
        c.update(10.0); // stall 1
        assert!(!c.update(8.0)); // big improvement resets
        c.update(8.0);
        assert!(c.update(8.0));
    }
}
