//! Fitness evaluation: `f(x) = T_sort(x)` — wall-clock time to sort a
//! representative sample with the candidate's parameters (paper §4.2).
//!
//! Details that matter for measurement quality:
//! * the sample array is generated **once** per tuning run; every evaluation
//!   sorts a fresh copy (the copy is outside the timed region);
//! * evaluations are repeated `repeats` times and the **minimum** is taken
//!   (minimum is the standard noise-robust estimator for cold-cache-free
//!   timing; the paper's per-generation error bars motivate smoothing);
//! * results are memoised by genome — elitism re-inserts identical genomes
//!   every generation and re-timing them would both waste time and inject
//!   noise into the convergence curves;
//! * every evaluated output is validated (sortedness + multiset fingerprint)
//!   so a buggy configuration can never win by "sorting" incorrectly — its
//!   fitness becomes +inf instead.

use std::collections::HashMap;

use crate::data::validate::{fingerprint_i64, validate_i64, Fingerprint, Verdict};
use crate::params::SortParams;
use crate::sort::AdaptiveSorter;
use crate::util::timer;

use super::individual::Genome;

/// Evaluates genomes by timing real sorts on a shared sample.
pub struct SortTimingFitness {
    sample: Vec<i64>,
    sample_fp: Fingerprint,
    sorter: AdaptiveSorter,
    repeats: usize,
    cache: HashMap<Genome, f64>,
    evals: usize,
    cache_hits: usize,
    /// Reused buffers: candidate copy + radix scratch.
    work: Vec<i64>,
    scratch: Vec<i64>,
}

impl SortTimingFitness {
    /// `sample` is the representative dataset (paper: a random array of the
    /// target size, or a subsample for very large n).
    pub fn new(sample: Vec<i64>, sorter: AdaptiveSorter, repeats: usize) -> Self {
        let threads = sorter.threads();
        let sample_fp = fingerprint_i64(&sample, threads);
        let work = Vec::with_capacity(sample.len());
        SortTimingFitness {
            sample,
            sample_fp,
            sorter,
            repeats: repeats.max(1),
            cache: HashMap::new(),
            evals: 0,
            cache_hits: 0,
            work,
            scratch: Vec::new(),
        }
    }

    pub fn sample_len(&self) -> usize {
        self.sample.len()
    }

    /// Total timed evaluations performed (cache misses).
    pub fn evals(&self) -> usize {
        self.evals
    }

    pub fn cache_hits(&self) -> usize {
        self.cache_hits
    }

    /// Evaluate a genome: minimum sort time over `repeats` runs, memoised.
    pub fn eval(&mut self, genome: &Genome) -> f64 {
        if let Some(&t) = self.cache.get(genome) {
            self.cache_hits += 1;
            return t;
        }
        let params = SortParams::from_genes(genome);
        let mut best = f64::INFINITY;
        for _ in 0..self.repeats {
            self.work.clear();
            self.work.extend_from_slice(&self.sample);
            let (_, secs) = timer::time(|| {
                self.sorter
                    .sort_i64_with_scratch(&mut self.work, &params, &mut self.scratch)
            });
            // Correctness gate: invalid output disqualifies the candidate.
            if validate_i64(self.sample_fp, &self.work, self.sorter.threads()) != Verdict::Valid {
                crate::log_error!("candidate {params} produced invalid output");
                best = f64::INFINITY;
                break;
            }
            best = best.min(secs);
        }
        self.evals += 1;
        self.cache.insert(*genome, best);
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_i64, Distribution};
    use crate::sort::AdaptiveSorter;

    fn fitness_fixture(n: usize) -> SortTimingFitness {
        let sample = generate_i64(n, Distribution::Uniform, 99, 2);
        SortTimingFitness::new(sample, AdaptiveSorter::new(2), 1)
    }

    #[test]
    fn eval_returns_positive_time() {
        let mut f = fitness_fixture(20_000);
        let t = f.eval(&[3075, 31291, 4, 99574, 1418, 8]);
        assert!(t.is_finite() && t > 0.0);
        assert_eq!(f.evals(), 1);
    }

    #[test]
    fn cache_prevents_reevaluation() {
        let mut f = fitness_fixture(10_000);
        let g = [64i64, 4096, 3, 1000, 512, 8];
        let t1 = f.eval(&g);
        let t2 = f.eval(&g);
        assert_eq!(t1, t2, "cached value must be bit-identical");
        assert_eq!(f.evals(), 1);
        assert_eq!(f.cache_hits(), 1);
    }

    #[test]
    fn different_genomes_timed_separately() {
        let mut f = fitness_fixture(10_000);
        f.eval(&[64, 4096, 3, 1000, 512, 8]);
        f.eval(&[64, 4096, 4, 1000, 512, 6]);
        assert_eq!(f.evals(), 2);
    }

    #[test]
    fn sample_survives_evaluations() {
        let mut f = fitness_fixture(5_000);
        let before = f.sample.clone();
        f.eval(&[100, 2048, 4, 500, 256, 11]);
        assert_eq!(f.sample, before, "sample must not be sorted in place");
    }
}
