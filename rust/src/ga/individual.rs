//! Genome representation and random initialisation.
//!
//! A candidate solution is the paper's 5-integer vector
//! `x = (T_insertion, T_merge, A_code, T_numpy, T_tile)` plus the `W_radix`
//! digit-width gene. Threshold genes span several orders of magnitude, so
//! random initialisation samples them **log-uniformly** — a uniform draw over
//! [16, 1e5] would almost never propose values below 1e4, starving the search
//! of small-threshold candidates (the paper's Generation-0 spread, e.g.
//! 6.6 s → 0.24 s at 1e7, shows the initial population does explore both
//! extremes). Categorical genes (`A_code`, `W_radix`) sample uniformly.

use crate::params::{Bounds, GeneRange};
use crate::rng::Xoshiro256pp;

/// The raw 6-gene chromosome (paper ordering + `W_radix`).
pub type Genome = [i64; 6];

/// Sample one gene log-uniformly within its range (categorical genes, i.e.
/// the algorithm code, are sampled uniformly).
pub fn random_gene(range: GeneRange, categorical: bool, rng: &mut Xoshiro256pp) -> i64 {
    if categorical || range.span() < 8 {
        return range.lo + rng.next_below((range.span() + 1) as u64) as i64;
    }
    let lo = (range.lo.max(1)) as f64;
    let hi = range.hi as f64;
    let v = (lo.ln() + rng.next_f64() * (hi.ln() - lo.ln())).exp();
    (v.round() as i64).clamp(range.lo, range.hi)
}

/// Sample a full random genome within `bounds`.
pub fn random_genome(bounds: &Bounds, rng: &mut Xoshiro256pp) -> Genome {
    [
        random_gene(bounds.insertion, false, rng),
        random_gene(bounds.parallel_merge, false, rng),
        random_gene(bounds.algorithm, true, rng),
        random_gene(bounds.fallback, false, rng),
        random_gene(bounds.tile, false, rng),
        random_gene(bounds.radix, true, rng),
    ]
}

/// An evaluated individual.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Individual {
    pub genome: Genome,
    /// Sorting time in seconds (lower is better); `f64::INFINITY` before
    /// evaluation.
    pub fitness: f64,
}

impl Individual {
    pub fn unevaluated(genome: Genome) -> Self {
        Individual { genome, fitness: f64::INFINITY }
    }

    pub fn better_than(&self, other: &Individual) -> bool {
        self.fitness < other.fitness
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_genome_within_bounds() {
        let bounds = Bounds::default();
        let mut rng = Xoshiro256pp::seeded(1);
        for _ in 0..1000 {
            let g = random_genome(&bounds, &mut rng);
            assert!(bounds.validate(&g), "{g:?}");
        }
    }

    #[test]
    fn log_uniform_reaches_both_extremes() {
        let bounds = Bounds::default();
        let mut rng = Xoshiro256pp::seeded(2);
        let (mut small, mut large) = (0, 0);
        for _ in 0..2000 {
            let g = random_gene(bounds.insertion, false, &mut rng);
            if g < 200 {
                small += 1;
            }
            if g > 20_000 {
                large += 1;
            }
        }
        assert!(small > 100, "log-uniform should visit small values ({small})");
        assert!(large > 100, "and large values ({large})");
    }

    #[test]
    fn categorical_gene_uniform() {
        let bounds = Bounds::default();
        let mut rng = Xoshiro256pp::seeded(3);
        let mut saw = std::collections::HashSet::new();
        for _ in 0..200 {
            saw.insert(random_gene(bounds.algorithm, true, &mut rng));
        }
        assert_eq!(saw, [3i64, 4].into_iter().collect());
    }

    #[test]
    fn width_gene_uniform_over_snap_targets() {
        let bounds = Bounds::default();
        let mut rng = Xoshiro256pp::seeded(4);
        let mut saw = std::collections::HashSet::new();
        for _ in 0..500 {
            saw.insert(random_gene(bounds.radix, true, &mut rng));
        }
        assert_eq!(saw, (6i64..=11).collect(), "uniform draw covers the whole range");
    }

    #[test]
    fn individual_comparison() {
        let a = Individual { genome: [1; 6], fitness: 0.5 };
        let b = Individual { genome: [2; 6], fitness: 0.7 };
        assert!(a.better_than(&b));
        assert!(!b.better_than(&a));
        let u = Individual::unevaluated([0; 6]);
        assert!(a.better_than(&u));
    }
}
