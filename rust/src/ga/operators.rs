//! GA variation and selection operators (paper §6: uniform recombination
//! with probability 0.7, uniform mutation with probability 0.3, elitism,
//! plus tournament selection — the standard companion to both).

use super::individual::{random_gene, Genome, Individual};
use crate::params::Bounds;
use crate::rng::Xoshiro256pp;

/// Tournament selection: draw `k` members uniformly, return the fittest.
pub fn tournament<'a>(
    pop: &'a [Individual],
    k: usize,
    rng: &mut Xoshiro256pp,
) -> &'a Individual {
    debug_assert!(!pop.is_empty());
    let mut best = &pop[rng.below(pop.len())];
    for _ in 1..k.max(1) {
        let cand = &pop[rng.below(pop.len())];
        if cand.better_than(best) {
            best = cand;
        }
    }
    best
}

/// Uniform crossover: with probability `p_crossover` the parents exchange
/// genes (each gene independently picks a parent, p = 0.5); otherwise the
/// children are clones.
pub fn uniform_crossover(
    a: &Genome,
    b: &Genome,
    p_crossover: f64,
    rng: &mut Xoshiro256pp,
) -> (Genome, Genome) {
    if rng.next_f64() >= p_crossover {
        return (*a, *b);
    }
    let mut c = *a;
    let mut d = *b;
    for i in 0..a.len() {
        if rng.next_f64() < 0.5 {
            c[i] = b[i];
            d[i] = a[i];
        }
    }
    (c, d)
}

/// Uniform mutation: with probability `p_mutation` per *individual*, each
/// gene independently mutates with probability 1/len. Threshold genes take a
/// fresh log-uniform draw half the time and a relative ±50% perturbation the
/// other half (local refinement — the paper's "exploring slight parameter
/// variations" in later generations); the categorical gene resamples.
pub fn uniform_mutation(
    g: &mut Genome,
    bounds: &Bounds,
    p_mutation: f64,
    rng: &mut Xoshiro256pp,
) {
    if rng.next_f64() >= p_mutation {
        return;
    }
    let per_gene = 1.0 / g.len() as f64;
    let mut mutated_any = false;
    for i in 0..g.len() {
        if rng.next_f64() < per_gene {
            mutate_gene(g, i, bounds, rng);
            mutated_any = true;
        }
    }
    if !mutated_any {
        // Guarantee at least one change once mutation triggered.
        let i = rng.below(g.len());
        mutate_gene(g, i, bounds, rng);
    }
}

fn mutate_gene(g: &mut Genome, i: usize, bounds: &Bounds, rng: &mut Xoshiro256pp) {
    let range = bounds.gene(i);
    // Gene 2 is the algorithm code; gene 5 is the radix digit width — both
    // categorical: a ±50% perturbation of a code is meaningless.
    let categorical = i == 2 || i == 5;
    if categorical || rng.next_f64() < 0.5 {
        g[i] = random_gene(range, categorical, rng);
    } else {
        // Relative perturbation in [0.5x, 1.5x].
        let factor = 0.5 + rng.next_f64();
        let v = (g[i] as f64 * factor).round() as i64;
        g[i] = v.clamp(range.lo, range.hi);
    }
}

/// Elitism: indices of the `e` fittest individuals (stable order).
pub fn elite_indices(pop: &[Individual], e: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..pop.len()).collect();
    idx.sort_by(|&a, &b| {
        pop[a]
            .fitness
            .partial_cmp(&pop[b].fitness)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    idx.truncate(e);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pop_with(fitnesses: &[f64]) -> Vec<Individual> {
        fitnesses
            .iter()
            .enumerate()
            .map(|(i, &f)| Individual { genome: [i as i64; 6], fitness: f })
            .collect()
    }

    #[test]
    fn tournament_prefers_fit() {
        let pop = pop_with(&[5.0, 1.0, 3.0, 0.2, 4.0]);
        let mut rng = Xoshiro256pp::seeded(5);
        // With k = population size the winner is almost always the global best.
        let mut best_wins = 0;
        for _ in 0..200 {
            if tournament(&pop, 16, &mut rng).genome == [3; 6] {
                best_wins += 1;
            }
        }
        assert!(best_wins > 190, "{best_wins}");
    }

    #[test]
    fn crossover_preserves_gene_pool() {
        let a = [1i64, 2, 3, 4, 5, 6];
        let b = [10i64, 20, 4, 40, 50, 11];
        let mut rng = Xoshiro256pp::seeded(6);
        for _ in 0..100 {
            let (c, d) = uniform_crossover(&a, &b, 1.0, &mut rng);
            for i in 0..6 {
                // Each child gene comes from one of the parents, and the pair
                // (c[i], d[i]) is a permutation of (a[i], b[i]).
                assert!(
                    (c[i] == a[i] && d[i] == b[i]) || (c[i] == b[i] && d[i] == a[i]),
                    "gene {i}"
                );
            }
        }
    }

    #[test]
    fn crossover_prob_zero_clones() {
        let a = [1i64, 2, 3, 4, 5, 6];
        let b = [9i64, 8, 4, 6, 5, 8];
        let mut rng = Xoshiro256pp::seeded(7);
        let (c, d) = uniform_crossover(&a, &b, 0.0, &mut rng);
        assert_eq!(c, a);
        assert_eq!(d, b);
    }

    #[test]
    fn mutation_respects_bounds_and_changes() {
        let bounds = Bounds::default();
        let mut rng = Xoshiro256pp::seeded(8);
        let mut changed = 0;
        for _ in 0..300 {
            let mut g = [3075i64, 31291, 4, 99574, 1418, 8];
            uniform_mutation(&mut g, &bounds, 1.0, &mut rng);
            assert!(bounds.validate(&g), "{g:?}");
            if g != [3075, 31291, 4, 99574, 1418, 8] {
                changed += 1;
            }
        }
        // A mutation attempt can re-draw the same value (relative factor
        // rounding to 1.0, or a categorical gene resampling itself), so
        // require "nearly always changes" rather than strict equality, with
        // margin for seed drift and libm ulp differences across platforms.
        assert!(changed >= 250, "p=1.0 should nearly always change a gene ({changed}/300)");
    }

    #[test]
    fn mutation_prob_zero_is_identity() {
        let bounds = Bounds::default();
        let mut rng = Xoshiro256pp::seeded(9);
        let mut g = [100i64, 2000, 3, 5000, 700, 8];
        uniform_mutation(&mut g, &bounds, 0.0, &mut rng);
        assert_eq!(g, [100, 2000, 3, 5000, 700, 8]);
    }

    #[test]
    fn elites_are_fittest() {
        let pop = pop_with(&[5.0, 1.0, 3.0, 0.2, 4.0]);
        assert_eq!(elite_indices(&pop, 2), vec![3, 1]);
        assert_eq!(elite_indices(&pop, 0), Vec::<usize>::new());
    }
}
