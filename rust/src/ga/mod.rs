//! The GA auto-tuner — `RunGATuning` of Algorithm 2.
//!
//! Evolves a population of [`SortParams`](crate::params::SortParams) genomes
//! to minimise measured sorting time. Defaults mirror the paper: population
//! 30, ~10 generations, uniform recombination with probability 0.7, uniform
//! mutation with probability 0.3, elitism.

// Enforced boundary of the unsafe audit surface (see README
// “Correctness tooling”): the evolutionary search is pure safe Rust.
#![forbid(unsafe_code)]

pub mod fitness;
pub mod individual;
pub mod operators;
pub mod stats;

pub use fitness::SortTimingFitness;
pub use individual::{Genome, Individual};
pub use stats::{Convergence, GenStats};

use crate::data::{self, Distribution};
use crate::params::{Bounds, SortParams};
use crate::rng::Xoshiro256pp;
use crate::sort::AdaptiveSorter;

/// GA hyper-parameters (paper §6 defaults).
#[derive(Debug, Clone)]
pub struct GaConfig {
    pub population: usize,
    pub generations: usize,
    pub crossover_prob: f64,
    pub mutation_prob: f64,
    pub elitism: usize,
    pub tournament_k: usize,
    pub bounds: Bounds,
    pub seed: u64,
    /// Timed repeats per fitness evaluation (min is taken).
    pub repeats: usize,
    /// Stop early once converged (patience in generations); `None` always
    /// runs the full budget, like the paper's fixed 10-generation plots.
    pub early_stop_patience: Option<usize>,
}

impl Default for GaConfig {
    fn default() -> Self {
        GaConfig {
            population: 30,
            generations: 10,
            crossover_prob: 0.7,
            mutation_prob: 0.3,
            elitism: 2,
            tournament_k: 3,
            bounds: Bounds::default(),
            seed: 0xE50_50E7,
            repeats: 1,
            early_stop_patience: None,
        }
    }
}

impl GaConfig {
    /// A fast configuration for tests and quick tuning runs.
    pub fn quick() -> Self {
        GaConfig { population: 8, generations: 4, ..Default::default() }
    }
}

/// Outcome of one tuning run.
#[derive(Debug, Clone)]
pub struct GaResult {
    pub best: SortParams,
    pub best_genome: Genome,
    pub best_fitness: f64,
    /// Per-generation best/worst/average — the Figures 2–6 series. Index 0
    /// is the initial population ("Generation 0" in the paper).
    pub history: Vec<GenStats>,
    /// Timed evaluations performed (cache misses).
    pub evaluations: usize,
    /// Whether the early-stop criterion fired before the budget ran out.
    pub converged_early: bool,
}

/// The GA driver (Algorithm 2).
pub struct GaDriver {
    pub config: GaConfig,
}

impl GaDriver {
    pub fn new(config: GaConfig) -> Self {
        GaDriver { config }
    }

    /// Tune for dataset size `n` (Algorithm 2): generate a sample of size
    /// `n.min(sample_cap)`, evolve, return the best parameter set.
    pub fn run_for_size(
        &self,
        n: usize,
        sample_cap: usize,
        dist: Distribution,
        sorter: AdaptiveSorter,
    ) -> GaResult {
        let threads = sorter.threads();
        let sample_n = n.min(sample_cap.max(1024));
        let sample = data::generate_i64(sample_n, dist, self.config.seed ^ 0xDA7A, threads);
        let fitness = SortTimingFitness::new(sample, sorter, self.config.repeats);
        self.run(fitness)
    }

    /// Evolve against a prepared fitness function.
    pub fn run(&self, mut fitness: SortTimingFitness) -> GaResult {
        let cfg = &self.config;
        let mut rng = Xoshiro256pp::seeded(cfg.seed);
        // Generation 0: random initialisation (log-uniform thresholds).
        let pop: Vec<Individual> = (0..cfg.population)
            .map(|_| Individual::unevaluated(individual::random_genome(&cfg.bounds, &mut rng)))
            .collect();
        self.evolve(&mut fitness, pop, cfg.generations, &mut rng)
    }

    /// Incremental refinement (the online autotuner's entry point): instead
    /// of cold-starting from a random population, generation 0 is seeded
    /// with a known-good genome (the cached best for a workload class), a
    /// cloud of its mutations (exploitation), and a random remainder
    /// (exploration). Runs `generations` generations against `fitness`,
    /// which is borrowed so its memoisation cache survives across cycles.
    pub fn refine(
        &self,
        fitness: &mut SortTimingFitness,
        seed_genome: &Genome,
        generations: usize,
    ) -> GaResult {
        let cfg = &self.config;
        let mut rng = Xoshiro256pp::seeded(cfg.seed);
        let mut pop = Vec::with_capacity(cfg.population);
        pop.push(Individual::unevaluated(*seed_genome));
        // Half the population explores the seed's neighbourhood.
        while pop.len() < cfg.population.div_ceil(2) {
            let mut g = *seed_genome;
            operators::uniform_mutation(&mut g, &cfg.bounds, cfg.mutation_prob.max(0.5), &mut rng);
            pop.push(Individual::unevaluated(g));
        }
        while pop.len() < cfg.population {
            pop.push(Individual::unevaluated(individual::random_genome(&cfg.bounds, &mut rng)));
        }
        self.evolve(fitness, pop, generations, &mut rng)
    }

    /// The shared evolution loop: evaluate generation 0, then select, cross
    /// over, mutate and re-evaluate for `generations` generations.
    fn evolve(
        &self,
        fitness: &mut SortTimingFitness,
        mut pop: Vec<Individual>,
        generations: usize,
        rng: &mut Xoshiro256pp,
    ) -> GaResult {
        let cfg = &self.config;
        assert!(cfg.population >= 2, "population must be at least 2");
        for ind in &mut pop {
            ind.fitness = fitness.eval(&ind.genome);
        }

        let mut history = vec![GenStats::of(0, &pop)];
        crate::log_debug!("{}", history[0].row());
        let mut convergence = cfg.early_stop_patience.map(|p| Convergence::new(p, 0.01));
        let mut converged_early = false;

        for g in 1..=generations {
            // Elitism: carry the best through unchanged.
            let elite: Vec<Individual> = operators::elite_indices(&pop, cfg.elitism)
                .into_iter()
                .map(|i| pop[i])
                .collect();

            // Offspring via tournament selection + uniform crossover +
            // uniform mutation.
            let mut next: Vec<Individual> = elite.clone();
            while next.len() < cfg.population {
                let pa = operators::tournament(&pop, cfg.tournament_k, rng).genome;
                let pb = operators::tournament(&pop, cfg.tournament_k, rng).genome;
                let (mut ca, mut cb) =
                    operators::uniform_crossover(&pa, &pb, cfg.crossover_prob, rng);
                operators::uniform_mutation(&mut ca, &cfg.bounds, cfg.mutation_prob, rng);
                operators::uniform_mutation(&mut cb, &cfg.bounds, cfg.mutation_prob, rng);
                next.push(Individual::unevaluated(ca));
                if next.len() < cfg.population {
                    next.push(Individual::unevaluated(cb));
                }
            }

            for ind in &mut next {
                if ind.fitness.is_infinite() {
                    ind.fitness = fitness.eval(&ind.genome);
                }
            }
            pop = next;
            let gs = GenStats::of(g, &pop);
            crate::log_debug!("{}", gs.row());
            history.push(gs);

            if let Some(c) = convergence.as_mut() {
                if c.update(history.last().unwrap().best) {
                    converged_early = true;
                    break;
                }
            }
        }

        // Best individual across the entire run (elitism makes this the last
        // generation's best, but be defensive).
        let best_stats = history
            .iter()
            .min_by(|a, b| a.best.partial_cmp(&b.best).unwrap())
            .unwrap();
        GaResult {
            best: SortParams::from_genes(&best_stats.best_genome),
            best_genome: best_stats.best_genome,
            best_fitness: best_stats.best,
            history,
            evaluations: fitness.evals(),
            converged_early,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generate_i64;

    fn quick_result(n: usize) -> GaResult {
        let sample = generate_i64(n, Distribution::Uniform, 7, 2);
        let fitness = SortTimingFitness::new(sample, AdaptiveSorter::new(2), 1);
        GaDriver::new(GaConfig { seed: 11, ..GaConfig::quick() }).run(fitness)
    }

    #[test]
    fn ga_runs_and_never_regresses() {
        let r = quick_result(30_000);
        assert_eq!(r.history.len(), 5); // gen 0 + 4
        assert!(r.best_fitness.is_finite() && r.best_fitness > 0.0);
        // Elitism + fitness memoisation guarantee monotone best.
        for w in r.history.windows(2) {
            assert!(
                w[1].best <= w[0].best + 1e-9,
                "best must not regress: {} -> {}",
                w[0].best,
                w[1].best
            );
        }
        assert!(Bounds::default().validate(&r.best_genome));
    }

    #[test]
    fn population_initialisation_is_seed_deterministic() {
        let cfg = GaConfig { seed: 13, ..GaConfig::quick() };
        let mut rng1 = Xoshiro256pp::seeded(cfg.seed);
        let mut rng2 = Xoshiro256pp::seeded(cfg.seed);
        let g1 = individual::random_genome(&cfg.bounds, &mut rng1);
        let g2 = individual::random_genome(&cfg.bounds, &mut rng2);
        assert_eq!(g1, g2);
    }

    #[test]
    fn early_stop_bounded() {
        let sample = generate_i64(5_000, Distribution::Uniform, 7, 2);
        let fitness = SortTimingFitness::new(sample, AdaptiveSorter::new(2), 1);
        let cfg = GaConfig {
            population: 6,
            generations: 30,
            early_stop_patience: Some(2),
            seed: 17,
            ..Default::default()
        };
        let r = GaDriver::new(cfg).run(fitness);
        assert!(r.history.len() <= 31);
        assert!(r.converged_early || r.history.len() == 31);
    }

    #[test]
    fn refine_never_loses_to_its_seed_and_keeps_memoisation() {
        let sample = generate_i64(20_000, Distribution::Uniform, 7, 2);
        let mut fitness = SortTimingFitness::new(sample, AdaptiveSorter::new(2), 1);
        let driver = GaDriver::new(GaConfig { population: 6, seed: 23, ..GaConfig::quick() });
        let seed = crate::params::SortParams::paper_1e7().to_genes();
        let seed_t = fitness.eval(&seed);
        let r = driver.refine(&mut fitness, &seed, 2);
        assert_eq!(r.history.len(), 3); // gen 0 + 2
        assert!(
            r.best_fitness <= seed_t,
            "the seed genome sits in generation 0 (memoised), so best can never be worse"
        );
        // The fitness cache survives across cycles — incremental refinement
        // re-uses prior evaluations instead of re-timing them.
        let r2 = driver.refine(&mut fitness, &r.best_genome, 1);
        assert!(r2.best_fitness <= r.best_fitness);
        assert!(fitness.cache_hits() > 0, "second cycle must hit the memo cache");
    }

    #[test]
    fn run_for_size_caps_sample() {
        let driver = GaDriver::new(GaConfig { seed: 19, ..GaConfig::quick() });
        let r =
            driver.run_for_size(1_000_000, 20_000, Distribution::Uniform, AdaptiveSorter::new(2));
        assert!(r.best_fitness.is_finite());
    }

    #[test]
    fn evaluations_bounded_by_budget() {
        let r = quick_result(5_000);
        // At most population × (generations + 1) timed evals (memoisation
        // may reduce it).
        assert!(r.evaluations <= 8 * 5, "evals = {}", r.evaluations);
        assert!(r.evaluations >= 8, "gen-0 must be fully evaluated");
    }
}
