//! The EvoSort parameter vector (the GA genome) and its bounds.
//!
//! The paper's candidate solution is
//! `x = (T_insertion, T_merge, A_code, T_numpy, T_tile)` (§3.2, §4.2). We keep
//! the exact encoding — the paper's five integers, with `A_code` interpreted
//! as the algorithm selector (3 = refined parallel mergesort, 4 = block-based
//! LSD radix sort, both per Algorithm 6; 5 = the XLA tile-sort backend this
//! reproduction adds as a first-class strategy) — extended with a sixth gene,
//! `W_radix`: the radix digit width in bits (6, 8, or 11), a structural
//! parameter of the count/scan/scatter kernel the GA can hill-climb per
//! workload class.

use std::fmt;

/// Algorithm selector (the paper's `merge_algorithm` / `A_code`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ACode {
    /// Refined parallel mergesort (code 3).
    Merge,
    /// Block-based LSD radix sort (code 4) — integer dtypes only.
    Radix,
    /// XLA tile-sort backend: Pallas bitonic tiles + rust merge (code 5).
    XlaTile,
    /// Parallel samplesort (code 6) — the related-work comparison strategy
    /// (Sanders & Winkel), available as an extension beyond the paper.
    Sample,
}

impl ACode {
    pub fn code(self) -> i64 {
        match self {
            ACode::Merge => 3,
            ACode::Radix => 4,
            ACode::XlaTile => 5,
            ACode::Sample => 6,
        }
    }

    pub fn from_code(c: i64) -> ACode {
        match c {
            4 => ACode::Radix,
            5 => ACode::XlaTile,
            6 => ACode::Sample,
            _ => ACode::Merge, // the paper: "For other cases ... mergesort"
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ACode::Merge => "merge",
            ACode::Radix => "radix",
            ACode::XlaTile => "xla-tile",
            ACode::Sample => "samplesort",
        }
    }
}

/// Digit width of one LSD radix pass (the `W_radix` gene).
///
/// Only three widths are worth searching: 6 bits (64 buckets — histogram
/// matrix fits L1 even at high thread counts, more passes), 8 bits (256
/// buckets — the classic byte-digit balance), 11 bits (2048 buckets — fewer
/// passes, heavier per-pass tables; wins when passes dominate). Gene values
/// snap to the nearest representable width, so mutation anywhere in the
/// bounds range lands on a valid kernel configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RadixWidth {
    /// 6-bit digits, 64 buckets.
    W6,
    /// 8-bit digits, 256 buckets (default).
    W8,
    /// 11-bit digits, 2048 buckets.
    W11,
}

impl RadixWidth {
    /// Digit width in bits.
    pub fn bits(self) -> usize {
        match self {
            RadixWidth::W6 => 6,
            RadixWidth::W8 => 8,
            RadixWidth::W11 => 11,
        }
    }

    /// Bucket count of one pass (`1 << bits`).
    pub fn buckets(self) -> usize {
        1 << self.bits()
    }

    /// Snap an arbitrary gene value to the nearest representable width.
    pub fn from_bits(bits: i64) -> RadixWidth {
        match bits {
            i64::MIN..=7 => RadixWidth::W6,
            8..=9 => RadixWidth::W8,
            _ => RadixWidth::W11,
        }
    }

    /// Encode as the gene value (the width in bits).
    pub fn gene(self) -> i64 {
        self.bits() as i64
    }
}

impl Default for RadixWidth {
    fn default() -> Self {
        RadixWidth::W8
    }
}

/// The six-gene EvoSort configuration (the paper's five plus `W_radix`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SortParams {
    /// `T_insertion` — base chunk size handled by insertion sort.
    pub insertion_threshold: usize,
    /// `T_merge` — output size beyond which one merge is split across threads.
    pub parallel_merge_threshold: usize,
    /// `A_code` — algorithm selector for large arrays.
    pub algorithm: ACode,
    /// `T_numpy` — below this size, fall back to the tuned library routine
    /// (rust `sort_unstable`, the `np.sort` analog).
    pub fallback_threshold: usize,
    /// `T_tile` — cache tile for blocked merging / histogram staging.
    pub tile: usize,
    /// `W_radix` — digit width of one radix pass (6/8/11 bits).
    pub radix_width: RadixWidth,
}

impl Default for SortParams {
    /// Untuned defaults — intentionally mediocre; the GA's job is to beat
    /// them (the ablation bench quantifies by how much).
    fn default() -> Self {
        SortParams {
            insertion_threshold: 64,
            parallel_merge_threshold: 1 << 20,
            algorithm: ACode::Merge,
            fallback_threshold: 4096,
            tile: 1024,
            radix_width: RadixWidth::W8,
        }
    }
}

impl SortParams {
    /// The paper's §6.2 best individual for 1e7: [3075, 31291, 4, 99574, 1418].
    pub fn paper_1e7() -> Self {
        SortParams::from_genes(&[3075, 31291, 4, 99574, 1418, 8])
    }

    /// §6.3 best for 1e8: [4074, 20251, 4, 92531, 7649].
    pub fn paper_1e8() -> Self {
        SortParams::from_genes(&[4074, 20251, 4, 92531, 7649, 8])
    }

    /// §6.4 best for 5e8: [1148, 1424, 4, 67698, 22136].
    pub fn paper_5e8() -> Self {
        SortParams::from_genes(&[1148, 1424, 4, 67698, 22136, 8])
    }

    /// §6.5 best for 1e9: [2514, 24721, 4, 50840, 2020].
    pub fn paper_1e9() -> Self {
        SortParams::from_genes(&[2514, 24721, 4, 50840, 2020, 8])
    }

    /// §6.6 best for 1e10: [2670, 12456, 4, 77432, 845].
    pub fn paper_1e10() -> Self {
        SortParams::from_genes(&[2670, 12456, 4, 77432, 845, 8])
    }

    /// Decode from the genome ordering (the paper's five genes + `W_radix`).
    pub fn from_genes(g: &[i64; 6]) -> Self {
        let b = Bounds::default();
        SortParams {
            insertion_threshold: b.insertion.clamp_val(g[0]),
            parallel_merge_threshold: b.parallel_merge.clamp_val(g[1]),
            algorithm: ACode::from_code(g[2]),
            fallback_threshold: b.fallback.clamp_val(g[3]),
            tile: b.tile.clamp_val(g[4]),
            radix_width: RadixWidth::from_bits(g[5]),
        }
    }

    /// Encode to the genome ordering.
    pub fn to_genes(&self) -> [i64; 6] {
        [
            self.insertion_threshold as i64,
            self.parallel_merge_threshold as i64,
            self.algorithm.code(),
            self.fallback_threshold as i64,
            self.tile as i64,
            self.radix_width.gene(),
        ]
    }
}

impl fmt::Display for SortParams {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let g = self.to_genes();
        write!(
            f,
            "[{}, {}, {} ({}), {}, {}, w{}]",
            g[0],
            g[1],
            g[2],
            self.algorithm.name(),
            g[3],
            g[4],
            g[5]
        )
    }
}

/// Inclusive integer range for one gene.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GeneRange {
    pub lo: i64,
    pub hi: i64,
}

impl GeneRange {
    pub fn new(lo: i64, hi: i64) -> Self {
        assert!(lo <= hi);
        GeneRange { lo, hi }
    }

    pub fn clamp_val(&self, v: i64) -> usize {
        v.clamp(self.lo, self.hi) as usize
    }

    pub fn contains(&self, v: i64) -> bool {
        (self.lo..=self.hi).contains(&v)
    }

    pub fn span(&self) -> i64 {
        self.hi - self.lo
    }
}

/// Search-space bounds for the genome, matching the magnitudes the paper's
/// GA explores (§6: insertion thresholds in the thousands, merge/fallback
/// thresholds in the tens of thousands, tiles from hundreds to tens of
/// thousands).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Bounds {
    pub insertion: GeneRange,
    pub parallel_merge: GeneRange,
    pub algorithm: GeneRange,
    pub fallback: GeneRange,
    pub tile: GeneRange,
    /// `W_radix` digit-width gene, in bits; values snap to {6, 8, 11}.
    pub radix: GeneRange,
}

impl Default for Bounds {
    fn default() -> Self {
        Bounds {
            insertion: GeneRange::new(16, 100_000),
            parallel_merge: GeneRange::new(1_024, 10_000_000),
            algorithm: GeneRange::new(3, 4),
            fallback: GeneRange::new(256, 1_000_000),
            tile: GeneRange::new(64, 100_000),
            radix: GeneRange::new(6, 11),
        }
    }
}

impl Bounds {
    /// Bounds that also let the GA choose the XLA tile backend.
    pub fn with_xla() -> Self {
        Bounds { algorithm: GeneRange::new(3, 5), ..Bounds::default() }
    }

    /// Bounds including every strategy (merge, radix, xla, samplesort).
    pub fn with_all_strategies() -> Self {
        Bounds { algorithm: GeneRange::new(3, 6), ..Bounds::default() }
    }

    pub fn gene(&self, i: usize) -> GeneRange {
        match i {
            0 => self.insertion,
            1 => self.parallel_merge,
            2 => self.algorithm,
            3 => self.fallback,
            4 => self.tile,
            5 => self.radix,
            _ => panic!("gene index {i} out of range"),
        }
    }

    /// Validate a genome against the bounds.
    pub fn validate(&self, g: &[i64; 6]) -> bool {
        (0..6).all(|i| self.gene(i).contains(g[i]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acode_roundtrip() {
        assert_eq!(ACode::from_code(3), ACode::Merge);
        assert_eq!(ACode::from_code(4), ACode::Radix);
        assert_eq!(ACode::from_code(5), ACode::XlaTile);
        assert_eq!(ACode::from_code(0), ACode::Merge); // "other cases"
        for a in [ACode::Merge, ACode::Radix, ACode::XlaTile] {
            assert_eq!(ACode::from_code(a.code()), a);
        }
    }

    #[test]
    fn genome_roundtrip_paper_values() {
        let p = SortParams::paper_1e7();
        assert_eq!(p.to_genes(), [3075, 31291, 4, 99574, 1418, 8]);
        assert_eq!(p.algorithm, ACode::Radix);
        assert_eq!(p.radix_width, RadixWidth::W8);
        let q = SortParams::from_genes(&p.to_genes());
        assert_eq!(p, q);
    }

    #[test]
    fn from_genes_clamps() {
        let p = SortParams::from_genes(&[-5, 0, 4, 999_999_999, 1, 99]);
        let b = Bounds::default();
        assert_eq!(p.insertion_threshold as i64, b.insertion.lo);
        assert_eq!(p.parallel_merge_threshold as i64, b.parallel_merge.lo);
        assert_eq!(p.fallback_threshold as i64, b.fallback.hi);
        assert_eq!(p.tile as i64, b.tile.lo);
        assert_eq!(p.radix_width, RadixWidth::W11, "out-of-range width snaps");
    }

    #[test]
    fn radix_width_snaps_to_representable_values() {
        assert_eq!(RadixWidth::from_bits(i64::MIN), RadixWidth::W6);
        assert_eq!(RadixWidth::from_bits(6), RadixWidth::W6);
        assert_eq!(RadixWidth::from_bits(7), RadixWidth::W6);
        assert_eq!(RadixWidth::from_bits(8), RadixWidth::W8);
        assert_eq!(RadixWidth::from_bits(9), RadixWidth::W8);
        assert_eq!(RadixWidth::from_bits(10), RadixWidth::W11);
        assert_eq!(RadixWidth::from_bits(11), RadixWidth::W11);
        assert_eq!(RadixWidth::from_bits(i64::MAX), RadixWidth::W11);
        for w in [RadixWidth::W6, RadixWidth::W8, RadixWidth::W11] {
            assert_eq!(RadixWidth::from_bits(w.gene()), w, "gene roundtrip");
            assert_eq!(w.buckets(), 1 << w.bits());
        }
    }

    #[test]
    fn bounds_validate() {
        let b = Bounds::default();
        assert!(b.validate(&[3075, 31291, 4, 99574, 1418, 8]));
        assert!(!b.validate(&[3075, 31291, 5, 99574, 1418, 8]), "xla needs with_xla()");
        assert!(Bounds::with_xla().validate(&[3075, 31291, 5, 99574, 1418, 8]));
        assert!(!b.validate(&[0, 31291, 4, 99574, 1418, 8]));
        assert!(!b.validate(&[3075, 31291, 4, 99574, 1418, 12]), "width above bounds");
        assert!(b.validate(&[3075, 31291, 4, 99574, 1418, 6]));
        assert!(b.validate(&[3075, 31291, 4, 99574, 1418, 11]));
    }

    #[test]
    fn display_matches_paper_format() {
        let s = format!("{}", SortParams::paper_1e8());
        assert!(s.contains("4074") && s.contains("radix") && s.contains("w8"), "{s}");
    }

    #[test]
    fn all_paper_configs_pick_radix() {
        for p in [
            SortParams::paper_1e7(),
            SortParams::paper_1e8(),
            SortParams::paper_5e8(),
            SortParams::paper_1e9(),
            SortParams::paper_1e10(),
        ] {
            assert_eq!(p.algorithm, ACode::Radix);
        }
    }
}
