//! The EvoSort parameter vector (the GA genome) and its bounds.
//!
//! The paper's candidate solution is
//! `x = (T_insertion, T_merge, A_code, T_numpy, T_tile)` (§3.2, §4.2). We keep
//! the exact encoding — five integers — with `A_code` interpreted as the
//! algorithm selector (3 = refined parallel mergesort, 4 = block-based LSD
//! radix sort, both per Algorithm 6; 5 = the XLA tile-sort backend this
//! reproduction adds as a first-class strategy).

use std::fmt;

/// Algorithm selector (the paper's `merge_algorithm` / `A_code`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ACode {
    /// Refined parallel mergesort (code 3).
    Merge,
    /// Block-based LSD radix sort (code 4) — integer dtypes only.
    Radix,
    /// XLA tile-sort backend: Pallas bitonic tiles + rust merge (code 5).
    XlaTile,
    /// Parallel samplesort (code 6) — the related-work comparison strategy
    /// (Sanders & Winkel), available as an extension beyond the paper.
    Sample,
}

impl ACode {
    pub fn code(self) -> i64 {
        match self {
            ACode::Merge => 3,
            ACode::Radix => 4,
            ACode::XlaTile => 5,
            ACode::Sample => 6,
        }
    }

    pub fn from_code(c: i64) -> ACode {
        match c {
            4 => ACode::Radix,
            5 => ACode::XlaTile,
            6 => ACode::Sample,
            _ => ACode::Merge, // the paper: "For other cases ... mergesort"
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ACode::Merge => "merge",
            ACode::Radix => "radix",
            ACode::XlaTile => "xla-tile",
            ACode::Sample => "samplesort",
        }
    }
}

/// The five-gene EvoSort configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SortParams {
    /// `T_insertion` — base chunk size handled by insertion sort.
    pub insertion_threshold: usize,
    /// `T_merge` — output size beyond which one merge is split across threads.
    pub parallel_merge_threshold: usize,
    /// `A_code` — algorithm selector for large arrays.
    pub algorithm: ACode,
    /// `T_numpy` — below this size, fall back to the tuned library routine
    /// (rust `sort_unstable`, the `np.sort` analog).
    pub fallback_threshold: usize,
    /// `T_tile` — cache tile for blocked merging / histogram staging.
    pub tile: usize,
}

impl Default for SortParams {
    /// Untuned defaults — intentionally mediocre; the GA's job is to beat
    /// them (the ablation bench quantifies by how much).
    fn default() -> Self {
        SortParams {
            insertion_threshold: 64,
            parallel_merge_threshold: 1 << 20,
            algorithm: ACode::Merge,
            fallback_threshold: 4096,
            tile: 1024,
        }
    }
}

impl SortParams {
    /// The paper's §6.2 best individual for 1e7: [3075, 31291, 4, 99574, 1418].
    pub fn paper_1e7() -> Self {
        SortParams::from_genes(&[3075, 31291, 4, 99574, 1418])
    }

    /// §6.3 best for 1e8: [4074, 20251, 4, 92531, 7649].
    pub fn paper_1e8() -> Self {
        SortParams::from_genes(&[4074, 20251, 4, 92531, 7649])
    }

    /// §6.4 best for 5e8: [1148, 1424, 4, 67698, 22136].
    pub fn paper_5e8() -> Self {
        SortParams::from_genes(&[1148, 1424, 4, 67698, 22136])
    }

    /// §6.5 best for 1e9: [2514, 24721, 4, 50840, 2020].
    pub fn paper_1e9() -> Self {
        SortParams::from_genes(&[2514, 24721, 4, 50840, 2020])
    }

    /// §6.6 best for 1e10: [2670, 12456, 4, 77432, 845].
    pub fn paper_1e10() -> Self {
        SortParams::from_genes(&[2670, 12456, 4, 77432, 845])
    }

    /// Decode from the paper's 5-integer genome ordering.
    pub fn from_genes(g: &[i64; 5]) -> Self {
        let b = Bounds::default();
        SortParams {
            insertion_threshold: b.insertion.clamp_val(g[0]),
            parallel_merge_threshold: b.parallel_merge.clamp_val(g[1]),
            algorithm: ACode::from_code(g[2]),
            fallback_threshold: b.fallback.clamp_val(g[3]),
            tile: b.tile.clamp_val(g[4]),
        }
    }

    /// Encode to the genome ordering.
    pub fn to_genes(&self) -> [i64; 5] {
        [
            self.insertion_threshold as i64,
            self.parallel_merge_threshold as i64,
            self.algorithm.code(),
            self.fallback_threshold as i64,
            self.tile as i64,
        ]
    }
}

impl fmt::Display for SortParams {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let g = self.to_genes();
        write!(
            f,
            "[{}, {}, {} ({}), {}, {}]",
            g[0],
            g[1],
            g[2],
            self.algorithm.name(),
            g[3],
            g[4]
        )
    }
}

/// Inclusive integer range for one gene.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GeneRange {
    pub lo: i64,
    pub hi: i64,
}

impl GeneRange {
    pub fn new(lo: i64, hi: i64) -> Self {
        assert!(lo <= hi);
        GeneRange { lo, hi }
    }

    pub fn clamp_val(&self, v: i64) -> usize {
        v.clamp(self.lo, self.hi) as usize
    }

    pub fn contains(&self, v: i64) -> bool {
        (self.lo..=self.hi).contains(&v)
    }

    pub fn span(&self) -> i64 {
        self.hi - self.lo
    }
}

/// Search-space bounds for the genome, matching the magnitudes the paper's
/// GA explores (§6: insertion thresholds in the thousands, merge/fallback
/// thresholds in the tens of thousands, tiles from hundreds to tens of
/// thousands).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Bounds {
    pub insertion: GeneRange,
    pub parallel_merge: GeneRange,
    pub algorithm: GeneRange,
    pub fallback: GeneRange,
    pub tile: GeneRange,
}

impl Default for Bounds {
    fn default() -> Self {
        Bounds {
            insertion: GeneRange::new(16, 100_000),
            parallel_merge: GeneRange::new(1_024, 10_000_000),
            algorithm: GeneRange::new(3, 4),
            fallback: GeneRange::new(256, 1_000_000),
            tile: GeneRange::new(64, 100_000),
        }
    }
}

impl Bounds {
    /// Bounds that also let the GA choose the XLA tile backend.
    pub fn with_xla() -> Self {
        Bounds { algorithm: GeneRange::new(3, 5), ..Bounds::default() }
    }

    /// Bounds including every strategy (merge, radix, xla, samplesort).
    pub fn with_all_strategies() -> Self {
        Bounds { algorithm: GeneRange::new(3, 6), ..Bounds::default() }
    }

    pub fn gene(&self, i: usize) -> GeneRange {
        match i {
            0 => self.insertion,
            1 => self.parallel_merge,
            2 => self.algorithm,
            3 => self.fallback,
            4 => self.tile,
            _ => panic!("gene index {i} out of range"),
        }
    }

    /// Validate a genome against the bounds.
    pub fn validate(&self, g: &[i64; 5]) -> bool {
        (0..5).all(|i| self.gene(i).contains(g[i]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acode_roundtrip() {
        assert_eq!(ACode::from_code(3), ACode::Merge);
        assert_eq!(ACode::from_code(4), ACode::Radix);
        assert_eq!(ACode::from_code(5), ACode::XlaTile);
        assert_eq!(ACode::from_code(0), ACode::Merge); // "other cases"
        for a in [ACode::Merge, ACode::Radix, ACode::XlaTile] {
            assert_eq!(ACode::from_code(a.code()), a);
        }
    }

    #[test]
    fn genome_roundtrip_paper_values() {
        let p = SortParams::paper_1e7();
        assert_eq!(p.to_genes(), [3075, 31291, 4, 99574, 1418]);
        assert_eq!(p.algorithm, ACode::Radix);
        let q = SortParams::from_genes(&p.to_genes());
        assert_eq!(p, q);
    }

    #[test]
    fn from_genes_clamps() {
        let p = SortParams::from_genes(&[-5, 0, 4, 999_999_999, 1]);
        let b = Bounds::default();
        assert_eq!(p.insertion_threshold as i64, b.insertion.lo);
        assert_eq!(p.parallel_merge_threshold as i64, b.parallel_merge.lo);
        assert_eq!(p.fallback_threshold as i64, b.fallback.hi);
        assert_eq!(p.tile as i64, b.tile.lo);
    }

    #[test]
    fn bounds_validate() {
        let b = Bounds::default();
        assert!(b.validate(&[3075, 31291, 4, 99574, 1418]));
        assert!(!b.validate(&[3075, 31291, 5, 99574, 1418]), "xla needs with_xla()");
        assert!(Bounds::with_xla().validate(&[3075, 31291, 5, 99574, 1418]));
        assert!(!b.validate(&[0, 31291, 4, 99574, 1418]));
    }

    #[test]
    fn display_matches_paper_format() {
        let s = format!("{}", SortParams::paper_1e8());
        assert!(s.contains("4074") && s.contains("radix"), "{s}");
    }

    #[test]
    fn all_paper_configs_pick_radix() {
        for p in [
            SortParams::paper_1e7(),
            SortParams::paper_1e8(),
            SortParams::paper_5e8(),
            SortParams::paper_1e9(),
            SortParams::paper_1e10(),
        ] {
            assert_eq!(p.algorithm, ACode::Radix);
        }
    }
}
