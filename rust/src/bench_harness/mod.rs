//! Benchmark harness (criterion is unavailable offline): warmup + repeated
//! timed runs with median/mean/stddev reporting, plus the table printer the
//! paper-reproduction benches share.
//!
//! Every `rust/benches/*.rs` target is a `harness = false` binary built on
//! this module; each regenerates one of the paper's tables/figures and
//! prints the paper's reference values alongside the measured ones.

// Enforced boundary of the unsafe audit surface (see README
// “Correctness tooling”): timing and table printing stay entirely safe.
#![forbid(unsafe_code)]

pub mod json;
pub mod tables;

use crate::util::stats::Summary;
use crate::util::{fmt_secs, timer};

/// Measurement policy.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup: usize,
    pub repeats: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup: 1, repeats: 3 }
    }
}

impl BenchConfig {
    /// Honour `EVOSORT_BENCH_REPEATS` / `EVOSORT_BENCH_WARMUP` overrides.
    pub fn from_env() -> Self {
        let mut c = BenchConfig::default();
        if let Ok(v) = std::env::var("EVOSORT_BENCH_REPEATS") {
            if let Ok(n) = v.parse() {
                c.repeats = n;
            }
        }
        if let Ok(v) = std::env::var("EVOSORT_BENCH_WARMUP") {
            if let Ok(n) = v.parse() {
                c.warmup = n;
            }
        }
        c
    }
}

/// One benchmarked quantity.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub label: String,
    pub summary: Summary,
}

impl Measurement {
    pub fn median(&self) -> f64 {
        self.summary.median
    }
}

/// Time `op` (with per-run `setup`) under the config; reports the median.
pub fn measure<S, T>(
    config: &BenchConfig,
    label: &str,
    mut setup: impl FnMut() -> S,
    mut op: impl FnMut(S) -> T,
) -> Measurement {
    for _ in 0..config.warmup {
        let s = setup();
        std::hint::black_box(op(s));
    }
    let mut samples = Vec::with_capacity(config.repeats);
    for _ in 0..config.repeats.max(1) {
        let s = setup();
        let (out, secs) = timer::time(|| op(s));
        std::hint::black_box(out);
        samples.push(secs);
    }
    let summary = Summary::of(&samples).unwrap();
    crate::log_debug!(
        "bench {label}: median={} mean={} stddev={}",
        fmt_secs(summary.median),
        fmt_secs(summary.mean),
        fmt_secs(summary.stddev)
    );
    Measurement { label: label.to_string(), summary }
}

/// Column-aligned table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Paper reference data for Table 1: (n, evosort_secs, numpy_lo, numpy_hi).
pub const PAPER_TABLE1: &[(usize, f64, f64, f64)] = &[
    (10_000_000, 0.2416, 0.8157, 0.9733),
    (100_000_000, 0.3781, 11.1105, 13.8122),
    (500_000_000, 0.8863, 51.2772, 61.6276),
    (1_000_000_000, 1.3806, 104.9122, 127.4918),
    (5_000_000_000, 5.9955, 651.0830, 852.5336),
    (10_000_000_000, 12.7142, 1164.9239, 1164.9239),
];

/// Paper reference data for Table 2: (n, evosort_secs, numpy_secs, speedup).
pub const PAPER_TABLE2: &[(usize, f64, f64, f64)] = &[
    (100_000_000, 0.3239, 11.2331, 34.7),
    (500_000_000, 0.5862, 62.4810, 106.6),
    (1_000_000_000, 0.9960, 112.2272, 112.6),
    (5_000_000_000, 3.7241, 615.2936, 165.3),
];

/// The effective `EVOSORT_BENCH_SCALE_DIV` divisor (default 100) — the one
/// source of truth [`scaled_size`] and the bench report's provenance field
/// share.
pub fn scale_div() -> usize {
    std::env::var("EVOSORT_BENCH_SCALE_DIV")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(100usize)
        .max(1)
}

/// Scale a paper-sized n down for this testbed: divide by
/// [`scale_div`], floored at 1e5.
pub fn scaled_size(paper_n: usize) -> usize {
    (paper_n / scale_div()).max(100_000)
}

/// Format a paper-vs-measured pair.
pub fn vs(paper: f64, measured: f64) -> String {
    format!("{} (paper {})", fmt_secs(measured), fmt_secs(paper))
}

/// Header line shared by all bench binaries.
pub fn banner(name: &str, detail: &str) {
    println!("\n=== EvoSort bench: {name} ===");
    println!("{detail}");
    println!(
        "threads={} scale_div={} repeats={}\n",
        crate::util::default_threads(),
        std::env::var("EVOSORT_BENCH_SCALE_DIV").unwrap_or_else(|_| "100".into()),
        BenchConfig::from_env().repeats
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_runs_setup_each_time() {
        let mut setups = 0;
        let config = BenchConfig { warmup: 1, repeats: 3 };
        let m = measure(
            &config,
            "test",
            || {
                setups += 1;
                vec![3u64, 1, 2]
            },
            |mut v| {
                v.sort_unstable();
                v
            },
        );
        assert_eq!(setups, 4); // 1 warmup + 3 timed
        assert_eq!(m.summary.n, 3);
        assert!(m.median() >= 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["n", "time"]);
        t.row(&["1e7".into(), "0.24s".into()]);
        t.row(&["1e10".into(), "12.71s".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].len(), lines[2].len());
        assert!(lines[3].contains("12.71s"));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn scaled_size_floor() {
        assert!(scaled_size(10_000_000) >= 100_000);
        if std::env::var("EVOSORT_BENCH_SCALE_DIV").is_err() {
            assert_eq!(scaled_size(1_000_000_000), 10_000_000);
        }
    }

    #[test]
    fn paper_tables_consistent() {
        for &(_, evo, lo, hi) in PAPER_TABLE1 {
            assert!(lo <= hi);
            assert!(evo < lo, "EvoSort beats both baselines in every row");
        }
        for &(_, evo, np, speedup) in PAPER_TABLE2 {
            let s = np / evo;
            assert!((s - speedup).abs() / speedup < 0.01, "{s} vs {speedup}");
        }
    }
}
