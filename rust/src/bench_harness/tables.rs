//! Shared table-regeneration logic: Table 1 (GA-tuned EvoSort vs baselines)
//! and Table 2 (symbolic-parameter EvoSort vs baseline), at testbed scale.
//! Used by both the `evosort repro` CLI command and the bench binaries.

use crate::coordinator::{ParamSource, PipelineConfig};
use crate::data::Distribution;
use crate::ga::GaConfig;
use crate::sort::Baseline;
use crate::symbolic::SymbolicModel;
use crate::util::{fmt_count, fmt_secs};

use super::{scaled_size, Table, PAPER_TABLE1, PAPER_TABLE2};

/// Regenerate Table 1: per size, GA-tuned EvoSort vs sequential quicksort /
/// mergesort baselines. Sizes are the paper's, scaled by
/// `EVOSORT_BENCH_SCALE_DIV`.
pub fn print_table1(threads: usize) {
    let sizes: Vec<usize> = PAPER_TABLE1.iter().map(|&(n, ..)| scaled_size(n)).collect();
    let mut sizes_dedup = sizes.clone();
    sizes_dedup.dedup();
    let config = PipelineConfig {
        sizes: sizes_dedup.clone(),
        dist: Distribution::Uniform,
        seed: 42,
        threads,
        params: ParamSource::Ga(GaConfig {
            population: 10,
            generations: 5,
            seed: 42,
            ..GaConfig::default()
        }),
        sample_cap: 2_000_000,
        baselines: vec![Baseline::Quicksort, Baseline::Mergesort],
    };
    let rows = crate::coordinator::pipeline::run(&config);

    let mut table = Table::new(&[
        "paper n",
        "our n",
        "EvoSort(s)",
        "baseline(s)",
        "speedup",
        "paper EvoSort(s)",
        "paper baseline(s)",
        "paper speedup",
    ]);
    for ((paper, our_n), row) in PAPER_TABLE1.iter().zip(&sizes).zip(rows_for(&rows, &sizes)) {
        let (pn, pe, plo, phi) = *paper;
        let base_lo = row
            .baselines
            .iter()
            .map(|(_, t, _)| *t)
            .fold(f64::INFINITY, f64::min);
        table.row(&[
            fmt_count(pn),
            fmt_count(*our_n),
            fmt_secs(row.evosort_secs),
            fmt_secs(base_lo),
            format!("{:.1}x", row.best_speedup()),
            fmt_secs(pe),
            format!("{}-{}", fmt_secs(plo), fmt_secs(phi)),
            format!("{:.0}x", plo / pe),
        ]);
    }
    table.print();
    println!("(shape check: speedup should grow with n; radix should be selected for large n)");
}

/// Regenerate Table 2: symbolic-parameter EvoSort (zero tuning overhead) vs
/// the sequential quicksort baseline, at the paper's Table-2 sizes scaled.
pub fn print_table2(threads: usize) {
    let sizes: Vec<usize> = PAPER_TABLE2.iter().map(|&(n, ..)| scaled_size(n)).collect();
    let mut sizes_dedup = sizes.clone();
    sizes_dedup.dedup();
    let config = PipelineConfig {
        sizes: sizes_dedup,
        dist: Distribution::Uniform,
        seed: 43,
        threads,
        params: ParamSource::Symbolic(SymbolicModel::paper()),
        sample_cap: 0,
        baselines: vec![Baseline::Quicksort],
    };
    let rows = crate::coordinator::pipeline::run(&config);

    let mut table = Table::new(&[
        "paper n",
        "our n",
        "EvoSort(s)",
        "baseline(s)",
        "speedup",
        "paper EvoSort(s)",
        "paper NumPy(s)",
        "paper speedup",
    ]);
    for ((paper, our_n), row) in PAPER_TABLE2.iter().zip(&sizes).zip(rows_for(&rows, &sizes)) {
        let (pn, pe, pnp, ps) = *paper;
        let (_, bt, _) = row.baselines[0];
        table.row(&[
            fmt_count(pn),
            fmt_count(*our_n),
            fmt_secs(row.evosort_secs),
            fmt_secs(bt),
            format!("{:.1}x", row.best_speedup()),
            fmt_secs(pe),
            fmt_secs(pnp),
            format!("{ps:.1}x"),
        ]);
    }
    table.print();
    println!("(symbolic params: zero tuning overhead — §7.5)");
}

/// Re-expand deduplicated pipeline rows back onto the possibly-repeating
/// scaled-size list (small scale divisors can collapse adjacent paper sizes).
fn rows_for<'a>(
    rows: &'a [crate::coordinator::PipelineRow],
    sizes: &[usize],
) -> Vec<&'a crate::coordinator::PipelineRow> {
    sizes
        .iter()
        .map(|n| {
            rows.iter()
                .find(|r| r.n == *n)
                .expect("pipeline produced a row for every size")
        })
        .collect()
}
