//! The machine-readable bench report (`evosort bench --json`) and the
//! regression gate that diffs two reports (`--compare`).
//!
//! No serde is available offline, so the format is hand-rolled: a writer
//! emitting a fixed `evosort-bench-v1` schema and a minimal recursive-
//! descent JSON reader that understands exactly that schema (it parses any
//! well-formed JSON value, then maps the known fields).
//!
//! ## Hardware portability
//!
//! Raw medians do not transfer between machines, so the regression gate
//! compares each entry's **score** — a dimensionless, hardware-normalised
//! figure of merit (higher is better):
//!
//! * kernel entries: speedup over the same run's `std` baseline at the same
//!   `(dist, n)` point;
//! * the service entry: parked-executor throughput over the spawn-per-call
//!   baseline measured in the same run.
//!
//! Entries with `score <= 0` are unmeasured placeholders (the committed
//! seed baseline starts that way — `provenance` says so) and are skipped by
//! the comparison, so the gate arms itself automatically once a measured
//! baseline is committed.

use anyhow::{anyhow, bail, Context, Result};

/// One benchmarked point.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchEntry {
    /// Stable identity used to pair entries across reports,
    /// e.g. `kernel/radix/uniform/n100000` or `service/parked/j32xn100000`.
    pub id: String,
    pub median_secs: f64,
    pub mean_secs: f64,
    pub stddev_secs: f64,
    /// Elements per second implied by the median (0 when not applicable).
    pub throughput: f64,
    /// Hardware-normalised figure of merit; `<= 0` means unmeasured.
    pub score: f64,
    /// v2: per-phase kernel timings from one instrumented pass —
    /// `(metric name, seconds)`, e.g. `("kernel.radix.scatter", 0.004)`,
    /// sorted by name. Empty for uninstrumented points and for every entry
    /// parsed from a v1 report.
    pub phases: Vec<(String, f64)>,
}

/// A full bench report.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchDoc {
    /// Format tag: `evosort-bench-v2` (the writer); the reader also accepts
    /// `evosort-bench-v1` files, whose entries simply carry no phases.
    pub schema: String,
    /// `measured` or `seed-unmeasured` (the committed bootstrap baseline).
    pub provenance: String,
    pub threads: usize,
    pub scale_div: usize,
    pub entries: Vec<BenchEntry>,
}

pub const SCHEMA: &str = "evosort-bench-v2";
/// The previous schema tag; still readable so committed v1 baselines keep
/// comparing against fresh v2 reports on their shared entry ids.
pub const SCHEMA_V1: &str = "evosort-bench-v1";
pub const PROVENANCE_MEASURED: &str = "measured";
pub const PROVENANCE_SEED: &str = "seed-unmeasured";

impl BenchDoc {
    pub fn entry(&self, id: &str) -> Option<&BenchEntry> {
        self.entries.iter().find(|e| e.id == id)
    }

    /// Serialise to pretty-printed JSON.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"schema\": {},\n", quote(&self.schema)));
        out.push_str(&format!("  \"provenance\": {},\n", quote(&self.provenance)));
        out.push_str(&format!("  \"threads\": {},\n", self.threads));
        out.push_str(&format!("  \"scale_div\": {},\n", self.scale_div));
        out.push_str("  \"entries\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            out.push_str("    {");
            out.push_str(&format!("\"id\": {}, ", quote(&e.id)));
            out.push_str(&format!("\"median_secs\": {}, ", num(e.median_secs)));
            out.push_str(&format!("\"mean_secs\": {}, ", num(e.mean_secs)));
            out.push_str(&format!("\"stddev_secs\": {}, ", num(e.stddev_secs)));
            out.push_str(&format!("\"throughput\": {}, ", num(e.throughput)));
            out.push_str(&format!("\"score\": {}", num(e.score)));
            out.push_str(", \"phases\": {");
            for (j, (name, secs)) in e.phases.iter().enumerate() {
                if j > 0 {
                    out.push_str(", ");
                }
                out.push_str(&format!("{}: {}", quote(name), num(*secs)));
            }
            out.push('}');
            out.push_str(if i + 1 < self.entries.len() { "},\n" } else { "}\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Parse a report previously written by [`to_json`](Self::to_json).
    pub fn from_json(text: &str) -> Result<BenchDoc> {
        let value = Json::parse(text)?;
        let obj = value.as_object().context("bench report: top level must be an object")?;
        let schema = get_str(obj, "schema")?;
        if schema != SCHEMA && schema != SCHEMA_V1 {
            bail!(
                "bench report: unsupported schema {schema:?} (expected {SCHEMA:?} or {SCHEMA_V1:?})"
            );
        }
        let entries_val =
            find(obj, "entries").context("bench report: missing entries")?;
        let Json::Array(items) = entries_val else {
            bail!("bench report: entries must be an array");
        };
        let mut entries = Vec::with_capacity(items.len());
        for item in items {
            let e = item.as_object().context("bench entry must be an object")?;
            // v1 entries have no phases field; v2 always writes one.
            let mut phases = Vec::new();
            if let Some(Json::Object(pairs)) = find(e, "phases") {
                for (name, value) in pairs {
                    let Json::Number(secs) = value else {
                        bail!("bench report: phase {name:?} must be a number");
                    };
                    phases.push((name.clone(), *secs));
                }
            }
            entries.push(BenchEntry {
                id: get_str(e, "id")?,
                median_secs: get_num(e, "median_secs")?,
                mean_secs: get_num(e, "mean_secs")?,
                stddev_secs: get_num(e, "stddev_secs")?,
                throughput: get_num(e, "throughput")?,
                score: get_num(e, "score")?,
                phases,
            });
        }
        Ok(BenchDoc {
            schema,
            provenance: get_str(obj, "provenance")?,
            threads: get_num(obj, "threads")? as usize,
            scale_div: get_num(obj, "scale_div")? as usize,
            entries,
        })
    }
}

/// Outcome of comparing a fresh report against a committed baseline.
#[derive(Debug, Clone, PartialEq)]
pub struct Comparison {
    /// Entries whose normalised score dropped by more than the allowed
    /// factor: `(id, baseline score, new score)`.
    pub regressions: Vec<(String, f64, f64)>,
    /// Entry ids compared (score > 0 on both sides).
    pub compared: usize,
    /// Entry ids present in both reports but unmeasured on at least one
    /// side (skipped).
    pub skipped: usize,
}

impl Comparison {
    pub fn passed(&self) -> bool {
        self.regressions.is_empty()
    }
}

/// Compare `new` against `base`: an entry regresses when its score drops by
/// more than `max_regression` (e.g. `2.0` = score halved). Unmeasured
/// entries (score <= 0, as in the seed baseline) are skipped.
pub fn compare(base: &BenchDoc, new: &BenchDoc, max_regression: f64) -> Comparison {
    let max_regression = max_regression.max(1.0);
    let mut regressions = Vec::new();
    let mut compared = 0usize;
    let mut skipped = 0usize;
    for b in &base.entries {
        let Some(n) = new.entry(&b.id) else { continue };
        if b.score <= 0.0 || n.score <= 0.0 {
            skipped += 1;
            continue;
        }
        compared += 1;
        if n.score * max_regression < b.score {
            regressions.push((b.id.clone(), b.score, n.score));
        }
    }
    Comparison { regressions, compared, skipped }
}

fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn num(x: f64) -> String {
    if x.is_finite() {
        // Enough digits to round-trip bench timings; trailing-zero noise is
        // irrelevant for a machine format.
        format!("{x:.9}")
    } else {
        "0.0".into()
    }
}

fn find<'a>(obj: &'a [(String, Json)], key: &str) -> Option<&'a Json> {
    obj.iter().find(|(k, _)| k == key).map(|(_, v)| v)
}

fn get_str(obj: &[(String, Json)], key: &str) -> Result<String> {
    match find(obj, key) {
        Some(Json::String(s)) => Ok(s.clone()),
        _ => Err(anyhow!("bench report: missing string field {key:?}")),
    }
}

fn get_num(obj: &[(String, Json)], key: &str) -> Result<f64> {
    match find(obj, key) {
        Some(Json::Number(x)) => Ok(*x),
        _ => Err(anyhow!("bench report: missing numeric field {key:?}")),
    }
}

/// Minimal JSON value + recursive-descent parser (objects as ordered pairs;
/// good enough for the bench schema, not a general-purpose library).
#[derive(Debug, Clone, PartialEq)]
enum Json {
    Object(Vec<(String, Json)>),
    Array(Vec<Json>),
    String(String),
    Number(f64),
    Bool(bool),
    Null,
}

impl Json {
    fn as_object(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Object(pairs) => Some(pairs),
            _ => None,
        }
    }

    fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("json: trailing data at byte {pos}");
        }
        Ok(value)
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<()> {
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        bail!("json: expected {:?} at byte {}", c as char, *pos)
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    let Some(&c) = b.get(*pos) else { bail!("json: unexpected end of input") };
    match c {
        b'{' => parse_object(b, pos),
        b'[' => parse_array(b, pos),
        b'"' => Ok(Json::String(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_number(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, value: Json) -> Result<Json> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        bail!("json: invalid literal at byte {}", *pos)
    }
}

fn parse_object(b: &[u8], pos: &mut usize) -> Result<Json> {
    expect(b, pos, b'{')?;
    let mut pairs = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Object(pairs));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        expect(b, pos, b':')?;
        let value = parse_value(b, pos)?;
        pairs.push((key, value));
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(&b',') => *pos += 1,
            Some(&b'}') => {
                *pos += 1;
                return Ok(Json::Object(pairs));
            }
            _ => bail!("json: expected ',' or '}}' at byte {}", *pos),
        }
    }
}

fn parse_array(b: &[u8], pos: &mut usize) -> Result<Json> {
    expect(b, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Array(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(&b',') => *pos += 1,
            Some(&b']') => {
                *pos += 1;
                return Ok(Json::Array(items));
            }
            _ => bail!("json: expected ',' or ']' at byte {}", *pos),
        }
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    loop {
        let Some(&c) = b.get(*pos) else { bail!("json: unterminated string") };
        *pos += 1;
        match c {
            b'"' => return Ok(out),
            b'\\' => {
                let Some(&e) = b.get(*pos) else { bail!("json: unterminated escape") };
                *pos += 1;
                match e {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        let hex = b
                            .get(*pos..*pos + 4)
                            .context("json: truncated \\u escape")?;
                        let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)
                            .context("json: invalid \\u escape")?;
                        *pos += 4;
                        // Surrogate pairs are not emitted by our writer;
                        // map lone surrogates to the replacement character.
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => bail!("json: unknown escape at byte {}", *pos),
                }
            }
            c => {
                // Multi-byte UTF-8: copy the full sequence.
                let start = *pos - 1;
                let len = utf8_len(c);
                *pos = start + len;
                let chunk = b.get(start..start + len).context("json: truncated utf-8")?;
                out.push_str(std::str::from_utf8(chunk)?);
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos])?;
    let x: f64 = text.parse().with_context(|| format!("json: bad number {text:?}"))?;
    Ok(Json::Number(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc() -> BenchDoc {
        BenchDoc {
            schema: SCHEMA.into(),
            provenance: PROVENANCE_MEASURED.into(),
            threads: 8,
            scale_div: 100,
            entries: vec![
                BenchEntry {
                    id: "kernel/radix/uniform/n100000".into(),
                    median_secs: 0.00123,
                    mean_secs: 0.00125,
                    stddev_secs: 0.00002,
                    throughput: 81_300_000.0,
                    score: 3.4,
                    phases: vec![
                        ("kernel.radix.count".into(), 0.0004),
                        ("kernel.radix.scatter".into(), 0.0007),
                    ],
                },
                BenchEntry {
                    id: "service/parked/j32xn100000".into(),
                    median_secs: 0.5,
                    mean_secs: 0.5,
                    stddev_secs: 0.01,
                    throughput: 64.0,
                    score: 1.8,
                    phases: Vec::new(),
                },
            ],
        }
    }

    #[test]
    fn json_roundtrip() {
        let d = doc();
        let text = d.to_json();
        let back = BenchDoc::from_json(&text).expect("parse own output");
        assert_eq!(back.schema, d.schema);
        assert_eq!(back.threads, 8);
        assert_eq!(back.scale_div, 100);
        assert_eq!(back.entries.len(), 2);
        for (a, b) in back.entries.iter().zip(&d.entries) {
            assert_eq!(a.id, b.id);
            assert!((a.median_secs - b.median_secs).abs() < 1e-12);
            assert!((a.score - b.score).abs() < 1e-9);
            assert_eq!(a.phases.len(), b.phases.len());
            for ((an, av), (bn, bv)) in a.phases.iter().zip(&b.phases) {
                assert_eq!(an, bn);
                assert!((av - bv).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn v1_reports_still_parse_and_compare() {
        // A committed v1 baseline (no phases field) must keep working as a
        // --compare input against fresh v2 reports.
        let v1 = r#"{
  "schema": "evosort-bench-v1",
  "provenance": "measured",
  "threads": 8,
  "scale_div": 100,
  "entries": [
    {"id": "kernel/radix/uniform/n100000", "median_secs": 0.002, "mean_secs": 0.002, "stddev_secs": 0.0001, "throughput": 50000000.0, "score": 3.0}
  ]
}
"#;
        let base = BenchDoc::from_json(v1).expect("v1 parses");
        assert_eq!(base.schema, SCHEMA_V1);
        assert!(base.entries[0].phases.is_empty());
        let fresh = doc();
        let c = compare(&base, &fresh, 2.0);
        assert_eq!(c.compared, 1, "shared ids compare across schema versions");
        assert!(c.passed());
    }

    #[test]
    fn parser_handles_whitespace_escapes_and_rejects_garbage() {
        let v = Json::parse(" { \"a\\n\" : [ 1.5e-3 , true , null , \"x\" ] } ").unwrap();
        let obj = v.as_object().unwrap();
        assert_eq!(obj[0].0, "a\n");
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("{} trailing").is_err());
        assert!(Json::parse("{\"a\": nope}").is_err());
        assert!(BenchDoc::from_json("{\"schema\": \"other-v9\"}").is_err());
    }

    #[test]
    fn compare_flags_score_collapse_and_skips_unmeasured() {
        let base = doc();
        let mut fresh = doc();
        // Score halved exactly: 2.0x tolerance keeps it (not strictly more).
        fresh.entries[0].score = base.entries[0].score / 2.0;
        let c = compare(&base, &fresh, 2.0);
        assert!(c.passed(), "exactly-2x drop is within a 2x gate");
        assert_eq!(c.compared, 2);

        fresh.entries[0].score = base.entries[0].score / 2.1;
        let c = compare(&base, &fresh, 2.0);
        assert!(!c.passed());
        assert_eq!(c.regressions.len(), 1);
        assert_eq!(c.regressions[0].0, base.entries[0].id);

        // Unmeasured seed entries are skipped, not compared.
        let mut seed = doc();
        seed.provenance = PROVENANCE_SEED.into();
        for e in &mut seed.entries {
            e.score = 0.0;
            e.median_secs = 0.0;
        }
        let c = compare(&seed, &fresh, 2.0);
        assert!(c.passed());
        assert_eq!(c.compared, 0);
        assert_eq!(c.skipped, 2);
    }

    #[test]
    fn compare_ignores_ids_missing_from_the_new_report() {
        let base = doc();
        let mut fresh = doc();
        fresh.entries.remove(1);
        let c = compare(&base, &fresh, 2.0);
        assert!(c.passed());
        assert_eq!(c.compared, 1);
    }
}
