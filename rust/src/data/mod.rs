//! Workload generation and output validation.
//!
//! Mirrors the paper's §5 ("Dataset Generation"): arrays of integers drawn
//! uniformly from [-1e9, +1e9] with a fixed seed, generated in parallel.
//! Additional distributions (Zipf-skewed, Gaussian-clustered, nearly-sorted,
//! reverse-sorted, few-unique, organ-pipe) cover the ablation benches and the
//! adaptive dispatcher's decision surface.

pub mod validate;

use crate::exec;
use crate::rng::distributions::{gaussian, Zipf};
use crate::rng::Xoshiro256pp;

/// The paper's sampling interval: x_i ~ U(-1e9, 1e9).
pub const PAPER_LO: i64 = -1_000_000_000;
pub const PAPER_HI: i64 = 1_000_000_000;

/// Input-data shapes used across experiments.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Distribution {
    /// Uniform over [-1e9, 1e9] — the paper's workload.
    Uniform,
    /// Uniform over a custom inclusive range.
    UniformRange(i64, i64),
    /// Zipf-ranked values (skewed, many duplicates at the head).
    Zipf,
    /// Gaussian-clustered around 0, stddev 1e8.
    Gaussian,
    /// Already sorted ascending.
    Sorted,
    /// Sorted descending.
    Reverse,
    /// Sorted with `swaps_per_million` random perturbations per 1e6 elements.
    NearlySorted,
    /// Only 16 distinct values.
    FewUnique,
    /// Ascending then descending (organ pipe) — adversarial for some merges.
    OrganPipe,
    /// All elements equal.
    Constant,
}

impl Distribution {
    pub fn name(&self) -> &'static str {
        match self {
            Distribution::Uniform => "uniform",
            Distribution::UniformRange(..) => "uniform-range",
            Distribution::Zipf => "zipf",
            Distribution::Gaussian => "gaussian",
            Distribution::Sorted => "sorted",
            Distribution::Reverse => "reverse",
            Distribution::NearlySorted => "nearly-sorted",
            Distribution::FewUnique => "few-unique",
            Distribution::OrganPipe => "organ-pipe",
            Distribution::Constant => "constant",
        }
    }

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Distribution> {
        Some(match s {
            "uniform" => Distribution::Uniform,
            "zipf" => Distribution::Zipf,
            "gaussian" => Distribution::Gaussian,
            "sorted" => Distribution::Sorted,
            "reverse" => Distribution::Reverse,
            "nearly-sorted" | "nearly_sorted" => Distribution::NearlySorted,
            "few-unique" | "few_unique" => Distribution::FewUnique,
            "organ-pipe" | "organ_pipe" => Distribution::OrganPipe,
            "constant" => Distribution::Constant,
            _ => return None,
        })
    }

    pub fn all() -> &'static [Distribution] {
        &[
            Distribution::Uniform,
            Distribution::Zipf,
            Distribution::Gaussian,
            Distribution::Sorted,
            Distribution::Reverse,
            Distribution::NearlySorted,
            Distribution::FewUnique,
            Distribution::OrganPipe,
            Distribution::Constant,
        ]
    }
}

/// Generate `n` i64 values with the given distribution and seed, filling in
/// parallel with per-thread xoshiro jump streams (deterministic for a fixed
/// seed *and* thread count-independent: stream index is derived from chunk
/// index, and chunk geometry is fixed by `n`, not the machine).
pub fn generate_i64(n: usize, dist: Distribution, seed: u64, threads: usize) -> Vec<i64> {
    let mut data = vec![0i64; n];
    fill_i64(&mut data, dist, seed, threads);
    data
}

/// Number of fixed-size generation blocks (deterministic chunk geometry).
const GEN_BLOCK: usize = 1 << 20;

/// Fill an existing buffer (avoids reallocation in benches).
pub fn fill_i64(data: &mut [i64], dist: Distribution, seed: u64, threads: usize) {
    let n = data.len();
    if n == 0 {
        return;
    }
    match dist {
        Distribution::Sorted => {
            exec::parallel_for_chunks(data, threads, |idx, chunk| {
                let base = (idx * GEN_BLOCK) as i64; // monotone across chunk index only if chunks uniform; recompute below
                let _ = base;
                for x in chunk.iter_mut() {
                    *x = 0;
                }
            });
            // Simple deterministic ascending ramp (values don't need to be
            // random for the sorted case).
            for (i, x) in data.iter_mut().enumerate() {
                *x = i as i64 - (n as i64 / 2);
            }
        }
        Distribution::Reverse => {
            for (i, x) in data.iter_mut().enumerate() {
                *x = (n - i) as i64 - (n as i64 / 2);
            }
        }
        Distribution::OrganPipe => {
            let half = n / 2;
            for (i, x) in data.iter_mut().enumerate() {
                *x = if i < half { i as i64 } else { (n - i) as i64 };
            }
        }
        Distribution::Constant => {
            data.fill(42);
        }
        Distribution::NearlySorted => {
            for (i, x) in data.iter_mut().enumerate() {
                *x = i as i64;
            }
            let mut rng = Xoshiro256pp::seeded(seed);
            let swaps = (n / 1000).max(1);
            for _ in 0..swaps {
                let i = rng.below(n);
                let j = rng.below(n);
                data.swap(i, j);
            }
        }
        _ => {
            // Random fills: deterministic block geometry + per-block streams.
            let blocks: Vec<std::ops::Range<usize>> = (0..n)
                .step_by(GEN_BLOCK)
                .map(|s| s..(s + GEN_BLOCK).min(n))
                .collect();
            let nblocks = blocks.len();
            // Give each fixed block its own seed; parallelise over blocks.
            let mut views: Vec<&mut [i64]> = Vec::with_capacity(nblocks);
            let mut rest = data;
            for b in &blocks {
                let (head, tail) = rest.split_at_mut(b.len());
                views.push(head);
                rest = tail;
            }
            let fill_block = |bi: usize, chunk: &mut [i64]| {
                let mut rng = Xoshiro256pp::seeded(seed ^ (bi as u64).wrapping_mul(0x9E3779B97F4A7C15));
                match dist {
                    Distribution::Uniform => {
                        for x in chunk.iter_mut() {
                            *x = rng.range_i64(PAPER_LO, PAPER_HI);
                        }
                    }
                    Distribution::UniformRange(lo, hi) => {
                        for x in chunk.iter_mut() {
                            *x = rng.range_i64(lo, hi);
                        }
                    }
                    Distribution::Zipf => {
                        let z = Zipf::new(1_000_000, 1.1);
                        for x in chunk.iter_mut() {
                            *x = z.sample(&mut rng) as i64;
                        }
                    }
                    Distribution::Gaussian => {
                        for x in chunk.iter_mut() {
                            *x = gaussian(&mut rng, 0.0, 1e8) as i64;
                        }
                    }
                    Distribution::FewUnique => {
                        for x in chunk.iter_mut() {
                            *x = (rng.below(16) as i64) * 1_000_003 - 8_000_000;
                        }
                    }
                    _ => unreachable!("handled above"),
                }
            };
            // Parallel over blocks on the shared parked executor, grouped
            // into at most `nworkers` tasks so the caller's `threads`
            // budget still bounds generation concurrency (the executor is
            // process-wide and usually wider).
            let nworkers = threads.max(1).min(nblocks);
            if nworkers <= 1 {
                for (bi, v) in views.into_iter().enumerate() {
                    fill_block(bi, v);
                }
            } else {
                let mut groups: Vec<Vec<(usize, &mut [i64])>> =
                    (0..nworkers).map(|_| Vec::new()).collect();
                for (bi, v) in views.into_iter().enumerate() {
                    groups[bi % nworkers].push((bi, v));
                }
                exec::global().run_consume(groups, |_, work| {
                    for (bi, v) in work {
                        fill_block(bi, v);
                    }
                });
            }
        }
    }
}

/// i32 variant of [`generate_i64`] (values clamped into i32 range).
pub fn generate_i32(n: usize, dist: Distribution, seed: u64, threads: usize) -> Vec<i32> {
    let wide = generate_i64(n, dist, seed, threads);
    wide.into_iter().map(|x| x.clamp(i32::MIN as i64, i32::MAX as i64) as i32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_respects_paper_interval() {
        let xs = generate_i64(10_000, Distribution::Uniform, 42, 4);
        assert_eq!(xs.len(), 10_000);
        assert!(xs.iter().all(|&x| (PAPER_LO..=PAPER_HI).contains(&x)));
        // Not constant.
        assert!(xs.iter().any(|&x| x != xs[0]));
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let a = generate_i64(50_000, Distribution::Uniform, 7, 1);
        let b = generate_i64(50_000, Distribution::Uniform, 7, 8);
        assert_eq!(a, b, "fills must be independent of thread count");
        let c = generate_i64(50_000, Distribution::Uniform, 8, 8);
        assert_ne!(a, c, "different seeds differ");
    }

    #[test]
    fn sorted_and_reverse_shapes() {
        let s = generate_i64(1000, Distribution::Sorted, 0, 2);
        assert!(s.windows(2).all(|w| w[0] <= w[1]));
        let r = generate_i64(1000, Distribution::Reverse, 0, 2);
        assert!(r.windows(2).all(|w| w[0] >= w[1]));
    }

    #[test]
    fn organ_pipe_shape() {
        let x = generate_i64(10, Distribution::OrganPipe, 0, 1);
        assert!(x[0] <= x[4] && x[5] >= x[9]);
    }

    #[test]
    fn few_unique_cardinality() {
        let xs = generate_i64(10_000, Distribution::FewUnique, 3, 4);
        let mut uniq = xs.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert!(uniq.len() <= 16, "got {} distinct", uniq.len());
    }

    #[test]
    fn nearly_sorted_mostly_ordered() {
        let xs = generate_i64(100_000, Distribution::NearlySorted, 5, 4);
        let inversions_adjacent =
            xs.windows(2).filter(|w| w[0] > w[1]).count();
        assert!(inversions_adjacent < xs.len() / 100, "{inversions_adjacent} adjacent inversions");
    }

    #[test]
    fn i32_in_range() {
        let xs = generate_i32(1000, Distribution::Uniform, 9, 2);
        assert!(xs.iter().all(|&x| (-1_000_000_000..=1_000_000_000).contains(&x)));
    }

    #[test]
    fn parse_roundtrip() {
        for d in Distribution::all() {
            if matches!(d, Distribution::UniformRange(..)) {
                continue;
            }
            assert_eq!(Distribution::parse(d.name()), Some(*d));
        }
        assert_eq!(Distribution::parse("nope"), None);
    }

    #[test]
    fn empty_fill_is_noop() {
        let mut v: Vec<i64> = vec![];
        fill_i64(&mut v, Distribution::Uniform, 1, 4);
        assert!(v.is_empty());
    }
}
