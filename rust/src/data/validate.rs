//! Output validation: the paper's master pipeline asserts the EvoSort output
//! equals the reference sort (Algorithm 1, line 6). We validate two
//! properties, both in parallel:
//!
//! 1. **Ordering** — the output is non-decreasing.
//! 2. **Multiset preservation** — the output is a permutation of the input,
//!    checked via an order-independent commutative fingerprint (sum, xor and
//!    a mixed hash accumulated per element), which is O(n) and needs no copy
//!    of the reference array.

use crate::exec;

/// Order-independent multiset fingerprint of a slice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fingerprint {
    pub len: usize,
    pub sum: u64,
    pub xor: u64,
    pub mix: u64,
}

#[inline]
pub(crate) fn mix64(mut z: u64) -> u64 {
    // splitmix64 finaliser — a good enough per-element mixer.
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Compute the fingerprint of `data` using up to `threads` threads.
pub fn fingerprint_i64(data: &[i64], threads: usize) -> Fingerprint {
    let bounds = exec::partition_even(data.len(), threads.max(1));
    let parts = exec::parallel_map(bounds.len(), threads, |i| {
        let chunk = &data[bounds[i].clone()];
        let mut sum = 0u64;
        let mut xor = 0u64;
        let mut mix = 0u64;
        for &x in chunk {
            let u = x as u64;
            sum = sum.wrapping_add(u);
            xor ^= u;
            mix = mix.wrapping_add(mix64(u));
        }
        (sum, xor, mix)
    });
    let mut fp = Fingerprint { len: data.len(), sum: 0, xor: 0, mix: 0 };
    for (s, x, m) in parts {
        fp.sum = fp.sum.wrapping_add(s);
        fp.xor ^= x;
        fp.mix = fp.mix.wrapping_add(m);
    }
    fp
}

/// Parallel check that `data` is non-decreasing.
pub fn is_sorted_i64(data: &[i64], threads: usize) -> bool {
    if data.len() < 2 {
        return true;
    }
    let bounds = exec::partition_even(data.len(), threads.max(1));
    let oks = exec::parallel_map(bounds.len(), threads, |i| {
        let r = bounds[i].clone();
        // Include the seam with the previous chunk.
        let start = r.start.saturating_sub(1);
        data[start..r.end].windows(2).all(|w| w[0] <= w[1])
    });
    oks.into_iter().all(|ok| ok)
}

/// Validation outcome for a sort run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Sorted and a permutation of the input.
    Valid,
    /// Ordering violated.
    NotSorted { first_violation: usize },
    /// Ordered but the multiset changed (elements lost/duplicated/corrupted).
    MultisetMismatch,
}

/// Full validation: `output` must be a sorted permutation of whatever
/// produced `input_fp` (compute the fingerprint *before* sorting in place).
pub fn validate_i64(input_fp: Fingerprint, output: &[i64], threads: usize) -> Verdict {
    if let Some(pos) = first_unsorted(output) {
        return Verdict::NotSorted { first_violation: pos };
    }
    if fingerprint_i64(output, threads) != input_fp {
        return Verdict::MultisetMismatch;
    }
    Verdict::Valid
}

fn first_unsorted(data: &[i64]) -> Option<usize> {
    data.windows(2).position(|w| w[0] > w[1])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_order_independent() {
        let a = vec![3i64, -1, 7, 7, 0];
        let b = vec![7i64, 0, 3, 7, -1];
        assert_eq!(fingerprint_i64(&a, 2), fingerprint_i64(&b, 4));
    }

    #[test]
    fn fingerprint_detects_mutation() {
        let a = vec![1i64, 2, 3, 4];
        let b = vec![1i64, 2, 3, 5];
        assert_ne!(fingerprint_i64(&a, 1), fingerprint_i64(&b, 1));
        // Sum+xor alone could be fooled by paired edits; mix catches e.g.
        // {0, 3} -> {1, 2}: sums equal, xors equal.
        let c = vec![0i64, 3];
        let d = vec![1i64, 2];
        assert_eq!(
            fingerprint_i64(&c, 1).sum,
            fingerprint_i64(&d, 1).sum
        );
        assert_ne!(fingerprint_i64(&c, 1), fingerprint_i64(&d, 1));
    }

    #[test]
    fn is_sorted_seams() {
        // Violation exactly at a chunk boundary must be caught.
        let mut data: Vec<i64> = (0..1000).collect();
        assert!(is_sorted_i64(&data, 7));
        data.swap(499, 500);
        assert!(!is_sorted_i64(&data, 7));
    }

    #[test]
    fn is_sorted_trivial() {
        assert!(is_sorted_i64(&[], 4));
        assert!(is_sorted_i64(&[1], 4));
        assert!(is_sorted_i64(&[2, 2, 2], 4));
    }

    #[test]
    fn validate_full_path() {
        let input = vec![5i64, -2, 9, 0, 5];
        let fp = fingerprint_i64(&input, 2);
        let mut out = input.clone();
        out.sort_unstable();
        assert_eq!(validate_i64(fp, &out, 2), Verdict::Valid);

        let bad_order = vec![9i64, -2, 0, 5, 5];
        assert!(matches!(validate_i64(fp, &bad_order, 2), Verdict::NotSorted { .. }));

        let bad_multiset = vec![-2i64, 0, 5, 5, 10];
        assert_eq!(validate_i64(fp, &bad_multiset, 2), Verdict::MultisetMismatch);
    }
}
