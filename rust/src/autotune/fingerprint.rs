//! Workload fingerprinting: a cheap sampled sketch of a job's *actual* data.
//!
//! PR 1 keyed the tuning cache on a caller-declared distribution label, which
//! the service trusted blindly — one mislabeled job could poison the cache
//! for every future job in that size band. The fingerprint replaces the label
//! as the cache key: it is computed from the data itself (size band,
//! sortedness, duplicate ratio, value-range width, sign mix — plus a dtype
//! tag for non-i64 keys), so two jobs share a cache slot only when they
//! actually look alike. The declared `dist` string is kept on
//! [`SortRequest`](crate::coordinator::SortRequest) purely as a
//! human-readable hint.
//!
//! The sketch is deliberately coarse (a handful of buckets per feature):
//! tuned thresholds vary smoothly with workload shape (paper §7, and the
//! Fugaku study arXiv:2305.05245 shows thresholds shifting with data shape),
//! so fine-grained classes would only fragment the cache. Everything is
//! computed from a strided probe of at most [`PROBE_CAP`] elements — O(1)
//! per job regardless of n, cheap enough for the submit hot path.

use std::fmt;

use crate::sort::key::{Dtype, SortKey};

/// Elements examined per probe. Arrays no longer than this are scanned in
/// full, which makes the value features (duplicates, width, signs) exactly
/// permutation-invariant for small inputs; larger arrays are strided.
pub const PROBE_CAP: usize = 1024;

/// Sortedness class, estimated from adjacent-pair comparisons.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RunShape {
    /// >= 95% of probed adjacent pairs are non-decreasing (sorted,
    /// nearly-sorted, constant).
    Ascending,
    /// <= 5% of probed adjacent pairs are non-decreasing (reverse-sorted).
    Descending,
    /// 65–95% non-decreasing: long ascending runs with disorder mixed in.
    MostlyAscending,
    /// No dominant direction (random-looking data, organ-pipe, ...).
    Mixed,
}

impl RunShape {
    fn tag(self) -> &'static str {
        match self {
            RunShape::Ascending => "asc",
            RunShape::Descending => "desc",
            RunShape::MostlyAscending => "masc",
            RunShape::Mixed => "mix",
        }
    }
}

/// Duplicate-density class from the distinct ratio of the probe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DupLevel {
    /// < 10% of probed values are distinct (constant, few-unique).
    Heavy,
    /// 10–90% distinct (skewed data such as Zipf).
    Some,
    /// >= 90% distinct (uniform/Gaussian over wide ranges).
    Distinct,
}

impl DupLevel {
    fn tag(self) -> &'static str {
        match self {
            DupLevel::Heavy => "dupH",
            DupLevel::Some => "dupS",
            DupLevel::Distinct => "uniq",
        }
    }
}

/// Sign composition of the probed values.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SignMix {
    NonNegative,
    Negative,
    Mixed,
}

impl SignMix {
    fn tag(self) -> &'static str {
        match self {
            SignMix::NonNegative => "pos",
            SignMix::Negative => "neg",
            SignMix::Mixed => "pm",
        }
    }
}

/// The workload sketch. Hashable/comparable — this *is* the tuning-cache key
/// (via [`Fingerprint::label`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Fingerprint {
    /// Half-decade size band, identical to
    /// [`CacheKey::band_of`](crate::coordinator::tuning_cache::CacheKey::band_of).
    pub size_band: u32,
    pub runs: RunShape,
    pub dups: DupLevel,
    /// Bytes needed to span the probed value range (`ceil(bits / 8)`,
    /// 0..=8) — the radix-width estimate an LSD radix sort cares about.
    pub width_bytes: u8,
    pub signs: SignMix,
    /// Key dtype the sketch was taken over. Labels for non-`i64` dtypes
    /// carry the tag as a suffix segment, so an f64 workload can never
    /// collide with an i64 workload of the same shape in the shared
    /// [`TuningCache`](crate::coordinator::TuningCache); `i64` stays
    /// untagged so pre-dtype persisted caches and labels keep resolving.
    pub dtype: Dtype,
}

impl Fingerprint {
    /// Sketch i64 `data` with a strided probe of at most [`PROBE_CAP`]
    /// elements (the historical entry point — identical to
    /// `of_keys::<i64>`).
    pub fn of(data: &[i64]) -> Fingerprint {
        Self::of_keys(data)
    }

    /// Sketch a slice of any [`SortKey`] dtype. Value features are computed
    /// over the monotone `i64` projection
    /// ([`SortKey::to_order_i64`]), so shape classes are consistent within a
    /// dtype; the dtype tag keeps classes separate *across* dtypes.
    pub fn of_keys<K: SortKey>(data: &[K]) -> Fingerprint {
        let size_band = crate::coordinator::tuning_cache::CacheKey::band_of(data.len());
        if data.is_empty() {
            return Fingerprint {
                size_band,
                runs: RunShape::Ascending,
                dups: DupLevel::Distinct,
                width_bytes: 0,
                signs: SignMix::NonNegative,
                dtype: K::DTYPE,
            };
        }
        let probe = sample_keys(data, PROBE_CAP);

        // Value features from the probe multiset.
        let (mut min, mut max) = (i64::MAX, i64::MIN);
        let (mut any_neg, mut any_nonneg) = (false, false);
        for &x in &probe {
            min = min.min(x);
            max = max.max(x);
            if x < 0 {
                any_neg = true;
            } else {
                any_nonneg = true;
            }
        }
        let signs = match (any_neg, any_nonneg) {
            (true, false) => SignMix::Negative,
            (true, true) => SignMix::Mixed,
            _ => SignMix::NonNegative,
        };
        let span = (max as i128 - min as i128) as u64;
        let bits = 64 - span.leading_zeros();
        let width_bytes = bits.div_ceil(8) as u8;

        // The probe is not needed again: sort it in place for the dedup.
        let probe_len = probe.len();
        let mut sorted = probe;
        sorted.sort_unstable();
        sorted.dedup();
        let distinct_ratio = sorted.len() as f64 / probe_len as f64;
        let dups = if distinct_ratio < 0.10 {
            DupLevel::Heavy
        } else if distinct_ratio < 0.90 {
            DupLevel::Some
        } else {
            DupLevel::Distinct
        };

        // Sortedness from strided *adjacent* pairs of the original layout
        // (the probe above loses adjacency).
        let runs = run_shape_keys(data);

        Fingerprint { size_band, runs, dups, width_bytes, signs, dtype: K::DTYPE }
    }

    /// Canonical cache-key string, e.g. `b10:asc:uniq:w4:pm` for i64 and
    /// `b10:asc:uniq:w8:pm:f64` for tagged dtypes. Whitespace-free so it
    /// survives the tuning cache's text persistence.
    pub fn label(&self) -> String {
        let base = format!(
            "b{}:{}:{}:w{}:{}",
            self.size_band,
            self.runs.tag(),
            self.dups.tag(),
            self.width_bytes,
            self.signs.tag()
        );
        match self.dtype {
            Dtype::I64 => base,
            tagged => format!("{base}:{}", tagged.name()),
        }
    }
}

impl fmt::Display for Fingerprint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.label())
    }
}

/// Suffix segment marking a *beyond-memory* fingerprint class: the same
/// workload shape, but sorted out of core (crate::extsort). Spill genes are
/// hardware- and disk-dependent in ways the in-memory genes are not, so the
/// escalated jobs get their own cache classes instead of polluting the
/// in-RAM ones.
pub const BEYOND_MEMORY_TAG: &str = "xm";

/// Derive the beyond-memory class label from a base fingerprint label, e.g.
/// `b16:mix:uniq:w4:pm` → `b16:mix:uniq:w4:pm:xm`.
pub fn beyond_memory_label(label: &str) -> String {
    format!("{label}:{BEYOND_MEMORY_TAG}")
}

/// Is `label` a beyond-memory class?
pub fn is_beyond_memory_label(label: &str) -> bool {
    label.ends_with(":xm")
}

/// Classify sortedness from at most [`PROBE_CAP`] strided adjacent pairs
/// (total order via the monotone `i64` projection).
fn run_shape_keys<K: SortKey>(data: &[K]) -> RunShape {
    if data.len() < 2 {
        return RunShape::Ascending;
    }
    let pairs = (data.len() - 1).min(PROBE_CAP);
    let mut ascending = 0usize;
    for i in 0..pairs {
        // Spread probes evenly: j in [0, len - 2], so j + 1 is in bounds.
        let j = i * (data.len() - 1) / pairs;
        if data[j].to_order_i64() <= data[j + 1].to_order_i64() {
            ascending += 1;
        }
    }
    let frac = ascending as f64 / pairs as f64;
    if frac >= 0.95 {
        RunShape::Ascending
    } else if frac <= 0.05 {
        RunShape::Descending
    } else if frac >= 0.65 {
        RunShape::MostlyAscending
    } else {
        RunShape::Mixed
    }
}

/// Strided value sample of at most `cap` elements (the whole slice when it
/// fits). Used for the probe and for the representative samples the online
/// tuner retains per fingerprint class.
pub fn sample(data: &[i64], cap: usize) -> Vec<i64> {
    sample_keys(data, cap)
}

/// Generic strided sample: at most `cap` elements projected onto `i64`
/// through [`SortKey::to_order_i64`] (identity for i64). The tuner's GA
/// fitness sorts these proxies, so every dtype shares one tuning pipeline —
/// order structure is preserved exactly, magnitudes are not.
pub fn sample_keys<K: SortKey>(data: &[K], cap: usize) -> Vec<i64> {
    let cap = cap.max(1);
    if data.len() <= cap {
        return data.iter().map(|x| x.to_order_i64()).collect();
    }
    // Evenly spread indices over the whole slice: i * len / cap < len.
    (0..cap).map(|i| data[i * data.len() / cap].to_order_i64()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_i64, Distribution};

    #[test]
    fn empty_and_tiny_inputs() {
        let fp = Fingerprint::of(&[]);
        assert_eq!(fp.width_bytes, 0);
        assert_eq!(fp.runs, RunShape::Ascending);
        let fp1 = Fingerprint::of(&[42]);
        assert_eq!(fp1.dups, DupLevel::Distinct);
        assert_eq!(fp1.signs, SignMix::NonNegative);
        let fpn = Fingerprint::of(&[-42]);
        assert_eq!(fpn.signs, SignMix::Negative);
    }

    #[test]
    fn sorted_reverse_and_dups_distinguished() {
        let n = 50_000;
        let sorted = Fingerprint::of(&generate_i64(n, Distribution::Sorted, 1, 2));
        let reverse = Fingerprint::of(&generate_i64(n, Distribution::Reverse, 1, 2));
        let few = Fingerprint::of(&generate_i64(n, Distribution::FewUnique, 1, 2));
        let uniform = Fingerprint::of(&generate_i64(n, Distribution::Uniform, 1, 2));
        assert_eq!(sorted.runs, RunShape::Ascending);
        assert_eq!(reverse.runs, RunShape::Descending);
        assert_eq!(few.dups, DupLevel::Heavy);
        assert_eq!(uniform.dups, DupLevel::Distinct);
        let labels = [sorted.label(), reverse.label(), few.label(), uniform.label()];
        for i in 0..labels.len() {
            for j in (i + 1)..labels.len() {
                assert_ne!(labels[i], labels[j], "classes must be distinct");
            }
        }
    }

    #[test]
    fn size_band_matches_cache_banding() {
        for n in [1usize, 100, 31_623, 1_000_000] {
            let data = vec![1i64; n];
            assert_eq!(
                Fingerprint::of(&data).size_band,
                crate::coordinator::tuning_cache::CacheKey::band_of(n)
            );
        }
    }

    #[test]
    fn sample_strided_and_full() {
        let data: Vec<i64> = (0..10_000).collect();
        let s = sample(&data, 100);
        assert_eq!(s.len(), 100);
        assert_eq!(s[0], 0);
        let full = sample(&data, 20_000);
        assert_eq!(full, data);
    }

    #[test]
    fn dtype_tags_separate_classes() {
        let ints = generate_i64(50_000, Distribution::Uniform, 11, 2);
        let floats: Vec<f64> = ints.iter().map(|&x| x as f64).collect();
        let unsigneds: Vec<u64> = ints.iter().map(|&x| x.wrapping_sub(i64::MIN) as u64).collect();
        let li = Fingerprint::of(&ints).label();
        let lf = Fingerprint::of_keys(&floats).label();
        let lu = Fingerprint::of_keys(&unsigneds).label();
        assert_eq!(li.split(':').count(), 5, "i64 labels stay untagged: {li}");
        assert!(lf.ends_with(":f64"), "{lf}");
        assert!(lu.ends_with(":u64"), "{lu}");
        assert_ne!(li, lf);
        assert_ne!(li, lu);
        assert_ne!(lf, lu);
        // Same shape, same dtype, different realisation: same class.
        let floats2: Vec<f64> = generate_i64(50_000, Distribution::Uniform, 77, 2)
            .iter()
            .map(|&x| x as f64)
            .collect();
        assert_eq!(lf, Fingerprint::of_keys(&floats2).label());
        assert!(!lf.contains(char::is_whitespace));
    }

    #[test]
    fn of_keys_i64_matches_of() {
        let data = generate_i64(30_000, Distribution::Zipf, 5, 2);
        assert_eq!(Fingerprint::of(&data), Fingerprint::of_keys(&data));
        assert_eq!(Fingerprint::of(&data).dtype, crate::sort::Dtype::I64);
    }

    #[test]
    fn beyond_memory_labels_tag_and_detect() {
        let base = Fingerprint::of(&generate_i64(10_000, Distribution::Uniform, 9, 2)).label();
        let xm = beyond_memory_label(&base);
        assert!(xm.ends_with(":xm"));
        assert!(is_beyond_memory_label(&xm));
        assert!(!is_beyond_memory_label(&base));
        assert_eq!(xm.split(':').count(), base.split(':').count() + 1);
        // Tagged dtypes compose: b..:f64:xm.
        let f = beyond_memory_label("b12:mix:uniq:w8:pm:f64");
        assert!(is_beyond_memory_label(&f));
        assert_eq!(f.split(':').count(), 7);
    }

    #[test]
    fn label_is_whitespace_free() {
        let fp = Fingerprint::of(&generate_i64(10_000, Distribution::Zipf, 3, 2));
        assert!(!fp.label().contains(char::is_whitespace), "{}", fp.label());
        assert_eq!(format!("{fp}"), fp.label());
    }
}
