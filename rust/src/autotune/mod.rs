//! Online autotuning: workload fingerprinting + background GA refinement.
//!
//! The paper's central claim is that EvoSort "adapts continuously to input
//! data and system architecture". This subsystem makes adaptation a runtime
//! property of the sort service instead of an offline CLI step:
//!
//! * [`fingerprint`] — a cheap sampled sketch of each job's *actual* data
//!   (size band, sortedness, duplicate ratio, radix width, sign mix) that
//!   keys the tuning cache, replacing the caller-declared distribution label
//!   the service previously trusted blindly;
//! * [`tuner`] — a background thread fed observed fingerprints + measured
//!   latencies through a bounded non-blocking queue; it prioritises the
//!   hottest/worst classes and runs incremental
//!   [`GaDriver::refine`](crate::ga::GaDriver::refine) generations on
//!   retained data samples, publishing improved parameters into the shared
//!   [`TuningCache`](crate::coordinator::TuningCache);
//! * [`policy`] — exploration-budget control (CPU duty cycle, observation
//!   thresholds, p99 regression detection) and versioned persistence of the
//!   fingerprint-keyed parameters.
//!
//! Wired into the service via
//! [`ServiceConfig::autotune`](crate::coordinator::ServiceConfig) and the
//! `evosort serve --autotune` CLI flag. This is the seam later scaling PRs
//! (async interface, cross-process sharding) plug into: anything that can
//! emit [`Observation`](tuner::Observation)s can drive adaptation.

// Enforced boundary of the unsafe audit surface (see README
// “Correctness tooling”): No raw pointers or transmutes belong in the tuning layer;
// the unsafe concurrency lives in `exec`/`obs::ring`/`sort` only.
#![forbid(unsafe_code)]

pub mod fingerprint;
pub mod policy;
pub mod tuner;

pub use fingerprint::{DupLevel, Fingerprint, RunShape, SignMix};
pub use policy::{AutotunePolicy, ClassState};
pub use tuner::{Observation, OnlineTuner};
