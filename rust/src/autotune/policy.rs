//! Autotuner policy: exploration-budget control, per-class bookkeeping, and
//! versioned persistence of fingerprint-keyed parameters.
//!
//! The policy answers three questions for the background tuner:
//!
//! 1. **When may a class be tuned?** Never before `min_observations` jobs
//!    have been seen; after the first tuning cycle, only while the
//!    incremental-refinement budget (`max_generations_per_class`) lasts or
//!    when a latency regression is detected (recent p99 drifting past
//!    `regression_ratio` × the p99 snapshot taken when the class was last
//!    tuned — the same windows `metrics.rs` uses for batch percentiles).
//! 2. **Which class first?** The hottest/worst one: accumulated sort-seconds
//!    since the last tuning cycle, doubled for regressed classes.
//! 3. **How much CPU?** `max_cpu_share` duty-cycles the tuner thread: after
//!    a cycle that took `t` seconds it sleeps `t · (1 − s) / s`.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::coordinator::metrics::SampleWindow;
use crate::coordinator::tuning_cache::TuningCache;
use crate::params::Bounds;

/// Knobs for the online tuner. All bounds are per-class unless noted.
#[derive(Debug, Clone)]
pub struct AutotunePolicy {
    /// Observations required before a class is first eligible for tuning.
    pub min_observations: u64,
    /// Fresh observations required between tuning cycles of the same class.
    pub cooldown_observations: u64,
    /// Elements retained per class as the GA fitness sample (strided from
    /// real job data; bounds hot-path copy cost and tuner memory).
    ///
    /// Trade-off: classes whose jobs are much larger than the cap are tuned
    /// on a subsample, so genes whose thresholds exceed the sample size are
    /// not exercised by fitness — the same subsample methodology as the
    /// paper's offline GA (`GaDriver::run_for_size` caps at `sample_cap`).
    /// The p99 regression window watches *real* job latencies, so a
    /// published genome that is pessimal at full size keeps the class
    /// re-eligible until refinement repairs it; raise the cap when tuning
    /// fidelity for very large bands matters more than memcpy cost.
    pub retained_sample_cap: usize,
    /// GA generations run per tuning cycle (kept small so the tuner remains
    /// responsive to shutdown and new observations).
    pub generations_per_cycle: usize,
    /// Refinement budget: once a class has consumed this many generations,
    /// it is re-tuned only on regression.
    pub max_generations_per_class: usize,
    /// GA population per cycle.
    pub population: usize,
    /// Timed repeats per GA fitness evaluation.
    pub repeats: usize,
    /// Publish only when the GA's best beats the seed genome by at least
    /// this percentage. Timed evaluations are noisy (sub-millisecond sorts,
    /// `repeats` often 1): without a margin, the minimum of ~a-dozen noisy
    /// candidate timings beats the seed's single timing almost every cycle
    /// and the cache churns on noise.
    pub min_improvement_pct: f64,
    /// Copy a retained data sample on only every k-th observed job (the
    /// tuner keeps the latest sample per class, so most copies are wasted;
    /// this bounds hot-path memcpy cost under sustained traffic).
    pub sample_every: u64,
    /// Most classes tracked at once; least-recently-observed is evicted.
    pub max_classes: usize,
    /// Background CPU duty cycle in (0, 1]: the tuner sleeps
    /// `t · (1 − share) / share` after a cycle that took `t` seconds.
    pub max_cpu_share: f64,
    /// Recent p99 above `ratio ×` the post-tune p99 counts as a regression.
    pub regression_ratio: f64,
    /// Bounded observation queue (hot path drops, never blocks, when full).
    pub queue_capacity: usize,
    /// Gene bounds for the per-cycle GA runs. The defaults match the
    /// offline driver's; tests (and deployments that want to pin a gene,
    /// e.g. force one radix digit width) narrow ranges here.
    pub bounds: Bounds,
    /// Base seed for the per-cycle GA runs.
    pub ga_seed: u64,
    /// When set, the tuning cache is restored from this file at startup and
    /// re-persisted (versioned format) after every published improvement.
    pub persist_path: Option<PathBuf>,
}

impl Default for AutotunePolicy {
    fn default() -> Self {
        AutotunePolicy {
            min_observations: 32,
            cooldown_observations: 16,
            retained_sample_cap: 16_384,
            generations_per_cycle: 2,
            max_generations_per_class: 12,
            population: 10,
            repeats: 1,
            min_improvement_pct: 2.0,
            sample_every: 4,
            max_classes: 64,
            max_cpu_share: 0.25,
            regression_ratio: 1.5,
            queue_capacity: 1024,
            bounds: Bounds::default(),
            ga_seed: 0xA070_7E4E,
            persist_path: None,
        }
    }
}

impl AutotunePolicy {
    /// An eager configuration for tests and smoke runs: tiny observation
    /// thresholds, small samples, full CPU share.
    pub fn quick() -> Self {
        AutotunePolicy {
            min_observations: 4,
            cooldown_observations: 2,
            retained_sample_cap: 4096,
            population: 6,
            max_cpu_share: 1.0,
            // Tests want deterministic adaptation, not noise filtering.
            min_improvement_pct: 0.0,
            sample_every: 1,
            ..AutotunePolicy::default()
        }
    }
}

/// Per-fingerprint-class state the tuner accumulates between cycles.
#[derive(Debug, Default)]
pub struct ClassState {
    /// Jobs observed for this class, ever.
    pub observations: u64,
    /// `observations` snapshot at the end of the last tuning cycle.
    pub observations_at_last_tune: u64,
    /// Recent per-job sort latencies (bounded window, p99-queryable).
    pub latency: SampleWindow,
    /// Sort-seconds accumulated since the last tuning cycle (priority).
    pub secs_since_tune: f64,
    /// p99 snapshot taken when the class was last tuned.
    pub tuned_p99: Option<f64>,
    /// GA generations consumed by this class so far.
    pub generations_run: usize,
    /// Latest retained data sample (pre-sort, strided from a real job).
    pub sample: Vec<i64>,
    /// Bumped whenever `sample` is replaced — lets the tuner invalidate its
    /// per-class memoised fitness only when the sample actually changed.
    pub sample_gen: u64,
    /// Representative job size (largest seen — cache banding input).
    pub n_hint: usize,
    /// Monotone tick of the most recent observation (LRU eviction).
    pub last_seen: u64,
}

impl ClassState {
    /// Fold one observation into the class.
    pub fn observe(&mut self, n: usize, secs: f64, sample: Option<Vec<i64>>, tick: u64) {
        self.observations += 1;
        self.latency.push(secs);
        self.secs_since_tune += secs;
        self.n_hint = self.n_hint.max(n);
        self.last_seen = tick;
        if let Some(s) = sample {
            if !s.is_empty() {
                self.sample = s;
                self.sample_gen += 1;
            }
        }
    }

    /// Recent p99 drifted past the post-tune snapshot by the policy ratio.
    pub fn regressed(&self, policy: &AutotunePolicy) -> bool {
        match (self.tuned_p99, self.latency.percentile(99.0)) {
            (Some(base), Some(now)) => now > base * policy.regression_ratio.max(1.0),
            _ => false,
        }
    }

    /// May the tuner spend a cycle on this class now?
    pub fn eligible(&self, policy: &AutotunePolicy) -> bool {
        if self.sample.is_empty() || self.observations < policy.min_observations {
            return false;
        }
        if self.generations_run == 0 {
            return true;
        }
        let fresh = self.observations - self.observations_at_last_tune;
        if fresh < policy.cooldown_observations {
            return false;
        }
        self.generations_run < policy.max_generations_per_class || self.regressed(policy)
    }

    /// Scheduling priority: hottest (most accumulated sort time since the
    /// last cycle) and worst (regressed) classes first.
    pub fn priority(&self, policy: &AutotunePolicy) -> f64 {
        let boost = if self.regressed(policy) { 2.0 } else { 1.0 };
        self.secs_since_tune * boost
    }

    /// Close out a tuning cycle: snapshot p99, reset the priority clock.
    pub fn mark_tuned(&mut self, generations: usize) {
        self.generations_run += generations;
        self.observations_at_last_tune = self.observations;
        self.secs_since_tune = 0.0;
        self.tuned_p99 = self.latency.percentile(99.0);
    }
}

/// Persist fingerprint-keyed parameters in the versioned text format (the
/// tuning cache writes a `# evosort-tuning-cache v4` header; loading accepts
/// the headered formats and legacy v1 files).
pub fn persist_params(cache: &TuningCache, path: &Path) -> Result<()> {
    cache.save(path)
}

/// Restore fingerprint-keyed parameters persisted by [`persist_params`].
pub fn restore_params(path: &Path) -> Result<TuningCache> {
    TuningCache::load(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> AutotunePolicy {
        AutotunePolicy { min_observations: 3, cooldown_observations: 2, ..AutotunePolicy::quick() }
    }

    fn observed(state: &mut ClassState, count: usize, secs: f64) {
        for i in 0..count {
            state.observe(10_000, secs, Some(vec![1, 2, 3]), i as u64);
        }
    }

    #[test]
    fn not_eligible_before_min_observations() {
        let p = policy();
        let mut s = ClassState::default();
        observed(&mut s, 2, 0.01);
        assert!(!s.eligible(&p));
        observed(&mut s, 1, 0.01);
        assert!(s.eligible(&p));
    }

    #[test]
    fn not_eligible_without_sample() {
        let p = policy();
        let mut s = ClassState::default();
        for i in 0..10 {
            s.observe(10_000, 0.01, None, i);
        }
        assert!(!s.eligible(&p), "a class with no retained data cannot be tuned");
    }

    #[test]
    fn cooldown_and_budget_gate_retuning() {
        let p = policy();
        let mut s = ClassState::default();
        observed(&mut s, 5, 0.01);
        assert!(s.eligible(&p));
        s.mark_tuned(p.generations_per_cycle);
        assert!(!s.eligible(&p), "cooldown: no fresh observations yet");
        observed(&mut s, p.cooldown_observations as usize, 0.01);
        assert!(s.eligible(&p), "within refinement budget");
        // Exhaust the budget: only a regression re-qualifies the class.
        s.generations_run = p.max_generations_per_class;
        assert!(!s.eligible(&p));
        observed(&mut s, 4, 0.01 * p.regression_ratio * 20.0);
        assert!(s.regressed(&p));
        assert!(s.eligible(&p), "regressed classes bypass the budget");
    }

    #[test]
    fn priority_prefers_hot_and_regressed() {
        let p = policy();
        let mut cold = ClassState::default();
        observed(&mut cold, 5, 0.001);
        let mut hot = ClassState::default();
        observed(&mut hot, 5, 0.1);
        assert!(hot.priority(&p) > cold.priority(&p));
        // Regression doubles priority.
        let base = hot.priority(&p);
        hot.tuned_p99 = Some(1e-6);
        assert!(hot.regressed(&p));
        assert!((hot.priority(&p) - base * 2.0).abs() < 1e-12);
    }

    #[test]
    fn mark_tuned_resets_clock() {
        let mut s = ClassState::default();
        observed(&mut s, 5, 0.02);
        assert!(s.secs_since_tune > 0.0);
        s.mark_tuned(2);
        assert_eq!(s.secs_since_tune, 0.0);
        assert_eq!(s.generations_run, 2);
        assert!(s.tuned_p99.is_some());
    }
}
