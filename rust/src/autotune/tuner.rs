//! The online tuner: a background thread that turns live traffic into better
//! sort parameters.
//!
//! The sort service feeds it [`Observation`]s (fingerprint class, job size,
//! measured latency, and a small pre-sort data sample) through a **bounded**
//! queue — `observe` uses `try_send` and drops on overflow, so the hot path
//! never blocks on the tuner. The tuner thread accumulates per-class state,
//! picks the hottest/worst eligible class (see
//! [`AutotunePolicy`](super::policy::AutotunePolicy)), and runs a few
//! incremental [`GaDriver::refine`](crate::ga::GaDriver::refine) generations
//! on the retained sample, seeded from the currently cached genome. Improved
//! parameters are published straight into the shared
//! [`TuningCache`](crate::coordinator::TuningCache), where the next submit
//! picks them up — adaptation is continuous, not a preprocessing step
//! (the asynchronous-evolution pattern of EvoX, arXiv:2301.12457).
//!
//! Metrics published (via the shared registry):
//! counters `tuner.observations/dropped/cycles/generations/publishes/no_change`,
//! gauges `tuner.classes`, `tuner.cache_hit_rate`, `tuner.last_improvement_pct`.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError, TrySendError};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::{names, Metrics};
use crate::coordinator::tuning_cache::TuningCache;
use crate::extsort::ExtBounds;
use crate::ga::{GaConfig, GaDriver, SortTimingFitness};
use crate::obs::{EventKind, Tracer};
use crate::rng::Xoshiro256pp;
use crate::sort::AdaptiveSorter;
use crate::symbolic::SymbolicModel;

use super::fingerprint;
use super::policy::{self, AutotunePolicy, ClassState};

/// One observed job: everything the tuner needs, nothing it doesn't.
#[derive(Debug)]
pub struct Observation {
    /// Fingerprint label ([`Fingerprint::label`](super::Fingerprint::label))
    /// — the tuning-cache key this job resolved through. Non-i64 dtypes
    /// carry their tag in the label (e.g. `…:f64`), so per-dtype classes
    /// are tuned — and cached — independently.
    pub label: String,
    /// Job size (cache banding input).
    pub n: usize,
    /// Measured sort latency in seconds.
    pub secs: f64,
    /// Strided pre-sort sample of the job's data, retained as GA fitness
    /// input. `None` when the submitter skipped sampling.
    pub sample: Option<Vec<i64>>,
}

/// Handle to the background tuning thread. Dropping it requests a stop,
/// disconnects the queue, and joins the thread — shutdown is clean and
/// bounded by one tuning cycle.
pub struct OnlineTuner {
    tx: Option<mpsc::SyncSender<Observation>>,
    stop: Arc<AtomicBool>,
    policy: AutotunePolicy,
    metrics: Arc<Metrics>,
    /// Sequence number backing the [`wants_sample`](Self::wants_sample)
    /// every-k-th gate.
    seq: AtomicU64,
    /// Labels that have (or have been promised) a retained sample: inserted
    /// optimistically by `wants_sample`'s first-yes path and by the worker
    /// thread on ingest, removed on class eviction. Lets `wants_sample` say
    /// yes for classes that have none — a bare global modulo would starve
    /// classes whose observations interleave out of phase with the gate —
    /// without letting a same-class burst pay the sample memcpy per job.
    sampled: Arc<RwLock<HashSet<String>>>,
    handle: Option<JoinHandle<()>>,
}

impl OnlineTuner {
    /// Spawn the tuner thread. `cache` and `metrics` are shared with the
    /// sort service; `model` seeds cold classes; `threads` bounds the
    /// background sorter's parallelism (use the service's per-job budget).
    /// An enabled `tracer` records every publish/reject decision as
    /// `TunerPublished`/`TunerRejected` events under trace id 0 (tuner
    /// decisions are service-scoped, not tied to one job).
    pub fn spawn(
        policy: AutotunePolicy,
        cache: Arc<TuningCache>,
        metrics: Arc<Metrics>,
        model: SymbolicModel,
        threads: usize,
        tracer: Tracer,
    ) -> OnlineTuner {
        if let Some(path) = &policy.persist_path {
            if path.exists() {
                match policy::restore_params(path) {
                    Ok(persisted) => {
                        let restored = cache.absorb(&persisted);
                        crate::log_info!(
                            "autotune: restored {restored} tuned classes from {}",
                            path.display()
                        );
                    }
                    Err(e) => crate::log_warn!("autotune: could not restore cache: {e:#}"),
                }
            }
        }
        let (tx, rx) = mpsc::sync_channel(policy.queue_capacity.max(1));
        let stop = Arc::new(AtomicBool::new(false));
        let sampled = Arc::new(RwLock::new(HashSet::new()));
        let worker = TunerWorker {
            rx,
            cache,
            metrics: Arc::clone(&metrics),
            model,
            policy: policy.clone(),
            stop: Arc::clone(&stop),
            sampled: Arc::clone(&sampled),
            threads: threads.max(1),
            tracer,
        };
        let handle = std::thread::Builder::new()
            .name("evosort-tuner".into())
            .spawn(move || worker.run())
            .expect("spawn tuner thread");
        OnlineTuner {
            tx: Some(tx),
            stop,
            policy,
            metrics,
            seq: AtomicU64::new(0),
            sampled,
            handle: Some(handle),
        }
    }

    pub fn policy(&self) -> &AutotunePolicy {
        &self.policy
    }

    /// Sampling gate for submitters: `true` for the first job of a class
    /// with no retained sample (a class without one can never become
    /// eligible for tuning), then every
    /// [`sample_every`](AutotunePolicy::sample_every)-th call. The tuner
    /// keeps one retained sample per class, so copying one from every job
    /// would be pure hot-path waste.
    ///
    /// The label is marked **optimistically** on that first `true`: a burst
    /// of same-class jobs arriving while the tuner thread is mid-cycle (or
    /// duty-cycle sleeping) must not each pay the retained-sample memcpy
    /// and flood the observation queue. If the burst's first observation is
    /// dropped on overflow, the class's sample simply arrives with a later
    /// `sample_every`-th job.
    pub fn wants_sample(&self, label: &str) -> bool {
        if !self.sampled.read().unwrap().contains(label) {
            self.sampled.write().unwrap().insert(label.to_string());
            return true;
        }
        self.seq.fetch_add(1, Ordering::Relaxed) % self.policy.sample_every.max(1) == 0
    }

    /// Feed one observation. Never blocks: a full queue drops the
    /// observation and bumps `tuner.dropped`.
    pub fn observe(&self, obs: Observation) {
        self.metrics.incr(names::TUNER_OBSERVATIONS);
        if let Some(tx) = &self.tx {
            match tx.try_send(obs) {
                Ok(()) => {}
                Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
                    self.metrics.incr(names::TUNER_DROPPED);
                }
            }
        }
    }
}

impl Drop for OnlineTuner {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Disconnect the queue so a blocked recv wakes immediately.
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Per-class memoised GA fitness, keyed by class label and tagged with the
/// [`ClassState::sample_gen`] it was built from: incremental refinement
/// cycles re-use prior timed evaluations (the memoisation
/// [`GaDriver::refine`] documents) until the retained sample is refreshed.
/// Bounded by `max_classes` — eviction removes the entry too.
type FitnessCache = HashMap<String, (u64, SortTimingFitness)>;

/// State owned by the background thread.
struct TunerWorker {
    rx: mpsc::Receiver<Observation>,
    cache: Arc<TuningCache>,
    metrics: Arc<Metrics>,
    model: SymbolicModel,
    policy: AutotunePolicy,
    stop: Arc<AtomicBool>,
    /// Shared with [`OnlineTuner::wants_sample`]: labels holding a sample.
    sampled: Arc<RwLock<HashSet<String>>>,
    threads: usize,
    tracer: Tracer,
}

impl TunerWorker {
    fn run(self) {
        let mut classes: HashMap<String, ClassState> = HashMap::new();
        let mut fitness_cache: FitnessCache = HashMap::new();
        let mut tick: u64 = 0;
        let mut cycles: u64 = 0;
        loop {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            // Ingest whatever arrived; wake at least every 50ms to re-check
            // the stop flag and eligibility.
            match self.rx.recv_timeout(Duration::from_millis(50)) {
                Ok(obs) => {
                    tick += 1;
                    self.ingest(&mut classes, &mut fitness_cache, obs, tick);
                    while let Ok(obs) = self.rx.try_recv() {
                        tick += 1;
                        self.ingest(&mut classes, &mut fitness_cache, obs, tick);
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break,
            }
            self.publish_gauges(&classes);
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let eligible = classes
                .iter()
                .filter(|(_, s)| s.eligible(&self.policy))
                .max_by(|(_, a), (_, b)| {
                    a.priority(&self.policy).total_cmp(&b.priority(&self.policy))
                })
                .map(|(k, _)| k.clone());
            if let Some(label) = eligible {
                cycles += 1;
                let state = classes.get_mut(&label).expect("picked class exists");
                // Beyond-memory classes tune their spill genes against the
                // merge proxy; in-RAM classes run the GA over the sort genome.
                let spent = if fingerprint::is_beyond_memory_label(&label) {
                    self.ext_cycle(&label, state, cycles)
                } else {
                    self.cycle(&label, state, &mut fitness_cache, cycles)
                };
                self.throttle(spent);
            }
        }
    }

    fn ingest(
        &self,
        classes: &mut HashMap<String, ClassState>,
        fitness_cache: &mut FitnessCache,
        obs: Observation,
        tick: u64,
    ) {
        let Observation { label, n, secs, sample } = obs;
        if classes.len() >= self.policy.max_classes && !classes.contains_key(&label) {
            // Evict the least-recently-observed class to stay bounded.
            if let Some(coldest) =
                classes.iter().min_by_key(|(_, s)| s.last_seen).map(|(k, _)| k.clone())
            {
                classes.remove(&coldest);
                fitness_cache.remove(&coldest);
                self.sampled.write().unwrap().remove(&coldest);
                self.metrics.incr(names::TUNER_EVICTED);
            }
        }
        let state = classes.entry(label.clone()).or_default();
        state.observe(n, secs, sample, tick);
        if !state.sample.is_empty() && !self.sampled.read().unwrap().contains(&label) {
            self.sampled.write().unwrap().insert(label);
        }
    }

    /// One incremental tuning cycle for `label`; returns the time it took.
    fn cycle(
        &self,
        label: &str,
        state: &mut ClassState,
        fitness_cache: &mut FitnessCache,
        cycle_no: u64,
    ) -> Duration {
        let started = Instant::now();
        let seed_params = self
            .cache
            .get(state.n_hint, label)
            .unwrap_or_else(|| self.model.params_for(state.n_hint));
        let seed_genome = seed_params.to_genes();
        // Re-use the memoised fitness across cycles (incremental
        // refinement); rebuild only when the retained sample was refreshed.
        let fresh = matches!(fitness_cache.get(label), Some((g, _)) if *g == state.sample_gen);
        if !fresh {
            let built = SortTimingFitness::new(
                state.sample.clone(),
                AdaptiveSorter::new(self.threads),
                self.policy.repeats,
            );
            fitness_cache.insert(label.to_string(), (state.sample_gen, built));
        }
        let fitness = &mut fitness_cache.get_mut(label).expect("fitness just ensured").1;
        let seed_fit = fitness.eval(&seed_genome);
        // Fresh GA seed per cycle so repeated refinements of the same class
        // explore different neighbourhoods.
        let cfg = GaConfig {
            population: self.policy.population.max(2),
            generations: self.policy.generations_per_cycle,
            repeats: self.policy.repeats,
            bounds: self.policy.bounds,
            seed: self.policy.ga_seed ^ cycle_no.wrapping_mul(0x9E37_79B9_7F4A_7C15),
            ..GaConfig::default()
        };
        let gens = self.policy.generations_per_cycle.max(1);
        let result = GaDriver::new(cfg).refine(fitness, &seed_genome, gens);
        self.metrics.incr(names::TUNER_CYCLES);
        self.metrics.add(names::TUNER_GENERATIONS, gens as u64);

        // Publish only past the noise margin: a dozen single-shot timings
        // beat one seed timing by luck alone, so a raw `<` would churn the
        // cache every cycle (min_improvement_pct = 0 restores raw compare).
        let required = seed_fit * (1.0 - self.policy.min_improvement_pct.max(0.0) / 100.0);
        if result.best_genome != seed_genome && result.best_fitness < required {
            let improvement_pct = (seed_fit - result.best_fitness) / seed_fit * 100.0;
            // Record the measured fitness with the entry: it is what makes
            // cross-cache merges (router ↔ shard broadcast, persisted
            // restore) improvement-aware instead of last-writer-wins.
            self.cache.put_with_fitness(state.n_hint, label, result.best, result.best_fitness);
            self.metrics.incr(names::TUNER_PUBLISHES);
            self.metrics.set_gauge(names::TUNER_LAST_IMPROVEMENT_PCT, improvement_pct);
            if self.tracer.is_enabled() {
                self.tracer.emit(
                    0,
                    EventKind::TunerPublished {
                        fingerprint: label.into(),
                        params: result.best.to_string().into_boxed_str(),
                        fitness: result.best_fitness,
                        improvement_pct,
                    },
                );
            }
            crate::log_info!(
                "autotune: class {label} improved {improvement_pct:.1}% \
                 ({seed_fit:.6}s -> {:.6}s) with {}",
                result.best_fitness,
                result.best
            );
            if let Some(path) = &self.policy.persist_path {
                if let Err(e) = policy::persist_params(&self.cache, path) {
                    crate::log_warn!("autotune: persist failed: {e:#}");
                }
            }
        } else {
            self.metrics.incr(names::TUNER_NO_CHANGE);
            if self.tracer.is_enabled() {
                let reason =
                    if result.best_genome == seed_genome { "no_change" } else { "below_margin" };
                self.tracer.emit(
                    0,
                    EventKind::TunerRejected { fingerprint: label.into(), reason: reason.into() },
                );
            }
        }
        state.mark_tuned(gens);
        started.elapsed()
    }

    /// One tuning cycle for a beyond-memory (`:xm`) class: instead of
    /// GA-refining the in-RAM genome, run a deterministic random search
    /// over the spill genes (run size, merge fan-in) scored by the
    /// in-memory merge proxy [`simulate_fitness`](crate::extsort::simulate_fitness)
    /// on the retained sample. The spill threshold is an escalation knob,
    /// not a merge-shape one, so the search leaves it alone.
    fn ext_cycle(&self, label: &str, state: &mut ClassState, cycle_no: u64) -> Duration {
        let started = Instant::now();
        let seed_params = self
            .cache
            .get(state.n_hint, label)
            .unwrap_or_else(|| self.model.params_for(state.n_hint));
        let bounds = ExtBounds::default();
        let seed_ext =
            bounds.clamp(&self.cache.get_ext(state.n_hint, label).unwrap_or_default().to_genes());
        let repeats = self.policy.repeats.max(1);
        let seed_fit =
            crate::extsort::simulate_fitness(&state.sample, state.n_hint, &seed_ext, repeats);
        let gens = self.policy.generations_per_cycle.max(1);
        let candidates = (self.policy.population.max(2) * gens).min(64);
        let mut rng = Xoshiro256pp::seeded(
            self.policy.ga_seed ^ cycle_no.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let mut best = seed_ext;
        let mut best_fit = seed_fit;
        for _ in 0..candidates {
            let mut c = best;
            // Log-uniform run size (2^10..=2^26) and uniform fan-in
            // (2..=64), each mutated with probability 1/2 — a greedy
            // hill-climb from the incumbent.
            if rng.next_u32() % 2 == 0 {
                c.run_size = 1i64 << (10 + rng.next_u32() % 17);
            }
            if rng.next_u32() % 2 == 0 {
                c.merge_fan_in = 2 + (rng.next_u64() % 63) as i64;
            }
            let c = bounds.clamp(&c.to_genes());
            if c == best {
                continue;
            }
            let fit = crate::extsort::simulate_fitness(&state.sample, state.n_hint, &c, repeats);
            if fit < best_fit {
                best = c;
                best_fit = fit;
            }
        }
        self.metrics.incr(names::TUNER_CYCLES);
        self.metrics.add(names::TUNER_GENERATIONS, gens as u64);
        let required = seed_fit * (1.0 - self.policy.min_improvement_pct.max(0.0) / 100.0);
        if best != seed_ext && seed_fit > 0.0 && best_fit < required {
            let improvement_pct = (seed_fit - best_fit) / seed_fit * 100.0;
            self.cache.put_ext_with_fitness(state.n_hint, label, seed_params, best, best_fit);
            self.metrics.incr(names::TUNER_PUBLISHES);
            self.metrics.incr(names::TUNER_EXT_PUBLISHES);
            self.metrics.set_gauge(names::TUNER_LAST_IMPROVEMENT_PCT, improvement_pct);
            if self.tracer.is_enabled() {
                self.tracer.emit(
                    0,
                    EventKind::TunerPublished {
                        fingerprint: label.into(),
                        params: format!(
                            "run_size={} merge_fan_in={} spill_threshold={}",
                            best.run_size, best.merge_fan_in, best.spill_threshold
                        )
                        .into_boxed_str(),
                        fitness: best_fit,
                        improvement_pct,
                    },
                );
            }
            crate::log_info!(
                "autotune: spill class {label} improved {improvement_pct:.1}% \
                 (run_size={} fan_in={})",
                best.run_size,
                best.merge_fan_in
            );
            if let Some(path) = &self.policy.persist_path {
                if let Err(e) = policy::persist_params(&self.cache, path) {
                    crate::log_warn!("autotune: persist failed: {e:#}");
                }
            }
        } else {
            self.metrics.incr(names::TUNER_NO_CHANGE);
            if self.tracer.is_enabled() {
                let reason = if best == seed_ext { "no_change" } else { "below_margin" };
                self.tracer.emit(
                    0,
                    EventKind::TunerRejected { fingerprint: label.into(), reason: reason.into() },
                );
            }
        }
        state.mark_tuned(gens);
        started.elapsed()
    }

    fn publish_gauges(&self, classes: &HashMap<String, ClassState>) {
        self.metrics.set_gauge(names::TUNER_CLASSES, classes.len() as f64);
        if let Some(rate) = self.metrics.counter_ratio(names::PARAMS_CACHE_HIT, names::PARAMS_CACHE_MISS) {
            self.metrics.set_gauge(names::TUNER_CACHE_HIT_RATE, rate);
        }
    }

    /// Duty-cycle the thread: after a cycle that took `spent`, sleep
    /// `spent · (1 − share) / share`, in short slices so stop stays snappy.
    fn throttle(&self, spent: Duration) {
        let share = self.policy.max_cpu_share.clamp(0.01, 1.0);
        let mut idle = spent.mul_f64((1.0 - share) / share);
        while !idle.is_zero() && !self.stop.load(Ordering::SeqCst) {
            let slice = idle.min(Duration::from_millis(25));
            std::thread::sleep(slice);
            idle -= slice;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::fingerprint::{self, Fingerprint};
    use crate::data::{generate_i64, Distribution};

    fn tuner_fixture(policy: AutotunePolicy) -> (OnlineTuner, Arc<TuningCache>, Arc<Metrics>) {
        let cache = Arc::new(TuningCache::new());
        let metrics = Arc::new(Metrics::new());
        let tuner = OnlineTuner::spawn(
            policy,
            Arc::clone(&cache),
            Arc::clone(&metrics),
            SymbolicModel::paper(),
            2,
            Tracer::disabled(),
        );
        (tuner, cache, metrics)
    }

    fn wait_until(deadline_secs: f64, mut cond: impl FnMut() -> bool) -> bool {
        let deadline = Instant::now() + Duration::from_secs_f64(deadline_secs);
        while Instant::now() < deadline {
            if cond() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        cond()
    }

    #[test]
    fn tunes_a_hot_class_and_publishes_params() {
        let cache = Arc::new(TuningCache::new());
        let metrics = Arc::new(Metrics::new());
        let tracer = Tracer::enabled(1024, u32::MAX);
        let tuner = OnlineTuner::spawn(
            AutotunePolicy::quick(),
            Arc::clone(&cache),
            Arc::clone(&metrics),
            SymbolicModel::paper(),
            2,
            tracer.clone(),
        );
        let data = generate_i64(20_000, Distribution::Uniform, 1, 2);
        let label = Fingerprint::of(&data).label();
        let sample = fingerprint::sample(&data, 4096);
        for _ in 0..8 {
            tuner.observe(Observation {
                label: label.clone(),
                n: data.len(),
                secs: 0.004,
                sample: Some(sample.clone()),
            });
        }
        assert!(
            wait_until(30.0, || metrics.counter(names::TUNER_CYCLES) > 0),
            "tuner never ran a cycle"
        );
        // A cycle ran; the cache gains the class params once the GA finds an
        // improvement over the symbolic seed (usually the first cycle on a
        // 4k-element sample). Feed observations until it does.
        let published = wait_until(30.0, || {
            tuner.observe(Observation {
                label: label.clone(),
                n: data.len(),
                secs: 0.004,
                sample: Some(sample.clone()),
            });
            cache.get(data.len(), &label).is_some()
        });
        assert!(published, "no parameters published for the hot class");
        assert!(metrics.counter(names::TUNER_GENERATIONS) > 0);
        // The publish decision was traced (trace id 0, tuner-scoped).
        let mut events = Vec::new();
        tracer.drain_into(&mut events);
        let publish = events
            .iter()
            .find(|e| e.kind.name() == "tuner_published")
            .expect("publish decision traced");
        assert_eq!(publish.trace_id, 0);
        if let EventKind::TunerPublished { fingerprint, improvement_pct, .. } = &publish.kind {
            assert_eq!(&**fingerprint, label.as_str());
            assert!(*improvement_pct > 0.0);
        }
        drop(tuner); // must join cleanly
    }

    #[test]
    fn drop_shuts_down_promptly_without_traffic() {
        let (tuner, _cache, _metrics) = tuner_fixture(AutotunePolicy::quick());
        let started = Instant::now();
        drop(tuner);
        assert!(
            started.elapsed() < Duration::from_secs(5),
            "idle tuner must shut down quickly"
        );
    }

    #[test]
    fn queue_overflow_drops_instead_of_blocking() {
        let policy = AutotunePolicy {
            queue_capacity: 2,
            min_observations: u64::MAX, // never tune: queue fills up
            ..AutotunePolicy::quick()
        };
        let (tuner, _cache, metrics) = tuner_fixture(policy);
        let started = Instant::now();
        for i in 0..500 {
            tuner.observe(Observation {
                label: "b9:mix:uniq:w4:pm".into(),
                n: 10_000,
                secs: 0.001,
                sample: if i == 0 { Some(vec![3, 1, 2]) } else { None },
            });
        }
        assert!(
            started.elapsed() < Duration::from_secs(2),
            "observe must never block the caller"
        );
        assert_eq!(metrics.counter(names::TUNER_OBSERVATIONS), 500);
        drop(tuner);
    }

    #[test]
    fn beyond_memory_class_tunes_spill_genes() {
        use crate::extsort::ExtParams;
        let (tuner, cache, metrics) = tuner_fixture(AutotunePolicy::quick());
        let data = generate_i64(20_000, Distribution::Uniform, 5, 2);
        let label = fingerprint::beyond_memory_label(&Fingerprint::of(&data).label());
        let n_hint = 5_000_000; // pretend the class is far beyond RAM
        // Seed the class with pathological spill genes: minimum runs,
        // minimum fan-in — almost any candidate the search tries beats it.
        let awful = ExtParams { run_size: 1024, merge_fan_in: 2, spill_threshold: 0 };
        cache.put_ext_with_fitness(n_hint, &label, SymbolicModel::paper().params_for(n_hint), awful, 1e9);
        let sample = fingerprint::sample(&data, 4096);
        let tuned = wait_until(30.0, || {
            tuner.observe(Observation {
                label: label.clone(),
                n: n_hint,
                secs: 0.5,
                sample: Some(sample.clone()),
            });
            cache.get_ext(n_hint, &label) != Some(awful)
        });
        assert!(tuned, "spill genes never improved for the :xm class");
        assert!(metrics.counter(names::TUNER_EXT_PUBLISHES) > 0);
        let tuned_ext = cache.get_ext(n_hint, &label).expect("ext genes cached");
        assert!(tuned_ext.run_size >= 1024 && tuned_ext.merge_fan_in >= 2);
        drop(tuner);
    }

    #[test]
    fn width_gene_publishes_non_default_radix_width() {
        use crate::params::{Bounds, GeneRange, RadixWidth, SortParams};
        // Pin the width gene to W11 via the policy bounds: every genome the
        // GA generates carries the non-default width, so a publish proves
        // the gene flows GA -> cache end to end.
        let policy = AutotunePolicy {
            bounds: Bounds { radix: GeneRange::new(10, 11), ..Bounds::default() },
            ..AutotunePolicy::quick()
        };
        let (tuner, cache, _metrics) = tuner_fixture(policy);
        let data = generate_i64(20_000, Distribution::Uniform, 7, 2);
        let label = Fingerprint::of(&data).label();
        // Seed the class with a pathologically slow genome (insertion-sorts
        // the whole retained sample at the default W8 width) so GA cycles
        // reliably find something to publish over it.
        cache.put(data.len(), &label, SortParams::from_genes(&[100_000, 31291, 4, 99574, 1418, 8]));
        let sample = fingerprint::sample(&data, 4096);
        let published = wait_until(30.0, || {
            tuner.observe(Observation {
                label: label.clone(),
                n: data.len(),
                secs: 0.004,
                sample: Some(sample.clone()),
            });
            // fitness.is_some() = the entry came from the tuner's publish
            // path, not our explicit pre-seed put.
            cache
                .entry(data.len(), &label)
                .is_some_and(|e| e.fitness.is_some() && e.params.radix_width == RadixWidth::W11)
        });
        assert!(published, "tuner never published a W11-width genome for the class");
        drop(tuner);
    }

    #[test]
    fn class_eviction_stays_bounded() {
        let policy = AutotunePolicy {
            max_classes: 4,
            min_observations: u64::MAX,
            ..AutotunePolicy::quick()
        };
        let (tuner, _cache, metrics) = tuner_fixture(policy);
        for i in 0..32 {
            tuner.observe(Observation {
                label: format!("b9:mix:uniq:w{i}:pm"),
                n: 10_000,
                secs: 0.001,
                sample: None,
            });
        }
        assert!(wait_until(10.0, || metrics.counter(names::TUNER_EVICTED) >= 28));
        assert!(wait_until(10.0, || metrics.gauge(names::TUNER_CLASSES) == Some(4.0)));
        drop(tuner);
    }
}
