//! The XLA tile-sort backend: a `Send + Sync` front over the (thread-pinned)
//! PJRT engine.
//!
//! The `xla` crate's client/executable types are raw-pointer wrappers and
//! cannot cross threads, so a dedicated worker thread owns the
//! [`PjRtEngine`](super::engine::PjRtEngine) and serves requests over an
//! mpsc channel. This also serialises access to the CPU PJRT client, which
//! is the correct discipline for a shared accelerator queue.

use std::sync::mpsc;
use std::sync::Mutex;

use anyhow::{anyhow, Result};

use super::artifacts::Manifest;
use super::engine::PjRtEngine;
use crate::sort::TileSorter;

enum Request {
    SortTiles { data: Vec<i32>, reply: mpsc::Sender<Result<Vec<i32>>> },
    Histogram { data: Vec<i32>, shift: i32, reply: mpsc::Sender<Result<Vec<i32>>> },
    Shutdown,
}

/// Channel-fronted PJRT tile sorter (implements [`TileSorter`]).
pub struct XlaTileSorter {
    tx: Mutex<mpsc::Sender<Request>>,
    batch: usize,
    tile: usize,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl XlaTileSorter {
    /// Spin up the worker thread, load + compile artifacts from `manifest`.
    /// Fails fast (before returning) if compilation fails.
    pub fn new(manifest: &Manifest) -> Result<Self> {
        let entry = manifest
            .find("tile_sort")
            .ok_or_else(|| anyhow!("manifest has no tile_sort artifact"))?;
        let (batch, tile) = (entry.batch, entry.tile);
        let manifest = manifest.clone();
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let worker = std::thread::Builder::new()
            .name("evosort-pjrt".into())
            .spawn(move || {
                let engine = match PjRtEngine::from_manifest(&manifest) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::SortTiles { data, reply } => {
                            let _ = reply.send(engine.run_tile_sort(&data));
                        }
                        Request::Histogram { data, shift, reply } => {
                            let _ = reply.send(engine.run_radix_hist(&data, shift));
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .expect("spawn pjrt worker");
        ready_rx.recv().map_err(|_| anyhow!("pjrt worker died during init"))??;
        Ok(XlaTileSorter { tx: Mutex::new(tx), batch, tile, worker: Some(worker) })
    }

    /// Convenience: discover artifacts in the default directory.
    pub fn from_default_artifacts() -> Result<Self> {
        let dir = Manifest::default_dir();
        let manifest = Manifest::load(&dir)?;
        Self::new(&manifest)
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    fn call(&self, req_of: impl FnOnce(mpsc::Sender<Result<Vec<i32>>>) -> Request) -> Result<Vec<i32>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(req_of(reply_tx))
            .map_err(|_| anyhow!("pjrt worker gone"))?;
        reply_rx.recv().map_err(|_| anyhow!("pjrt worker dropped reply"))?
    }

    /// Run one full (batch × tile) buffer through the tile-sort executable.
    pub fn sort_batch(&self, data: Vec<i32>) -> Result<Vec<i32>> {
        self.call(|reply| Request::SortTiles { data, reply })
    }

    /// Per-block histograms via the radix_hist executable.
    pub fn histogram_batch(&self, data: Vec<i32>, shift: i32) -> Result<Vec<i32>> {
        self.call(|reply| Request::Histogram { data, shift, reply })
    }
}

impl Drop for XlaTileSorter {
    fn drop(&mut self) {
        if let Ok(tx) = self.tx.lock() {
            let _ = tx.send(Request::Shutdown);
        }
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl TileSorter for XlaTileSorter {
    fn tile_size(&self) -> usize {
        self.tile
    }

    /// Sort every `tile`-wide chunk of `data` (len must be a multiple of the
    /// tile size). Full batches go through the executable directly; a final
    /// partial batch is padded with i32::MAX rows, executed, and truncated.
    fn sort_tiles_i32(&self, data: &mut [i32]) -> Result<()> {
        anyhow::ensure!(
            data.len() % self.tile == 0,
            "data length {} not a multiple of tile {}",
            data.len(),
            self.tile
        );
        let batch_elems = self.batch * self.tile;
        let mut offset = 0;
        while offset < data.len() {
            let remaining = data.len() - offset;
            let take = remaining.min(batch_elems);
            let mut buf = Vec::with_capacity(batch_elems);
            buf.extend_from_slice(&data[offset..offset + take]);
            buf.resize(batch_elems, i32::MAX); // pad rows sort to all-MAX
            let sorted = self.sort_batch(buf)?;
            data[offset..offset + take].copy_from_slice(&sorted[..take]);
            offset += take;
        }
        Ok(())
    }
}
