//! Artifact discovery: locate the HLO text files `make artifacts` emitted and
//! parse `manifest.txt` (`<kind> <file> <batch> <tile>` per line).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One entry of `artifacts/manifest.txt`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactEntry {
    pub kind: String,
    pub path: PathBuf,
    pub batch: usize,
    pub tile: usize,
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Resolve the artifacts directory: `$EVOSORT_ARTIFACTS`, else
    /// `./artifacts`, else `<exe_dir>/../../artifacts` (target/release/..).
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("EVOSORT_ARTIFACTS") {
            return PathBuf::from(d);
        }
        let local = PathBuf::from("artifacts");
        if local.join("manifest.txt").exists() {
            return local;
        }
        if let Ok(exe) = std::env::current_exe() {
            if let Some(dir) = exe.parent() {
                let candidate = dir.join("../../artifacts");
                if candidate.join("manifest.txt").exists() {
                    return candidate;
                }
            }
        }
        local
    }

    /// Load and parse `manifest.txt` from `dir`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let mpath = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {} (run `make artifacts`)", mpath.display()))?;
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 4 {
                bail!("manifest line {} malformed: {line:?}", lineno + 1);
            }
            let entry = ArtifactEntry {
                kind: parts[0].to_string(),
                path: dir.join(parts[1]),
                batch: parts[2].parse().context("batch field")?,
                tile: parts[3].parse().context("tile field")?,
            };
            if !entry.path.exists() {
                bail!("artifact file missing: {}", entry.path.display());
            }
            entries.push(entry);
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    pub fn find(&self, kind: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.kind == kind)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_fixture(dir: &Path, manifest: &str, files: &[&str]) {
        std::fs::create_dir_all(dir).unwrap();
        for f in files {
            std::fs::File::create(dir.join(f)).unwrap().write_all(b"HloModule x").unwrap();
        }
        std::fs::write(dir.join("manifest.txt"), manifest).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("evosort-artifacts-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn parses_valid_manifest() {
        let dir = tmpdir("ok");
        write_fixture(&dir, "tile_sort a.hlo.txt 32 1024\nradix_hist b.hlo.txt 32 1024\n", &["a.hlo.txt", "b.hlo.txt"]);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 2);
        let ts = m.find("tile_sort").unwrap();
        assert_eq!(ts.batch, 32);
        assert_eq!(ts.tile, 1024);
        assert!(m.find("nope").is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_missing_file() {
        let dir = tmpdir("missing");
        write_fixture(&dir, "tile_sort ghost.hlo.txt 8 64\n", &[]);
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_malformed_line() {
        let dir = tmpdir("malformed");
        write_fixture(&dir, "tile_sort a.hlo.txt 8\n", &["a.hlo.txt"]);
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn skips_comments_and_blanks() {
        let dir = tmpdir("comments");
        write_fixture(&dir, "# comment\n\ntile_sort a.hlo.txt 8 64\n", &["a.hlo.txt"]);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_manifest_is_error() {
        let dir = tmpdir("nomanifest");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
