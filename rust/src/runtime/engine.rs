//! PJRT engine: load HLO-text artifacts, compile once, execute many times.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin). The engine is **not**
//! `Send`/`Sync` — the crate's types are raw-pointer wrappers — so it lives
//! on a dedicated worker thread (see [`super::xla_sort`]) and everything
//! crossing threads is plain `Vec<i32>`.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::artifacts::Manifest;
// Offline builds have no PJRT bindings; the shim mirrors the `xla` crate's
// API and fails at runtime, keeping the optional-backend fallbacks intact.
// Swap this import for `use xla;` when the real bindings are linked.
use super::xla_shim as xla;

/// A compiled artifact plus its shape metadata.
pub struct CompiledKernel {
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub tile: usize,
}

/// The PJRT CPU engine: one client, one compiled executable per artifact.
pub struct PjRtEngine {
    client: xla::PjRtClient,
    kernels: HashMap<String, CompiledKernel>,
}

impl PjRtEngine {
    /// Create a CPU client and compile every artifact in the manifest.
    pub fn from_manifest(manifest: &Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
        crate::log_info!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        let mut kernels = HashMap::new();
        for entry in &manifest.entries {
            let compiled = Self::compile_file(&client, &entry.path)
                .with_context(|| format!("compiling {}", entry.path.display()))?;
            kernels.insert(
                entry.kind.clone(),
                CompiledKernel { exe: compiled, batch: entry.batch, tile: entry.tile },
            );
            crate::log_info!("compiled artifact '{}' (batch={} tile={})", entry.kind, entry.batch, entry.tile);
        }
        Ok(PjRtEngine { client, kernels })
    }

    fn compile_file(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse HLO text: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client.compile(&comp).map_err(|e| anyhow!("compile: {e}"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn kernel(&self, kind: &str) -> Option<&CompiledKernel> {
        self.kernels.get(kind)
    }

    /// Execute the tile-sort artifact on one (batch × tile) i32 buffer,
    /// returning the row-sorted buffer.
    pub fn run_tile_sort(&self, input: &[i32]) -> Result<Vec<i32>> {
        let k = self.kernel("tile_sort").ok_or_else(|| anyhow!("tile_sort artifact missing"))?;
        anyhow::ensure!(
            input.len() == k.batch * k.tile,
            "tile_sort expects {}x{} = {} elements, got {}",
            k.batch,
            k.tile,
            k.batch * k.tile,
            input.len()
        );
        let lit = xla::Literal::vec1(input)
            .reshape(&[k.batch as i64, k.tile as i64])
            .map_err(|e| anyhow!("reshape input: {e}"))?;
        let result = k
            .exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow!("execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e}"))?;
        // Lowered with return_tuple=True -> 1-tuple.
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
        out.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e}"))
    }

    /// Execute the radix-histogram artifact: (batch × tile) i32 + shift →
    /// batch × 256 counts.
    pub fn run_radix_hist(&self, input: &[i32], shift: i32) -> Result<Vec<i32>> {
        let k = self.kernel("radix_hist").ok_or_else(|| anyhow!("radix_hist artifact missing"))?;
        anyhow::ensure!(
            input.len() == k.batch * k.tile,
            "radix_hist expects {} elements, got {}",
            k.batch * k.tile,
            input.len()
        );
        let lit = xla::Literal::vec1(input)
            .reshape(&[k.batch as i64, k.tile as i64])
            .map_err(|e| anyhow!("reshape: {e}"))?;
        let shift_lit = xla::Literal::vec1(&[shift]);
        let result = k
            .exe
            .execute::<xla::Literal>(&[lit, shift_lit])
            .map_err(|e| anyhow!("execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
        out.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e}"))
    }
}
