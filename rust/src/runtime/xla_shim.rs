//! Offline stand-in for the external `xla` crate (PJRT C-API bindings).
//!
//! The build environment carries no PJRT plugin or `xla` bindings, so this
//! module mirrors the exact API surface [`super::engine`] consumes and fails
//! at *runtime* (client construction / HLO parsing return `Err`), never at
//! compile time. Every caller already treats the backend as optional — the
//! adaptive dispatcher falls back to the refined parallel mergesort when
//! `A_code = 5` has no backend, the CLI warns-and-continues, and the runtime
//! integration tests skip — so the whole crate stays buildable and testable
//! with zero native dependencies. Linking the real `xla` crate back in is a
//! one-line import swap in `engine.rs`.

#![allow(dead_code)]

use std::fmt;

/// Error type mirroring `xla::Error` (only `Display` is consumed).
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla_shim::Error({})", self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT runtime not linked into this build (offline xla shim); \
         the tile backend is unavailable and callers fall back to the rust sorts"
            .to_string(),
    )
}

/// Mirrors `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "offline-shim".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Mirrors `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// Mirrors `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Mirrors `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Mirrors `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Mirrors `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}
