//! PJRT runtime bridge (Layer-1/2 ↔ Layer-3 seam).
//!
//! `make artifacts` lowers the JAX/Pallas graphs to HLO text once; this
//! module loads them (`artifacts`), compiles them on the PJRT CPU client
//! (`engine`), and exposes a thread-safe [`XlaTileSorter`] backend
//! (`xla_sort`) the adaptive dispatcher can select with `A_code = 5`.
//! Python never runs at request time.

pub mod artifacts;
pub mod engine;
pub(crate) mod xla_shim;
pub mod xla_sort;

pub use artifacts::{ArtifactEntry, Manifest};
pub use engine::PjRtEngine;
pub use xla_sort::XlaTileSorter;
