//! Typed trace events and the per-phase kernel timer.
//!
//! Every event the observability layer moves — through the in-process ring,
//! over the shard wire, into the JSONL log — is one [`TraceEvent`]: a trace
//! id (the job's router-level id, or the service-local id when no router is
//! involved), the shard that observed it, a wall-clock microsecond stamp,
//! and a typed [`EventKind`]. The hot-path kinds (`Submitted` … `Failed`)
//! carry only `Copy` data so emitting one never allocates; the tuner kinds
//! carry owned strings but are produced on the tuner's background thread,
//! never on a sort path.

use std::time::Instant;

use crate::coordinator::metrics::names;

/// The shard id the router stamps on its own events (`u32::MAX` — real
/// shards are small indices). Rendered as `router` in the trace CLI.
pub const ROUTER_SHARD: u32 = u32::MAX;

/// Why a job terminated without a [`Completed`](EventKind::Completed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailReason {
    Cancelled,
    WorkerLost,
    Overloaded,
}

impl FailReason {
    pub fn name(self) -> &'static str {
        match self {
            FailReason::Cancelled => "cancelled",
            FailReason::WorkerLost => "worker_lost",
            FailReason::Overloaded => "overloaded",
        }
    }

    pub fn from_name(s: &str) -> Option<FailReason> {
        Some(match s {
            "cancelled" => FailReason::Cancelled,
            "worker_lost" => FailReason::WorkerLost,
            "overloaded" => FailReason::Overloaded,
            _ => return None,
        })
    }

    pub(crate) fn wire(self) -> u8 {
        match self {
            FailReason::Cancelled => 0,
            FailReason::WorkerLost => 1,
            FailReason::Overloaded => 2,
        }
    }

    pub(crate) fn from_wire(code: u8) -> Option<FailReason> {
        Some(match code {
            0 => FailReason::Cancelled,
            1 => FailReason::WorkerLost,
            2 => FailReason::Overloaded,
            _ => return None,
        })
    }
}

/// Which sort kernel a [`Phase`] belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    Radix,
    Merge,
    Sample,
    /// The out-of-core external sorter (run formation / spill / k-way merge).
    Ext,
}

impl Kernel {
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Radix => "radix",
            Kernel::Merge => "merge",
            Kernel::Sample => "sample",
            Kernel::Ext => "ext",
        }
    }
}

/// One internal phase of one kernel. Discriminants are globally unique (a
/// phase belongs to exactly one kernel) so a [`PhaseTimer`] can accumulate
/// into a fixed array with no allocation and no hashing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Phase {
    // Radix: fused sign-flip + min/max reduce, then per-pass
    // count / scan / scatter, final copy-back / sign-unflip. Wire codes are
    // same-binary only (both ends of the shard protocol run one build), so
    // the insertion of `RadixScan` renumbering later phases is safe.
    RadixMinMax = 0,
    RadixCount = 1,
    RadixScan = 2,
    RadixScatter = 3,
    RadixCopyback = 4,
    // Merge: insertion-sorted base runs, then width-doubling merge levels.
    MergeRunSort = 5,
    MergeLevels = 6,
    // Samplesort: splitter sampling, classify+scatter partitioning,
    // per-bucket sort + copy-back.
    SampleSplitters = 7,
    SamplePartition = 8,
    SampleBucketSort = 9,
    // External sort: in-memory run formation, spill-to-disk writes, and the
    // k-way (possibly multi-pass) loser-tree merge.
    ExtRunForm = 10,
    ExtSpill = 11,
    ExtMerge = 12,
}

impl Phase {
    /// Number of phases — the [`PhaseTimer`] accumulator width.
    pub const COUNT: usize = 13;

    /// Every phase, in discriminant order.
    pub fn all() -> &'static [Phase] {
        &[
            Phase::RadixMinMax,
            Phase::RadixCount,
            Phase::RadixScan,
            Phase::RadixScatter,
            Phase::RadixCopyback,
            Phase::MergeRunSort,
            Phase::MergeLevels,
            Phase::SampleSplitters,
            Phase::SamplePartition,
            Phase::SampleBucketSort,
            Phase::ExtRunForm,
            Phase::ExtSpill,
            Phase::ExtMerge,
        ]
    }

    pub fn kernel(self) -> Kernel {
        match self {
            Phase::RadixMinMax
            | Phase::RadixCount
            | Phase::RadixScan
            | Phase::RadixScatter
            | Phase::RadixCopyback => Kernel::Radix,
            Phase::MergeRunSort | Phase::MergeLevels => Kernel::Merge,
            Phase::SampleSplitters | Phase::SamplePartition | Phase::SampleBucketSort => {
                Kernel::Sample
            }
            Phase::ExtRunForm | Phase::ExtSpill | Phase::ExtMerge => Kernel::Ext,
        }
    }

    /// The phase's short name (unique within its kernel).
    pub fn name(self) -> &'static str {
        match self {
            Phase::RadixMinMax => "minmax",
            Phase::RadixCount => "count",
            Phase::RadixScan => "scan",
            Phase::RadixScatter => "scatter",
            Phase::RadixCopyback => "copyback",
            Phase::MergeRunSort => "run_sort",
            Phase::MergeLevels => "merge_levels",
            Phase::SampleSplitters => "sample",
            Phase::SamplePartition => "partition",
            Phase::SampleBucketSort => "bucket_sort",
            Phase::ExtRunForm => "run_form",
            Phase::ExtSpill => "spill",
            Phase::ExtMerge => "merge",
        }
    }

    /// The `Metrics` sample-window name: `kernel.<kernel>.<phase>`,
    /// resolved through the central [`names`] registry (one definition for
    /// the span names, the bench phase tables, and the README).
    pub fn metric_name(self) -> &'static str {
        match self {
            Phase::RadixMinMax => names::KERNEL_RADIX_MINMAX,
            Phase::RadixCount => names::KERNEL_RADIX_COUNT,
            Phase::RadixScan => names::KERNEL_RADIX_SCAN,
            Phase::RadixScatter => names::KERNEL_RADIX_SCATTER,
            Phase::RadixCopyback => names::KERNEL_RADIX_COPYBACK,
            Phase::MergeRunSort => names::KERNEL_MERGE_RUN_SORT,
            Phase::MergeLevels => names::KERNEL_MERGE_MERGE_LEVELS,
            Phase::SampleSplitters => names::KERNEL_SAMPLE_SAMPLE,
            Phase::SamplePartition => names::KERNEL_SAMPLE_PARTITION,
            Phase::SampleBucketSort => names::KERNEL_SAMPLE_BUCKET_SORT,
            Phase::ExtRunForm => names::KERNEL_EXT_RUN_FORM,
            Phase::ExtSpill => names::KERNEL_EXT_SPILL,
            Phase::ExtMerge => names::KERNEL_EXT_MERGE,
        }
    }

    /// Inverse of `kernel().name()` + [`name`](Phase::name).
    pub fn from_names(kernel: &str, phase: &str) -> Option<Phase> {
        Phase::all()
            .iter()
            .copied()
            .find(|p| p.kernel().name() == kernel && p.name() == phase)
    }

    pub(crate) fn wire(self) -> u8 {
        self as u8
    }

    pub(crate) fn from_wire(code: u8) -> Option<Phase> {
        Phase::all().get(code as usize).copied()
    }
}

/// What happened. Hot-path kinds are `Copy`-only data; the tuner kinds own
/// their strings (produced off the sort path).
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// The job entered the service (or router).
    Submitted,
    /// The job was admitted to a pending queue.
    Queued,
    /// The job was handed to shard `shard` (the executing side emits
    /// `shard ==` its own id; the router emits the target's).
    Dispatched { shard: u32 },
    /// One kernel phase of the job's sort took `dur_secs`.
    KernelPhase { phase: Phase, dur_secs: f64 },
    /// Terminal: the sort finished in `secs` (excludes queueing).
    Completed { secs: f64 },
    /// Terminal: the job resolved to an error.
    Failed { reason: FailReason },
    /// The autotuner published improved parameters for a fingerprint class.
    TunerPublished {
        fingerprint: Box<str>,
        params: Box<str>,
        fitness: f64,
        improvement_pct: f64,
    },
    /// The autotuner finished a cycle without publishing.
    TunerRejected { fingerprint: Box<str>, reason: Box<str> },
}

impl EventKind {
    /// Short kind name (the JSONL `kind` field).
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Submitted => "submitted",
            EventKind::Queued => "queued",
            EventKind::Dispatched { .. } => "dispatched",
            EventKind::KernelPhase { .. } => "kernel_phase",
            EventKind::Completed { .. } => "completed",
            EventKind::Failed { .. } => "failed",
            EventKind::TunerPublished { .. } => "tuner_published",
            EventKind::TunerRejected { .. } => "tuner_rejected",
        }
    }

    /// Is this a terminal event for its job?
    pub fn is_terminal(&self) -> bool {
        matches!(self, EventKind::Completed { .. } | EventKind::Failed { .. })
    }
}

/// One observed event, stamped with its job's trace id, the observing
/// shard, and wall-clock microseconds since the Unix epoch.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub trace_id: u64,
    pub shard: u32,
    pub ts_micros: u64,
    pub kind: EventKind,
}

/// Wall-clock microseconds since the Unix epoch (0 if the clock is broken).
pub fn now_micros() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}

/// Per-sort kernel phase timer: a fixed accumulator array indexed by
/// [`Phase`] discriminant. Lives on the worker's
/// [`SortScratch`](crate::sort::key::SortScratch) so steady-state sorts
/// never allocate for timing; disabled it is two branches per phase
/// (`begin` returns `None`, `end` matches nothing). Kernels call
/// `begin`/`end` around their `exec.run_*` fan-outs on the coordinating
/// thread — the phases themselves are parallel inside.
#[derive(Debug, Clone)]
pub struct PhaseTimer {
    enabled: bool,
    accum: [f64; Phase::COUNT],
}

impl Default for PhaseTimer {
    fn default() -> Self {
        PhaseTimer::disabled()
    }
}

impl PhaseTimer {
    pub const fn disabled() -> PhaseTimer {
        PhaseTimer { enabled: false, accum: [0.0; Phase::COUNT] }
    }

    pub const fn enabled() -> PhaseTimer {
        PhaseTimer { enabled: true, accum: [0.0; Phase::COUNT] }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Enable or disable; either way the accumulators reset.
    pub fn set_enabled(&mut self, on: bool) {
        self.enabled = on;
        self.reset();
    }

    /// Start timing a phase (`None` when disabled — the matching
    /// [`end`](PhaseTimer::end) is then a no-op).
    #[inline]
    pub fn begin(&self) -> Option<Instant> {
        if self.enabled {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Accumulate the elapsed time since `begin` into `phase`.
    #[inline]
    pub fn end(&mut self, phase: Phase, started: Option<Instant>) {
        if let Some(t) = started {
            self.accum[phase as usize] += t.elapsed().as_secs_f64();
        }
    }

    /// Directly accumulate a duration (for callers that timed externally).
    #[inline]
    pub fn add(&mut self, phase: Phase, secs: f64) {
        if self.enabled {
            self.accum[phase as usize] += secs;
        }
    }

    pub fn reset(&mut self) {
        self.accum = [0.0; Phase::COUNT];
    }

    /// The non-zero `(phase, seconds)` accumulators, then reset. Call after
    /// each sort to turn one job's phase times into events/samples.
    pub fn drain(&mut self) -> Vec<(Phase, f64)> {
        let mut out = Vec::new();
        for &p in Phase::all() {
            let v = self.accum[p as usize];
            if v > 0.0 {
                out.push((p, v));
            }
        }
        self.reset();
        out
    }

    /// Non-zero accumulators without resetting (bench aggregation).
    pub fn snapshot(&self) -> Vec<(Phase, f64)> {
        Phase::all()
            .iter()
            .map(|&p| (p, self.accum[p as usize]))
            .filter(|(_, v)| *v > 0.0)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_wire_roundtrip_and_uniqueness() {
        let mut seen = std::collections::HashSet::new();
        for &p in Phase::all() {
            assert_eq!(Phase::from_wire(p.wire()), Some(p));
            assert!(seen.insert(p.metric_name()), "metric name collision");
            assert_eq!(Phase::from_names(p.kernel().name(), p.name()), Some(p));
        }
        assert_eq!(Phase::all().len(), Phase::COUNT);
        assert_eq!(Phase::from_wire(99), None);
    }

    #[test]
    fn fail_reason_roundtrips() {
        for r in [FailReason::Cancelled, FailReason::WorkerLost, FailReason::Overloaded] {
            assert_eq!(FailReason::from_wire(r.wire()), Some(r));
            assert_eq!(FailReason::from_name(r.name()), Some(r));
        }
        assert_eq!(FailReason::from_wire(9), None);
    }

    #[test]
    fn disabled_timer_accumulates_nothing() {
        let mut t = PhaseTimer::disabled();
        let h = t.begin();
        assert!(h.is_none());
        t.end(Phase::RadixScatter, h);
        t.add(Phase::RadixScatter, 1.0);
        assert!(t.drain().is_empty());
    }

    #[test]
    fn enabled_timer_accumulates_and_drains() {
        let mut t = PhaseTimer::enabled();
        let h = t.begin();
        assert!(h.is_some());
        std::thread::sleep(std::time::Duration::from_millis(2));
        t.end(Phase::MergeRunSort, h);
        t.add(Phase::MergeLevels, 0.5);
        let drained = t.drain();
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0].0, Phase::MergeRunSort);
        assert!(drained[0].1 > 0.0);
        assert_eq!(drained[1], (Phase::MergeLevels, 0.5));
        assert!(t.drain().is_empty(), "drain resets");
    }
}
