//! A minimal Prometheus scrape endpoint: one background thread, one
//! `TcpListener`, HTTP/1.1 `200 text/plain` responses carrying
//! [`Metrics::render_prometheus`] — no HTTP library in the offline build,
//! and none needed: scrapers send one GET and read one body.
//!
//! The server answers every path identically (scrape configs vary between
//! `/metrics` and `/`), closes each connection after one response
//! (`Connection: close`), and bounds how long a slow client can hold the
//! handler with a read timeout. Dropping the handle stops the thread: the
//! stop flag flips and a self-connect unblocks `accept`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::metrics::{names, Metrics};

/// Handle to the scrape server; dropping it shuts the listener down.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9464`, port `0` for OS-assigned) and
    /// serve `metrics` until dropped.
    pub fn spawn(addr: &str, metrics: Arc<Metrics>) -> Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding metrics endpoint {addr}"))?;
        let addr = listener.local_addr().context("resolving metrics endpoint")?;
        let stop = Arc::new(AtomicBool::new(false));
        let handle = {
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("evosort-metrics-http".into())
                .spawn(move || {
                    for conn in listener.incoming() {
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        let Ok(stream) = conn else { continue };
                        // One request per connection; a stuck client times
                        // out instead of pinning the accept loop.
                        let _ = serve_one(stream, &metrics);
                    }
                })
                .expect("spawn metrics http server")
        };
        Ok(MetricsServer { addr, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port `0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

fn serve_one(mut stream: TcpStream, metrics: &Metrics) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    // Read until the end of the request head (or the 8 KiB bound — scrape
    // requests are tiny; anything bigger is not a scraper).
    let mut buf = [0u8; 1024];
    let mut head: Vec<u8> = Vec::new();
    loop {
        let n = stream.read(&mut buf)?;
        if n == 0 {
            break;
        }
        head.extend_from_slice(&buf[..n]);
        if head.windows(4).any(|w| w == b"\r\n\r\n") || head.len() > 8192 {
            break;
        }
    }
    let body = metrics.render_prometheus();
    let response = format!(
        "HTTP/1.1 200 OK\r\n\
         Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\r\n{}",
        body.len(),
        body
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Unblock the accept loop; the handler sees the flag and exits.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(250));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scrape(addr: SocketAddr) -> String {
        let mut stream = TcpStream::connect(addr).expect("connect");
        stream
            .write_all(b"GET /metrics HTTP/1.1\r\nHost: test\r\n\r\n")
            .expect("request");
        let mut out = String::new();
        stream.read_to_string(&mut out).expect("response");
        out
    }

    #[test]
    fn serves_prometheus_text_and_shuts_down() {
        let metrics = Arc::new(Metrics::new());
        metrics.incr(names::JOBS_COMPLETED);
        metrics.set_gauge(names::ROUTER_QUEUE_DEPTH, 3.0);
        let server = MetricsServer::spawn("127.0.0.1:0", Arc::clone(&metrics)).expect("spawn");
        let addr = server.addr();
        let response = scrape(addr);
        assert!(response.starts_with("HTTP/1.1 200 OK"), "{response}");
        assert!(response.contains("evosort_jobs_completed 1"), "{response}");
        assert!(response.contains("evosort_router_queue_depth 3"), "{response}");
        // Counters move between scrapes.
        metrics.incr(names::JOBS_COMPLETED);
        assert!(scrape(addr).contains("evosort_jobs_completed 2"));
        drop(server);
        assert!(
            TcpStream::connect_timeout(&addr, Duration::from_millis(200)).is_err(),
            "listener must be gone after drop"
        );
    }
}
