//! Schema-versioned JSONL encoding of trace events (hand-rolled — no serde
//! in the offline build, same policy as `bench_harness/json.rs`).
//!
//! A trace log is line-oriented: the first line is a header object carrying
//! the schema tag, every following line is one [`TraceEvent`]:
//!
//! ```text
//! {"schema":"evosort-trace-v1"}
//! {"trace":17,"shard":4294967295,"ts_us":1760000000123456,"kind":"submitted"}
//! {"trace":17,"shard":1,"ts_us":1760000000123999,"kind":"kernel_phase","kernel":"radix","phase":"scatter","dur_secs":0.0042}
//! {"trace":17,"shard":1,"ts_us":1760000000124510,"kind":"completed","secs":0.0061}
//! ```
//!
//! [`TraceLog`] appends events to a file (buffered, flushed on drop);
//! [`read_events`] parses a whole log back for the `evosort trace` CLI.

use std::fmt::Write as _;
use std::io::{BufRead, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::event::{EventKind, FailReason, Phase, TraceEvent};

/// The trace-log schema tag (bump on breaking format changes).
pub const SCHEMA: &str = "evosort-trace-v1";

// --- writing ---------------------------------------------------------------

fn quote(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn num(out: &mut String, v: f64) {
    // JSON has no NaN/Infinity; clamp the degenerate cases to 0.
    if v.is_finite() {
        let _ = write!(out, "{v}");
        // `{}` prints integral floats without a point; keep them numbers
        // that round-trip as f64 regardless.
    } else {
        out.push('0');
    }
}

/// One event as a single JSON line (no trailing newline).
pub fn event_to_json(ev: &TraceEvent) -> String {
    let mut s = String::with_capacity(96);
    let _ = write!(
        s,
        "{{\"trace\":{},\"shard\":{},\"ts_us\":{},\"kind\":\"{}\"",
        ev.trace_id,
        ev.shard,
        ev.ts_micros,
        ev.kind.name()
    );
    match &ev.kind {
        EventKind::Submitted | EventKind::Queued => {}
        EventKind::Dispatched { shard } => {
            let _ = write!(s, ",\"to_shard\":{shard}");
        }
        EventKind::KernelPhase { phase, dur_secs } => {
            let _ = write!(s, ",\"kernel\":\"{}\",\"phase\":\"{}\"", phase.kernel().name(), phase.name());
            s.push_str(",\"dur_secs\":");
            num(&mut s, *dur_secs);
        }
        EventKind::Completed { secs } => {
            s.push_str(",\"secs\":");
            num(&mut s, *secs);
        }
        EventKind::Failed { reason } => {
            let _ = write!(s, ",\"reason\":\"{}\"", reason.name());
        }
        EventKind::TunerPublished { fingerprint, params, fitness, improvement_pct } => {
            s.push_str(",\"fingerprint\":");
            quote(&mut s, fingerprint);
            s.push_str(",\"params\":");
            quote(&mut s, params);
            s.push_str(",\"fitness\":");
            num(&mut s, *fitness);
            s.push_str(",\"improvement_pct\":");
            num(&mut s, *improvement_pct);
        }
        EventKind::TunerRejected { fingerprint, reason } => {
            s.push_str(",\"fingerprint\":");
            quote(&mut s, fingerprint);
            s.push_str(",\"reason\":");
            quote(&mut s, reason);
        }
    }
    s.push('}');
    s
}

/// Append-only trace-log writer: opens (creating or truncating) `path`,
/// writes the schema header, buffers event lines, flushes on
/// [`flush`](TraceLog::flush) and on drop.
pub struct TraceLog {
    w: std::io::BufWriter<std::fs::File>,
}

impl TraceLog {
    pub fn create(path: &Path) -> Result<TraceLog> {
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating trace log {}", path.display()))?;
        let mut w = std::io::BufWriter::new(file);
        writeln!(w, "{{\"schema\":\"{SCHEMA}\"}}").context("writing trace-log header")?;
        Ok(TraceLog { w })
    }

    pub fn append(&mut self, ev: &TraceEvent) -> Result<()> {
        writeln!(self.w, "{}", event_to_json(ev)).context("appending trace event")
    }

    pub fn append_all(&mut self, events: &[TraceEvent]) -> Result<()> {
        for ev in events {
            self.append(ev)?;
        }
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.w.flush().context("flushing trace log")
    }
}

impl Drop for TraceLog {
    fn drop(&mut self) {
        let _ = self.w.flush();
    }
}

// --- reading ---------------------------------------------------------------

/// A parsed JSON value (recursive descent over one line; private — the
/// public surface is [`parse_event_line`] / [`read_events`]).
enum Json {
    Object(Vec<(String, Json)>),
    Array(Vec<Json>),
    String(String),
    Number(f64),
    Bool(bool),
    Null,
}

impl Json {
    fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    fn str(&self) -> Option<&str> {
        match self {
            Json::String(s) => Some(s.as_str()),
            _ => None,
        }
    }

    fn f64(&self) -> Option<f64> {
        match self {
            Json::Number(n) => Some(*n),
            _ => None,
        }
    }

    fn u64(&self) -> Option<u64> {
        self.f64().filter(|v| *v >= 0.0 && v.fract() == 0.0).map(|v| v as u64)
    }

    fn parse(input: &str) -> Result<Json> {
        let mut p = Parser { s: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.s.len() {
            bail!("trailing bytes after JSON value at offset {}", p.pos);
        }
        Ok(v)
    }
}

struct Parser<'a> {
    s: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.pos < self.s.len() && self.s[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at offset {}", c as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::String(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at offset {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.s[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at offset {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Object(fields));
                }
                _ => bail!("expected ',' or '}}' at offset {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Array(items));
                }
                _ => bail!("expected ',' or ']' at offset {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else { bail!("unterminated string") };
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(esc) = self.peek() else { bail!("unterminated escape") };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .s
                                .get(self.pos..self.pos + 4)
                                .context("truncated \\u escape")?;
                            self.pos += 4;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).context("non-utf8 \\u escape")?,
                                16,
                            )
                            .context("bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => bail!("unknown escape \\{}", other as char),
                    }
                }
                c => {
                    // Re-decode multi-byte UTF-8 from the raw bytes.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xc0..=0xdf => 2,
                            0xe0..=0xef => 3,
                            _ => 4,
                        };
                        let chunk =
                            self.s.get(start..start + width).context("truncated utf-8")?;
                        let s = std::str::from_utf8(chunk).context("bad utf-8 in string")?;
                        out.push_str(s);
                        self.pos = start + width;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.s[start..self.pos]).unwrap();
        let v: f64 = text.parse().with_context(|| format!("bad number {text:?}"))?;
        Ok(Json::Number(v))
    }
}

/// Parse one event line back into a [`TraceEvent`].
pub fn parse_event_line(line: &str) -> Result<TraceEvent> {
    let v = Json::parse(line)?;
    let trace_id = v.get("trace").and_then(Json::u64).context("missing trace id")?;
    let shard = v.get("shard").and_then(Json::u64).context("missing shard")? as u32;
    let ts_micros = v.get("ts_us").and_then(Json::u64).context("missing ts_us")?;
    let kind_name = v.get("kind").and_then(Json::str).context("missing kind")?;
    let kind = match kind_name {
        "submitted" => EventKind::Submitted,
        "queued" => EventKind::Queued,
        "dispatched" => EventKind::Dispatched {
            shard: v.get("to_shard").and_then(Json::u64).context("missing to_shard")? as u32,
        },
        "kernel_phase" => {
            let kernel = v.get("kernel").and_then(Json::str).context("missing kernel")?;
            let phase = v.get("phase").and_then(Json::str).context("missing phase")?;
            EventKind::KernelPhase {
                phase: Phase::from_names(kernel, phase)
                    .with_context(|| format!("unknown phase {kernel}.{phase}"))?,
                dur_secs: v.get("dur_secs").and_then(Json::f64).context("missing dur_secs")?,
            }
        }
        "completed" => EventKind::Completed {
            secs: v.get("secs").and_then(Json::f64).context("missing secs")?,
        },
        "failed" => EventKind::Failed {
            reason: v
                .get("reason")
                .and_then(Json::str)
                .and_then(FailReason::from_name)
                .context("missing/unknown failure reason")?,
        },
        "tuner_published" => EventKind::TunerPublished {
            fingerprint: v
                .get("fingerprint")
                .and_then(Json::str)
                .context("missing fingerprint")?
                .into(),
            params: v.get("params").and_then(Json::str).context("missing params")?.into(),
            fitness: v.get("fitness").and_then(Json::f64).context("missing fitness")?,
            improvement_pct: v
                .get("improvement_pct")
                .and_then(Json::f64)
                .context("missing improvement_pct")?,
        },
        "tuner_rejected" => EventKind::TunerRejected {
            fingerprint: v
                .get("fingerprint")
                .and_then(Json::str)
                .context("missing fingerprint")?
                .into(),
            reason: v.get("reason").and_then(Json::str).context("missing reason")?.into(),
        },
        other => bail!("unknown event kind {other:?}"),
    };
    Ok(TraceEvent { trace_id, shard, ts_micros, kind })
}

/// Read a whole trace log: validates the schema header, parses every event
/// line (empty lines are skipped; a malformed line is an error with its
/// line number).
pub fn read_events(path: &Path) -> Result<Vec<TraceEvent>> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening trace log {}", path.display()))?;
    let reader = std::io::BufReader::new(file);
    let mut events = Vec::new();
    let mut saw_header = false;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.context("reading trace log")?;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        if !saw_header {
            let header = Json::parse(trimmed)
                .with_context(|| format!("line {}: bad header", lineno + 1))?;
            let schema = header.get("schema").and_then(Json::str).unwrap_or("");
            if schema != SCHEMA {
                bail!("unsupported trace schema {schema:?} (want {SCHEMA:?})");
            }
            saw_header = true;
            continue;
        }
        let ev = parse_event_line(trimmed)
            .with_context(|| format!("line {}: bad trace event", lineno + 1))?;
        events.push(ev);
    }
    if !saw_header {
        bail!("empty trace log: no schema header");
    }
    Ok(events)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::event::now_micros;

    fn sample_events() -> Vec<TraceEvent> {
        let ts = now_micros();
        vec![
            TraceEvent { trace_id: 1, shard: u32::MAX, ts_micros: ts, kind: EventKind::Submitted },
            TraceEvent { trace_id: 1, shard: u32::MAX, ts_micros: ts + 1, kind: EventKind::Queued },
            TraceEvent {
                trace_id: 1,
                shard: u32::MAX,
                ts_micros: ts + 2,
                kind: EventKind::Dispatched { shard: 1 },
            },
            TraceEvent {
                trace_id: 1,
                shard: 1,
                ts_micros: ts + 3,
                kind: EventKind::KernelPhase { phase: Phase::RadixScatter, dur_secs: 0.0042 },
            },
            TraceEvent {
                trace_id: 1,
                shard: 1,
                ts_micros: ts + 4,
                kind: EventKind::Completed { secs: 0.0061 },
            },
            TraceEvent {
                trace_id: 2,
                shard: 0,
                ts_micros: ts + 5,
                kind: EventKind::Failed { reason: FailReason::Overloaded },
            },
            TraceEvent {
                trace_id: 0,
                shard: 1,
                ts_micros: ts + 6,
                kind: EventKind::TunerPublished {
                    fingerprint: "b9:mix \"q\":w4".into(),
                    params: "tile=4096".into(),
                    fitness: 0.123,
                    improvement_pct: 4.5,
                },
            },
            TraceEvent {
                trace_id: 0,
                shard: 1,
                ts_micros: ts + 7,
                kind: EventKind::TunerRejected {
                    fingerprint: "b9".into(),
                    reason: "below noise margin".into(),
                },
            },
        ]
    }

    #[test]
    fn events_roundtrip_through_jsonl() {
        for ev in sample_events() {
            let line = event_to_json(&ev);
            let back = parse_event_line(&line).expect("parse back");
            assert_eq!(back, ev, "line: {line}");
        }
    }

    #[test]
    fn log_file_roundtrip_with_header() {
        let dir = std::env::temp_dir()
            .join(format!("evosort-trace-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        let events = sample_events();
        {
            let mut log = TraceLog::create(&path).expect("create");
            log.append_all(&events).expect("append");
        } // drop flushes
        let back = read_events(&path).expect("read");
        assert_eq!(back, events);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wrong_schema_and_garbage_are_rejected() {
        let dir = std::env::temp_dir()
            .join(format!("evosort-trace-test2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("bad.jsonl");
        std::fs::write(&bad, "{\"schema\":\"evosort-trace-v999\"}\n").unwrap();
        assert!(read_events(&bad).is_err());
        std::fs::write(&bad, "").unwrap();
        assert!(read_events(&bad).is_err(), "empty log has no header");
        std::fs::write(&bad, format!("{{\"schema\":\"{SCHEMA}\"}}\nnot json\n")).unwrap();
        assert!(read_events(&bad).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn nonfinite_durations_encode_as_zero() {
        let ev = TraceEvent {
            trace_id: 1,
            shard: 0,
            ts_micros: 0,
            kind: EventKind::Completed { secs: f64::NAN },
        };
        let line = event_to_json(&ev);
        let back = parse_event_line(&line).expect("NaN must not poison the line");
        assert_eq!(back.kind, EventKind::Completed { secs: 0.0 });
    }
}
