//! Observability: per-job trace spans, per-phase kernel timings, and the
//! export surfaces (JSONL trace log, Prometheus text, span-tree CLI).
//!
//! The layering, hot side to cold side:
//!
//! * [`Tracer`] — the handle the service, router, workers, kernels and
//!   tuner emit through. Internally an `Option<Arc<TraceRing>>`: a
//!   **disabled tracer is a branch** (`emit` early-returns on `None` before
//!   touching a clock), and an enabled one does one non-blocking push into
//!   a preallocated lock-free ring ([`ring::TraceRing`]) — a full ring
//!   drops the event and bumps a counter, it never stalls a sort.
//! * [`event`] — the typed [`TraceEvent`]/[`EventKind`] vocabulary and the
//!   [`PhaseTimer`] kernels accumulate per-phase durations into.
//! * [`collect::TraceHub`] — the drain side: a background thread empties
//!   the ring into the schema-versioned JSONL log ([`jsonl`]), folds events
//!   into a bounded in-memory timeline keyed by `(shard, trace id)`, and
//!   publishes ring drops as the `trace.dropped` counter. The shard router
//!   [`ingest`](collect::TraceHub::ingest)s event batches streamed from
//!   worker processes into the same hub, so one timeline covers the fleet.
//! * [`http::MetricsServer`] — a minimal scrape endpoint serving
//!   [`Metrics::render_prometheus`](crate::coordinator::Metrics::render_prometheus).
//! * [`report`] — the `evosort trace` summary (per-phase p50/p99, slowest
//!   traces, span-chain completeness check) over a JSONL file.

pub mod collect;
pub mod event;
pub mod http;
pub mod jsonl;
pub mod report;
pub mod ring;

use std::sync::Arc;

pub use collect::TraceHub;
pub use event::{
    now_micros, EventKind, FailReason, Kernel, Phase, PhaseTimer, TraceEvent, ROUTER_SHARD,
};
pub use http::MetricsServer;
pub use ring::TraceRing;

/// Default ring capacity (events) when tracing is enabled.
pub const DEFAULT_RING_CAPACITY: usize = 1 << 16;

/// The emission handle. Cheap to clone (an `Option<Arc>` plus a shard id);
/// every clone feeds the same ring. `Tracer::default()` is disabled.
#[derive(Clone, Default)]
pub struct Tracer {
    inner: Option<Arc<TraceRing>>,
    shard: u32,
}

impl Tracer {
    /// A tracer that does nothing: `emit` is one branch, no clock read, no
    /// atomics.
    pub fn disabled() -> Tracer {
        Tracer::default()
    }

    /// An enabled tracer over a fresh ring of (at least) `capacity` slots,
    /// stamping `shard` on every event.
    pub fn enabled(capacity: usize, shard: u32) -> Tracer {
        Tracer { inner: Some(Arc::new(TraceRing::with_capacity(capacity))), shard }
    }

    /// This tracer, re-stamped with a different shard id (shares the ring).
    pub fn with_shard(&self, shard: u32) -> Tracer {
        Tracer { inner: self.inner.clone(), shard }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    pub fn shard(&self) -> u32 {
        self.shard
    }

    /// Emit one event. Disabled: returns before reading the clock.
    /// Enabled: one `SystemTime` read plus one lock-free ring push; a full
    /// ring drops the event (counted) without blocking.
    #[inline]
    pub fn emit(&self, trace_id: u64, kind: EventKind) {
        let Some(ring) = &self.inner else { return };
        ring.push(TraceEvent {
            trace_id,
            shard: self.shard,
            ts_micros: event::now_micros(),
            kind,
        });
    }

    /// Move everything currently buffered into `out` (drain side).
    pub fn drain_into(&self, out: &mut Vec<TraceEvent>) -> usize {
        match &self.inner {
            Some(ring) => ring.drain_into(out),
            None => 0,
        }
    }

    /// Events dropped to a full ring since the last call (delta).
    pub fn take_dropped(&self) -> u64 {
        self.inner.as_ref().map_or(0, |r| r.take_dropped())
    }

    /// Total events dropped since construction (plus any not yet taken).
    pub fn dropped(&self) -> u64 {
        self.inner.as_ref().map_or(0, |r| r.dropped())
    }
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tracer")
            .field("enabled", &self.is_enabled())
            .field("shard", &self.shard)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_is_inert() {
        let t = Tracer::disabled();
        assert!(!t.is_enabled());
        t.emit(1, EventKind::Submitted);
        let mut out = Vec::new();
        assert_eq!(t.drain_into(&mut out), 0);
        assert_eq!(t.take_dropped(), 0);
    }

    #[test]
    fn enabled_tracer_stamps_shard_and_time() {
        let t = Tracer::enabled(64, 3);
        let before = now_micros();
        t.emit(42, EventKind::Completed { secs: 0.5 });
        let mut out = Vec::new();
        t.drain_into(&mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].trace_id, 42);
        assert_eq!(out[0].shard, 3);
        assert!(out[0].ts_micros >= before);
        assert_eq!(out[0].kind, EventKind::Completed { secs: 0.5 });
    }

    #[test]
    fn with_shard_shares_the_ring() {
        let t = Tracer::enabled(64, 0);
        let t2 = t.with_shard(7);
        t2.emit(1, EventKind::Queued);
        let mut out = Vec::new();
        t.drain_into(&mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shard, 7);
    }
}
