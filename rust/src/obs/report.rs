//! Span-tree summarisation for the `evosort trace` CLI: per-phase p50/p99,
//! slowest traces, tuner decisions, and the span-chain completeness check
//! the CI smoke leg gates on.

use std::collections::BTreeMap;

use super::event::{EventKind, Phase, TraceEvent, ROUTER_SHARD};
use crate::coordinator::metrics::percentile_of_sorted;

/// Aggregated per-phase timing across every job in a trace log.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseStat {
    pub phase: Phase,
    pub count: usize,
    pub p50_secs: f64,
    pub p99_secs: f64,
    pub total_secs: f64,
}

/// One job trace, reduced to the span facts the summary needs.
#[derive(Debug, Clone, Default)]
struct TraceFacts {
    submitted: bool,
    dispatched: bool,
    phases: usize,
    completed_secs: Option<f64>,
    failed: Option<&'static str>,
    shards: Vec<u32>,
}

/// The whole-log summary.
#[derive(Debug, Clone)]
pub struct TraceSummary {
    /// Distinct job trace ids (tuner-only traces are excluded).
    pub traces: usize,
    pub completed: usize,
    pub failed: usize,
    /// Failure reasons, name → count.
    pub failures_by_reason: BTreeMap<&'static str, usize>,
    pub events: usize,
    pub phase_stats: Vec<PhaseStat>,
    /// `(trace id, sort seconds)`, slowest first, capped at 10.
    pub slowest: Vec<(u64, f64)>,
    pub tuner_published: usize,
    pub tuner_rejected: usize,
    /// Completed traces that also carry ≥ 1 kernel-phase span.
    pub completed_with_phases: usize,
    /// Span-chain problems ([`check`]'s findings; empty means every chain
    /// is complete).
    pub problems: Vec<String>,
}

fn shard_name(shard: u32) -> String {
    if shard == ROUTER_SHARD {
        "router".to_string()
    } else {
        shard.to_string()
    }
}

/// Reduce a log to per-trace facts (job traces only — tuner events, which
/// are not tied to a job, are counted separately).
fn fold(events: &[TraceEvent]) -> (BTreeMap<u64, TraceFacts>, usize, usize) {
    let mut traces: BTreeMap<u64, TraceFacts> = BTreeMap::new();
    let (mut published, mut rejected) = (0usize, 0usize);
    for ev in events {
        match &ev.kind {
            EventKind::TunerPublished { .. } => published += 1,
            EventKind::TunerRejected { .. } => rejected += 1,
            kind => {
                let t = traces.entry(ev.trace_id).or_default();
                if !t.shards.contains(&ev.shard) {
                    t.shards.push(ev.shard);
                }
                match kind {
                    EventKind::Submitted => t.submitted = true,
                    EventKind::Queued => {}
                    EventKind::Dispatched { .. } => t.dispatched = true,
                    EventKind::KernelPhase { .. } => t.phases += 1,
                    EventKind::Completed { secs } => {
                        // Both the worker and the router may report a
                        // completion; keep the longer (worker-side) time.
                        let prev = t.completed_secs.unwrap_or(0.0);
                        t.completed_secs = Some(prev.max(*secs));
                    }
                    EventKind::Failed { reason } => t.failed = Some(reason.name()),
                    EventKind::TunerPublished { .. } | EventKind::TunerRejected { .. } => {}
                }
            }
        }
    }
    (traces, published, rejected)
}

/// The span-chain completeness rules:
///
/// 1. Per `(shard, trace)` stream: a `Submitted` must be matched by
///    **exactly one** terminal event (`Completed` or `Failed`) from that
///    same shard — no lost jobs, no double terminals.
/// 2. Per trace overall: at least one terminal event.
/// 3. A trace that completed must carry a `Dispatched` span.
pub fn check(events: &[TraceEvent]) -> Vec<String> {
    let mut problems = Vec::new();
    // Rule 1 over (shard, trace) streams.
    let mut streams: BTreeMap<(u32, u64), (usize, usize)> = BTreeMap::new();
    for ev in events {
        let entry = streams.entry((ev.shard, ev.trace_id)).or_default();
        match &ev.kind {
            EventKind::Submitted => entry.0 += 1,
            k if k.is_terminal() => entry.1 += 1,
            _ => {}
        }
    }
    for ((shard, trace), (submitted, terminals)) in &streams {
        if *submitted > 0 && *terminals != 1 {
            problems.push(format!(
                "trace {trace} on shard {}: {terminals} terminal events for {submitted} \
                 submission(s) (want exactly 1)",
                shard_name(*shard)
            ));
        }
    }
    // Rules 2 and 3 over whole traces.
    let (traces, _, _) = fold(events);
    for (id, t) in &traces {
        if t.completed_secs.is_none() && t.failed.is_none() {
            problems.push(format!("trace {id}: no terminal event"));
        }
        if t.completed_secs.is_some() && !t.dispatched {
            problems.push(format!("trace {id}: completed without a Dispatched span"));
        }
        if t.completed_secs.is_some() && !t.submitted {
            problems.push(format!("trace {id}: completed without a Submitted span"));
        }
    }
    problems
}

/// Build the summary (includes [`check`]'s findings).
pub fn summarize(events: &[TraceEvent]) -> TraceSummary {
    let (traces, tuner_published, tuner_rejected) = fold(events);
    let mut per_phase: BTreeMap<u8, Vec<f64>> = BTreeMap::new();
    for ev in events {
        if let EventKind::KernelPhase { phase, dur_secs } = &ev.kind {
            per_phase.entry(phase.wire()).or_default().push(*dur_secs);
        }
    }
    let mut phase_stats = Vec::new();
    for (code, mut durs) in per_phase {
        let phase = Phase::from_wire(code).expect("folded from a valid phase");
        durs.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        phase_stats.push(PhaseStat {
            phase,
            count: durs.len(),
            p50_secs: percentile_of_sorted(&durs, 50.0),
            p99_secs: percentile_of_sorted(&durs, 99.0),
            total_secs: durs.iter().sum(),
        });
    }
    let mut slowest: Vec<(u64, f64)> = traces
        .iter()
        .filter_map(|(id, t)| t.completed_secs.map(|s| (*id, s)))
        .collect();
    slowest.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
    slowest.truncate(10);
    let mut failures_by_reason: BTreeMap<&'static str, usize> = BTreeMap::new();
    for t in traces.values() {
        if let Some(reason) = t.failed {
            *failures_by_reason.entry(reason).or_default() += 1;
        }
    }
    TraceSummary {
        traces: traces.len(),
        completed: traces.values().filter(|t| t.completed_secs.is_some()).count(),
        failed: traces.values().filter(|t| t.failed.is_some()).count(),
        failures_by_reason,
        events: events.len(),
        phase_stats,
        slowest,
        tuner_published,
        tuner_rejected,
        completed_with_phases: traces
            .values()
            .filter(|t| t.completed_secs.is_some() && t.phases > 0)
            .count(),
        problems: check(events),
    }
}

fn fmt_ms(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 0.001 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{:.1}us", secs * 1e6)
    }
}

/// Render the summary as the `evosort trace` report text.
pub fn render(summary: &TraceSummary) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "trace summary: {} events, {} traces ({} completed, {} failed)",
        summary.events, summary.traces, summary.completed, summary.failed
    );
    if !summary.failures_by_reason.is_empty() {
        let breakdown: Vec<String> = summary
            .failures_by_reason
            .iter()
            .map(|(r, n)| format!("{n} {r}"))
            .collect();
        let _ = writeln!(out, "  failures: {}", breakdown.join(", "));
    }
    if summary.tuner_published + summary.tuner_rejected > 0 {
        let _ = writeln!(
            out,
            "  tuner: {} published, {} rejected",
            summary.tuner_published, summary.tuner_rejected
        );
    }
    if summary.phase_stats.is_empty() {
        let _ = writeln!(out, "\nper-phase kernel timings: (no kernel_phase events)");
    } else {
        let _ = writeln!(out, "\nper-phase kernel timings");
        let _ = writeln!(
            out,
            "  {:<28} {:>6} {:>10} {:>10} {:>10}",
            "phase", "n", "p50", "p99", "total"
        );
        for s in &summary.phase_stats {
            let _ = writeln!(
                out,
                "  {:<28} {:>6} {:>10} {:>10} {:>10}",
                s.phase.metric_name(),
                s.count,
                fmt_ms(s.p50_secs),
                fmt_ms(s.p99_secs),
                fmt_ms(s.total_secs)
            );
        }
    }
    if !summary.slowest.is_empty() {
        let _ = writeln!(out, "\nslowest traces");
        for (id, secs) in &summary.slowest {
            let _ = writeln!(out, "  trace {id:<12} {}", fmt_ms(*secs));
        }
    }
    let _ = writeln!(
        out,
        "\nspan chains: {}/{} completed traces carry kernel phases; {} problem(s)",
        summary.completed_with_phases, summary.completed, summary.problems.len()
    );
    for p in &summary.problems {
        let _ = writeln!(out, "  problem: {p}");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::event::FailReason;

    fn ev(trace: u64, shard: u32, ts: u64, kind: EventKind) -> TraceEvent {
        TraceEvent { trace_id: trace, shard, ts_micros: ts, kind }
    }

    fn full_chain(trace: u64, shard: u32, base: u64) -> Vec<TraceEvent> {
        vec![
            ev(trace, ROUTER_SHARD, base, EventKind::Submitted),
            ev(trace, ROUTER_SHARD, base + 1, EventKind::Queued),
            ev(trace, ROUTER_SHARD, base + 2, EventKind::Dispatched { shard }),
            ev(trace, shard, base + 3, EventKind::Submitted),
            ev(trace, shard, base + 4, EventKind::Dispatched { shard }),
            ev(
                trace,
                shard,
                base + 5,
                EventKind::KernelPhase { phase: Phase::RadixCount, dur_secs: 0.002 },
            ),
            ev(
                trace,
                shard,
                base + 6,
                EventKind::KernelPhase { phase: Phase::RadixScatter, dur_secs: 0.004 },
            ),
            ev(trace, shard, base + 7, EventKind::Completed { secs: 0.01 }),
            ev(trace, ROUTER_SHARD, base + 8, EventKind::Completed { secs: 0.012 }),
        ]
    }

    #[test]
    fn complete_chains_pass_the_check() {
        let mut events = full_chain(1, 0, 100);
        events.extend(full_chain(2, 1, 200));
        assert!(check(&events).is_empty(), "{:?}", check(&events));
        let s = summarize(&events);
        assert_eq!(s.traces, 2);
        assert_eq!(s.completed, 2);
        assert_eq!(s.failed, 0);
        assert_eq!(s.completed_with_phases, 2);
        assert_eq!(s.phase_stats.len(), 2);
        assert_eq!(s.phase_stats[0].phase, Phase::RadixCount);
        assert_eq!(s.phase_stats[0].count, 2);
        assert!(s.problems.is_empty());
        // Slowest keeps the worker-vs-router max.
        assert_eq!(s.slowest[0].1, 0.012);
        let text = render(&s);
        assert!(text.contains("kernel.radix.scatter"), "{text}");
        assert!(text.contains("2 traces"), "{text}");
    }

    #[test]
    fn missing_terminal_is_flagged() {
        let events = vec![
            ev(5, 0, 1, EventKind::Submitted),
            ev(5, 0, 2, EventKind::Queued),
        ];
        let problems = check(&events);
        assert_eq!(problems.len(), 2, "{problems:?}"); // stream + trace rules
        assert!(problems.iter().any(|p| p.contains("no terminal")), "{problems:?}");
    }

    #[test]
    fn double_terminal_is_flagged() {
        let events = vec![
            ev(6, 0, 1, EventKind::Submitted),
            ev(6, 0, 2, EventKind::Dispatched { shard: 0 }),
            ev(6, 0, 3, EventKind::Completed { secs: 0.1 }),
            ev(6, 0, 4, EventKind::Failed { reason: FailReason::WorkerLost }),
        ];
        let problems = check(&events);
        assert!(problems.iter().any(|p| p.contains("2 terminal events")), "{problems:?}");
    }

    #[test]
    fn failed_jobs_count_by_reason() {
        let events = vec![
            ev(7, ROUTER_SHARD, 1, EventKind::Submitted),
            ev(7, ROUTER_SHARD, 2, EventKind::Failed { reason: FailReason::Overloaded }),
            ev(8, ROUTER_SHARD, 3, EventKind::Submitted),
            ev(8, ROUTER_SHARD, 4, EventKind::Failed { reason: FailReason::WorkerLost }),
        ];
        assert!(check(&events).is_empty());
        let s = summarize(&events);
        assert_eq!(s.failed, 2);
        assert_eq!(s.failures_by_reason.get("overloaded"), Some(&1));
        assert_eq!(s.failures_by_reason.get("worker_lost"), Some(&1));
        assert!(render(&s).contains("1 overloaded"), "{}", render(&s));
    }

    #[test]
    fn tuner_events_do_not_create_job_traces() {
        let events = vec![ev(
            0,
            1,
            1,
            EventKind::TunerPublished {
                fingerprint: "fp".into(),
                params: "p".into(),
                fitness: 1.0,
                improvement_pct: 2.0,
            },
        )];
        assert!(check(&events).is_empty());
        let s = summarize(&events);
        assert_eq!(s.traces, 0);
        assert_eq!(s.tuner_published, 1);
    }
}
