//! Lock-free bounded MPMC ring for trace events (Vyukov's bounded queue).
//!
//! The sort hot path pushes; a background drainer pops. A push against a
//! full ring **drops the event and returns immediately** — it never blocks,
//! never spins waiting for space, and never allocates (the slots are
//! preallocated). Drops are counted in an atomic the drainer periodically
//! publishes as the `trace.dropped` metric, so lost events are visible
//! without ever being allowed to stall a sort.
//!
//! Each slot carries a sequence number: `seq == pos` means free for the
//! producer at `pos`; `seq == pos + 1` means occupied for the consumer at
//! `pos`. Producers claim a position with a CAS *before* writing, so two
//! producers can never write one slot; the `Release` store of `seq` after
//! the write is what publishes the payload to the consumer's `Acquire`
//! load.

use std::mem::MaybeUninit;

use super::event::TraceEvent;
use crate::util::sync::{AtomicU64, AtomicUsize, Ordering, UnsafeCell};

struct Slot {
    seq: AtomicUsize,
    value: UnsafeCell<MaybeUninit<TraceEvent>>,
}

/// The bounded ring. Capacity is rounded up to a power of two (min 8).
pub struct TraceRing {
    buf: Box<[Slot]>,
    mask: usize,
    enqueue_pos: AtomicUsize,
    dequeue_pos: AtomicUsize,
    dropped: AtomicU64,
}

// SAFETY: moving the ring to another thread moves the boxed slots wholesale;
// `TraceEvent` is `Send`, and the only non-`Send` ingredient (`UnsafeCell`)
// is never aliased across the move because `Self` is taken by value.
unsafe impl Send for TraceRing {}
// SAFETY: slots are handed off between threads through the seq protocol
// above — a slot's payload is only ever touched by the one producer that
// CAS-claimed its position or the one consumer that CAS-claimed it back,
// with Release/Acquire ordering on `seq` sequencing the accesses.
unsafe impl Sync for TraceRing {}

impl TraceRing {
    pub fn with_capacity(capacity: usize) -> TraceRing {
        let cap = capacity.max(8).next_power_of_two();
        let buf: Vec<Slot> = (0..cap)
            .map(|i| Slot {
                seq: AtomicUsize::new(i),
                value: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect();
        TraceRing {
            buf: buf.into_boxed_slice(),
            mask: cap - 1,
            enqueue_pos: AtomicUsize::new(0),
            dequeue_pos: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Non-blocking push. `false` means the ring was full: the event is
    /// dropped and [`dropped`](TraceRing::dropped) incremented.
    pub fn push(&self, value: TraceEvent) -> bool {
        let mut pos = self.enqueue_pos.load(Ordering::Relaxed);
        loop {
            let slot = &self.buf[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - pos as isize;
            if diff == 0 {
                // Free for us if we win the position.
                match self.enqueue_pos.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: the CAS made `pos` exclusively ours; the
                        // consumer cannot touch this slot until the Release
                        // store below.
                        slot.value.with_mut(|p| unsafe { (*p).write(value) });
                        slot.seq.store(pos.wrapping_add(1), Ordering::Release);
                        return true;
                    }
                    Err(actual) => pos = actual,
                }
            } else if diff < 0 {
                // The slot one lap back is still occupied: the ring is full.
                self.dropped.fetch_add(1, Ordering::Relaxed);
                return false;
            } else {
                // Another producer claimed `pos`; chase the head.
                pos = self.enqueue_pos.load(Ordering::Relaxed);
            }
        }
    }

    /// Non-blocking pop (`None` when empty).
    pub fn pop(&self) -> Option<TraceEvent> {
        let mut pos = self.dequeue_pos.load(Ordering::Relaxed);
        loop {
            let slot = &self.buf[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - pos.wrapping_add(1) as isize;
            if diff == 0 {
                match self.dequeue_pos.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: the CAS made this occupied slot exclusively
                        // ours; the producer published the payload with the
                        // Release store `pop`'s Acquire load synchronized on.
                        let value = slot.value.with_mut(|p| unsafe { (*p).assume_init_read() });
                        // Mark free for the producer one lap ahead.
                        slot.seq.store(pos.wrapping_add(self.mask + 1), Ordering::Release);
                        return Some(value);
                    }
                    Err(actual) => pos = actual,
                }
            } else if diff < 0 {
                return None; // empty
            } else {
                pos = self.dequeue_pos.load(Ordering::Relaxed);
            }
        }
    }

    /// Drain everything currently in the ring into `out`; returns how many
    /// events were moved.
    pub fn drain_into(&self, out: &mut Vec<TraceEvent>) -> usize {
        let mut n = 0;
        while let Some(ev) = self.pop() {
            out.push(ev);
            n += 1;
        }
        n
    }

    /// Total events dropped to a full ring since construction.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// The dropped count since the last call (for periodic publication to
    /// a metrics counter without double-counting).
    pub fn take_dropped(&self) -> u64 {
        self.dropped.swap(0, Ordering::Relaxed)
    }
}

impl Drop for TraceRing {
    fn drop(&mut self) {
        // Release any payloads still parked in slots.
        while self.pop().is_some() {}
    }
}

#[cfg(all(test, not(feature = "loom")))]
mod tests {
    use super::*;
    use crate::obs::event::{EventKind, FailReason};
    use std::sync::Arc;

    fn ev(trace_id: u64) -> TraceEvent {
        TraceEvent { trace_id, shard: 0, ts_micros: trace_id, kind: EventKind::Submitted }
    }

    #[test]
    fn fifo_within_capacity() {
        let ring = TraceRing::with_capacity(8);
        assert_eq!(ring.capacity(), 8);
        for i in 0..8 {
            assert!(ring.push(ev(i)));
        }
        for i in 0..8 {
            assert_eq!(ring.pop().unwrap().trace_id, i);
        }
        assert!(ring.pop().is_none());
    }

    #[test]
    fn full_ring_drops_without_blocking() {
        let ring = TraceRing::with_capacity(8);
        for i in 0..8 {
            assert!(ring.push(ev(i)));
        }
        assert!(!ring.push(ev(99)), "full ring must refuse");
        assert!(!ring.push(ev(100)));
        assert_eq!(ring.dropped(), 2);
        assert_eq!(ring.take_dropped(), 2);
        assert_eq!(ring.take_dropped(), 0, "take is a delta");
        // Space freed: pushes succeed again and order is preserved.
        assert_eq!(ring.pop().unwrap().trace_id, 0);
        assert!(ring.push(ev(8)));
        let rest: Vec<u64> = std::iter::from_fn(|| ring.pop()).map(|e| e.trace_id).collect();
        assert_eq!(rest, vec![1, 2, 3, 4, 5, 6, 7, 8]);
    }

    #[test]
    fn capacity_rounds_up_to_power_of_two() {
        assert_eq!(TraceRing::with_capacity(0).capacity(), 8);
        assert_eq!(TraceRing::with_capacity(9).capacity(), 16);
        assert_eq!(TraceRing::with_capacity(1024).capacity(), 1024);
    }

    #[test]
    fn drop_releases_heap_carrying_events() {
        let ring = TraceRing::with_capacity(8);
        ring.push(TraceEvent {
            trace_id: 1,
            shard: 0,
            ts_micros: 0,
            kind: EventKind::TunerPublished {
                fingerprint: "fp".into(),
                params: "p".into(),
                fitness: 1.0,
                improvement_pct: 2.0,
            },
        });
        drop(ring); // must not leak the boxed strings (checked under ASan/Miri)
    }

    #[test]
    fn concurrent_producers_single_consumer() {
        let ring = Arc::new(TraceRing::with_capacity(1 << 14));
        let producers: Vec<_> = (0..4u64)
            .map(|p| {
                let ring = Arc::clone(&ring);
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        let id = p * 1_000_000 + i;
                        ring.push(TraceEvent {
                            trace_id: id,
                            shard: p as u32,
                            ts_micros: i,
                            kind: EventKind::Failed { reason: FailReason::Cancelled },
                        });
                    }
                })
            })
            .collect();
        for h in producers {
            h.join().unwrap();
        }
        let mut got = Vec::new();
        ring.drain_into(&mut got);
        assert_eq!(got.len() as u64 + ring.dropped(), 4000);
        assert_eq!(ring.dropped(), 0, "2^14 slots fit 4000 events");
        // Per-producer order is preserved even across interleaving.
        for p in 0..4u64 {
            let ids: Vec<u64> =
                got.iter().map(|e| e.trace_id).filter(|id| id / 1_000_000 == p).collect();
            assert!(ids.windows(2).all(|w| w[0] < w[1]), "producer {p} order");
        }
    }

    /// Hammer a deliberately tiny ring so every slot wraps hundreds of laps
    /// while a concurrent consumer races the producers. Checks the overflow
    /// accounting exactly (received + dropped == pushed), and that no event
    /// is duplicated or torn: each event's payload fields are derived from
    /// its `trace_id`, so any cross-slot mixup shows up as a mismatch.
    #[test]
    #[cfg_attr(miri, ignore = "minutes-slow under Miri; the small-n tests cover this path")]
    fn wrap_around_under_contention_never_tears_or_double_counts() {
        const PRODUCERS: u64 = 3;
        const PER_PRODUCER: u64 = 4000;
        let ring = Arc::new(TraceRing::with_capacity(8)); // minimum size: max laps
        let done = Arc::new(std::sync::atomic::AtomicUsize::new(0));

        let consumer = {
            let ring = Arc::clone(&ring);
            let done = Arc::clone(&done);
            std::thread::spawn(move || {
                let mut got = Vec::new();
                loop {
                    let drained = ring.drain_into(&mut got);
                    if drained == 0 {
                        if done.load(std::sync::atomic::Ordering::Acquire)
                            == PRODUCERS as usize
                        {
                            // Producers finished and the ring read empty after
                            // that: one final drain and we have everything.
                            ring.drain_into(&mut got);
                            return got;
                        }
                        std::thread::yield_now();
                    }
                }
            })
        };

        let producers: Vec<_> = (0..PRODUCERS)
            .map(|p| {
                let ring = Arc::clone(&ring);
                let done = Arc::clone(&done);
                std::thread::spawn(move || {
                    for i in 0..PER_PRODUCER {
                        let id = p * 1_000_000 + i;
                        ring.push(TraceEvent {
                            trace_id: id,
                            shard: p as u32,
                            ts_micros: i,
                            kind: EventKind::Submitted,
                        });
                    }
                    done.fetch_add(1, std::sync::atomic::Ordering::Release);
                })
            })
            .collect();
        for h in producers {
            h.join().unwrap();
        }
        let got = consumer.join().unwrap();

        // Exact overflow accounting: nothing lost untracked, nothing counted
        // twice.
        assert_eq!(
            got.len() as u64 + ring.dropped(),
            PRODUCERS * PER_PRODUCER,
            "received + dropped must equal pushed"
        );
        // No duplicated events (a seq-protocol bug would let two consumers
        // read one slot, or one payload land twice).
        let mut ids: Vec<u64> = got.iter().map(|e| e.trace_id).collect();
        ids.sort_unstable();
        let before = ids.len();
        ids.dedup();
        assert_eq!(ids.len(), before, "no event may be delivered twice");
        // No torn payloads: every field must agree with the trace_id it was
        // derived from at push time.
        for e in &got {
            assert_eq!(e.shard as u64, e.trace_id / 1_000_000, "torn shard field");
            assert_eq!(e.ts_micros, e.trace_id % 1_000_000, "torn ts field");
            assert!(matches!(e.kind, EventKind::Submitted), "torn kind field");
        }
        // Per-producer FIFO order survives arbitrarily many laps.
        for p in 0..PRODUCERS {
            let ids: Vec<u64> =
                got.iter().map(|e| e.trace_id).filter(|id| id / 1_000_000 == p).collect();
            assert!(ids.windows(2).all(|w| w[0] < w[1]), "producer {p} order");
        }
    }
}

/// Loom models for the seq-protocol invariants. Run with:
///
/// ```text
/// cargo test --features loom --lib -- loom_model
/// ```
///
/// Bodies are deliberately tiny (2 producers × 2 events) because the real
/// loom explores every interleaving; the vendored shim replays each body as
/// a bounded stress loop instead (see `rust/vendor/loom`).
#[cfg(all(test, feature = "loom"))]
mod loom_model {
    use super::*;
    use crate::obs::event::EventKind;
    use crate::util::sync::{thread, Arc};

    fn ev(trace_id: u64) -> TraceEvent {
        TraceEvent { trace_id, shard: 0, ts_micros: trace_id, kind: EventKind::Submitted }
    }

    /// Two racing producers: every accepted event is delivered exactly once,
    /// and accepted + dropped equals pushed under every interleaving.
    #[test]
    fn racing_producers_never_duplicate_or_lose_events() {
        loom::model(|| {
            let ring = Arc::new(TraceRing::with_capacity(8));
            let handles: Vec<_> = (0..2u64)
                .map(|p| {
                    let ring = Arc::clone(&ring);
                    thread::spawn(move || {
                        let mut accepted = 0u64;
                        for i in 0..2u64 {
                            if ring.push(ev(p * 10 + i)) {
                                accepted += 1;
                            }
                        }
                        accepted
                    })
                })
                .collect();
            let accepted: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
            let mut got = Vec::new();
            ring.drain_into(&mut got);
            assert_eq!(got.len() as u64, accepted);
            assert_eq!(accepted + ring.dropped(), 4);
            let mut ids: Vec<u64> = got.iter().map(|e| e.trace_id).collect();
            ids.sort_unstable();
            let before = ids.len();
            ids.dedup();
            assert_eq!(ids.len(), before, "no duplicated deliveries");
        });
    }

    /// Producer/consumer race on the same slots: the consumer sees each
    /// payload exactly once, in order, with the Acquire load synchronized on
    /// the producer's Release store (loom flags any unsynchronized access to
    /// the slot's `UnsafeCell`).
    #[test]
    fn push_pop_race_hands_off_each_payload_once() {
        loom::model(|| {
            let ring = Arc::new(TraceRing::with_capacity(8));
            let producer = {
                let ring = Arc::clone(&ring);
                thread::spawn(move || {
                    assert!(ring.push(ev(1)));
                    assert!(ring.push(ev(2)));
                })
            };
            let mut got = Vec::new();
            while got.len() < 2 {
                match ring.pop() {
                    Some(e) => got.push(e.trace_id),
                    None => thread::yield_now(),
                }
            }
            producer.join().unwrap();
            assert_eq!(got, vec![1, 2]);
            assert!(ring.pop().is_none());
        });
    }

    /// Sequence numbers stay coherent across full laps: after fill → refuse
    /// → drain, the next lap behaves identically (checked under loom's
    /// instrumented cell so a stale-seq bug is a model failure, not luck).
    #[test]
    fn sequence_numbers_survive_full_laps() {
        loom::model(|| {
            let ring = TraceRing::with_capacity(8);
            for lap in 0..3u64 {
                for i in 0..8 {
                    assert!(ring.push(ev(lap * 8 + i)));
                }
                assert!(!ring.push(ev(999)), "lap {lap}: full ring must refuse");
                for i in 0..8 {
                    assert_eq!(ring.pop().unwrap().trace_id, lap * 8 + i);
                }
                assert!(ring.pop().is_none());
            }
            assert_eq!(ring.dropped(), 3);
        });
    }
}
