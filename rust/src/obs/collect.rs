//! The drain side of tracing: one [`TraceHub`] per deployment collects
//! events from the local ring **and** from remote shards into one ordered
//! timeline, writing through to the JSONL log.
//!
//! A background drainer thread empties the [`Tracer`]'s ring every few
//! milliseconds (the hot path only ever pushes), appends each batch to the
//! trace log, folds it into a bounded in-memory timeline keyed by
//! `(shard, trace id)`, and publishes the ring's drop counter as the
//! `trace.dropped` metric — so the sort path never touches the metrics
//! mutex or the file. The shard router feeds event batches streamed from
//! worker processes into the same hub via [`ingest`](TraceHub::ingest);
//! local and remote events land in one log and one timeline, identically
//! over unix and TCP transports.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use super::event::TraceEvent;
use super::jsonl::TraceLog;
use super::Tracer;
use crate::coordinator::metrics::{names, Metrics};

/// Timeline retention bound: the hub keeps the most recent traces' events
/// in memory (the JSONL log keeps everything). Oldest-keyed traces are
/// evicted past this many distinct `(shard, trace)` keys.
const MAX_TIMELINE_KEYS: usize = 4096;

/// Drainer cadence.
const DRAIN_INTERVAL: Duration = Duration::from_millis(10);

struct HubState {
    log: Option<TraceLog>,
    /// Ordered timeline: events per `(shard, trace id)`, in arrival order
    /// (sorted by timestamp on read).
    timeline: BTreeMap<(u32, u64), Vec<TraceEvent>>,
    /// Insertion order of timeline keys, for bounded eviction.
    key_order: Vec<(u32, u64)>,
}

struct HubInner {
    tracer: Tracer,
    metrics: Option<Arc<Metrics>>,
    state: Mutex<HubState>,
    stop: AtomicBool,
    /// Ring drops folded in by the drainer (mirrors the `trace.dropped`
    /// counter for hubs without a metrics registry).
    dropped: AtomicU64,
}

impl HubInner {
    /// One drain cycle: move ring contents into the sinks, publish drops.
    fn drain_once(&self, scratch: &mut Vec<TraceEvent>) {
        scratch.clear();
        self.tracer.drain_into(scratch);
        let dropped = self.tracer.take_dropped();
        if dropped > 0 {
            self.dropped.fetch_add(dropped, Ordering::Relaxed);
            if let Some(m) = &self.metrics {
                m.add(names::TRACE_DROPPED, dropped);
            }
        }
        if !scratch.is_empty() {
            self.sink(scratch);
        }
    }

    fn sink(&self, events: &[TraceEvent]) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(log) = st.log.as_mut() {
            let _ = log.append_all(events);
        }
        for ev in events {
            let key = (ev.shard, ev.trace_id);
            match st.timeline.get_mut(&key) {
                Some(list) => list.push(ev.clone()),
                None => {
                    st.timeline.insert(key, vec![ev.clone()]);
                    st.key_order.push(key);
                }
            }
        }
        // Bounded retention: evict the oldest traces wholesale.
        while st.key_order.len() > MAX_TIMELINE_KEYS {
            let key = st.key_order.remove(0);
            st.timeline.remove(&key);
        }
    }
}

/// The deployment-wide trace collector. Owns the drainer thread; dropping
/// the hub performs a final drain and flushes the log.
pub struct TraceHub {
    inner: Arc<HubInner>,
    drainer: Option<JoinHandle<()>>,
}

impl TraceHub {
    /// Build a hub over `tracer`, optionally writing through to a JSONL
    /// log at `log_path` and publishing `trace.dropped` into `metrics`.
    pub fn new(
        tracer: Tracer,
        log_path: Option<&Path>,
        metrics: Option<Arc<Metrics>>,
    ) -> Result<TraceHub> {
        let log = match log_path {
            Some(p) => Some(TraceLog::create(p)?),
            None => None,
        };
        let inner = Arc::new(HubInner {
            tracer,
            metrics,
            state: Mutex::new(HubState {
                log,
                timeline: BTreeMap::new(),
                key_order: Vec::new(),
            }),
            stop: AtomicBool::new(false),
            dropped: AtomicU64::new(0),
        });
        let drainer = if inner.tracer.is_enabled() {
            let inner = Arc::clone(&inner);
            Some(
                std::thread::Builder::new()
                    .name("evosort-trace-drain".into())
                    .spawn(move || {
                        let mut scratch = Vec::with_capacity(256);
                        while !inner.stop.load(Ordering::Relaxed) {
                            inner.drain_once(&mut scratch);
                            std::thread::sleep(DRAIN_INTERVAL);
                        }
                        inner.drain_once(&mut scratch);
                    })
                    .expect("spawn trace drainer"),
            )
        } else {
            None
        };
        Ok(TraceHub { inner, drainer })
    }

    /// The tracer this hub drains — clone it into services/kernels/tuners.
    pub fn tracer(&self) -> &Tracer {
        &self.inner.tracer
    }

    /// Feed externally collected events (a worker's streamed batch) into
    /// the log and timeline directly, bypassing the local ring.
    pub fn ingest(&self, events: &[TraceEvent]) {
        if events.is_empty() {
            return;
        }
        if let Some(m) = &self.inner.metrics {
            m.add(names::TRACE_INGESTED, events.len() as u64);
        }
        self.inner.sink(events);
    }

    /// Drain the ring now and flush the log (end-of-run synchronization —
    /// the drainer also does this continuously).
    pub fn flush(&self) {
        let mut scratch = Vec::new();
        self.inner.drain_once(&mut scratch);
        let mut st = self.inner.state.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(log) = st.log.as_mut() {
            let _ = log.flush();
        }
    }

    /// Total ring-full drops observed so far.
    pub fn dropped(&self) -> u64 {
        self.inner.dropped.load(Ordering::Relaxed) + self.inner.tracer.dropped()
    }

    /// Distinct `(shard, trace id)` keys currently retained.
    pub fn timeline_len(&self) -> usize {
        self.inner.state.lock().unwrap_or_else(|e| e.into_inner()).timeline.len()
    }

    /// All retained events for one trace id, merged across shards and
    /// ordered by timestamp.
    pub fn events_for(&self, trace_id: u64) -> Vec<TraceEvent> {
        let st = self.inner.state.lock().unwrap_or_else(|e| e.into_inner());
        let mut out: Vec<TraceEvent> = st
            .timeline
            .iter()
            .filter(|((_, t), _)| *t == trace_id)
            .flat_map(|(_, evs)| evs.iter().cloned())
            .collect();
        out.sort_by_key(|e| e.ts_micros);
        out
    }

    /// Every retained event, ordered by `(shard, trace id)` then timestamp
    /// (tests and end-of-run summaries; bounded by the retention cap).
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        let st = self.inner.state.lock().unwrap_or_else(|e| e.into_inner());
        let mut out = Vec::new();
        for evs in st.timeline.values() {
            let mut evs: Vec<TraceEvent> = evs.clone();
            evs.sort_by_key(|e| e.ts_micros);
            out.extend(evs);
        }
        out
    }
}

impl Drop for TraceHub {
    fn drop(&mut self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.drainer.take() {
            let _ = h.join();
        }
        let mut st = self.inner.state.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(log) = st.log.as_mut() {
            let _ = log.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::event::{EventKind, FailReason};
    use crate::obs::jsonl;

    #[test]
    fn hub_drains_ring_into_log_and_timeline() {
        let dir = std::env::temp_dir().join(format!("evosort-hub-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("hub.jsonl");
        let metrics = Arc::new(Metrics::new());
        let tracer = Tracer::enabled(64, 2);
        {
            let hub =
                TraceHub::new(tracer.clone(), Some(&path), Some(Arc::clone(&metrics))).unwrap();
            tracer.emit(5, EventKind::Submitted);
            tracer.emit(5, EventKind::Completed { secs: 0.1 });
            hub.flush();
            assert_eq!(hub.timeline_len(), 1);
            let evs = hub.events_for(5);
            assert_eq!(evs.len(), 2);
            assert_eq!(evs[0].kind, EventKind::Submitted);
            assert_eq!(evs[0].shard, 2);
            // Remote batches merge into the same timeline under their shard.
            hub.ingest(&[TraceEvent {
                trace_id: 5,
                shard: 7,
                ts_micros: u64::MAX,
                kind: EventKind::Failed { reason: FailReason::WorkerLost },
            }]);
            assert_eq!(hub.events_for(5).len(), 3);
            assert_eq!(hub.timeline_len(), 2, "distinct (shard, trace) keys");
        } // drop flushes the log
        let back = jsonl::read_events(&path).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(metrics.counter(names::TRACE_INGESTED), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn ring_overflow_is_counted_not_blocking() {
        let metrics = Arc::new(Metrics::new());
        let tracer = Tracer::enabled(8, 0);
        let hub = TraceHub::new(tracer.clone(), None, Some(Arc::clone(&metrics))).unwrap();
        // Flood far past capacity, faster than the drainer can keep up;
        // every push must return (drop, not block).
        for i in 0..10_000u64 {
            tracer.emit(i, EventKind::Queued);
        }
        drop(hub); // joins the drainer: every drop delta is published
        let dropped = metrics.counter(names::TRACE_DROPPED);
        assert!(dropped > 0, "an 8-slot ring cannot absorb 10k events");
        assert!(dropped < 10_000, "some events still flow");
    }

    #[test]
    fn disabled_tracer_hub_still_ingests() {
        let hub = TraceHub::new(Tracer::disabled(), None, None).unwrap();
        hub.ingest(&[TraceEvent {
            trace_id: 1,
            shard: 3,
            ts_micros: 1,
            kind: EventKind::Submitted,
        }]);
        assert_eq!(hub.timeline_len(), 1);
        assert_eq!(hub.events_for(1).len(), 1);
    }
}
