//! Layer-3 coordination: the master pipeline (Algorithm 1), the long-running
//! sort service (typed async job API: dtype-generic requests, non-blocking
//! tickets, result streaming, backpressure + metrics), the tuning cache, and
//! the cross-process sharded deployment layer ([`shard`]: a router that
//! spreads the same typed API over a fleet of `evosort shard-worker` OS
//! processes — locally spawned or remote — on a frame transport addressed
//! by typed [`Endpoint`]s (`unix:///path.sock`, `tcp://host:port`)).

// Enforced boundary of the unsafe audit surface (see README
// “Correctness tooling”): the whole coordination layer (service, shards,
// pipeline, metrics) is safe Rust; unsafe is confined to `exec`, `obs::ring`
// and the `sort` kernels.
#![forbid(unsafe_code)]

pub mod endpoint;
pub mod metrics;
pub mod pipeline;
pub mod request;
pub mod service;
#[cfg(unix)]
pub mod shard;
pub mod ticket;
pub mod tuning_cache;

pub use endpoint::{Endpoint, EndpointParseError, TransportKind};
pub use metrics::Metrics;
pub use pipeline::{BatchWorkload, ParamSource, PipelineConfig, PipelineRow};
pub use request::SortRequest;
pub use service::{
    BatchReport, BatchStats, BatchTicket, DtypeStats, ResultStream, ServiceConfig, SortService,
};
#[cfg(unix)]
pub use shard::{ShardRouter, ShardSpec, ShardedService, ShardedServiceBuilder};
pub use ticket::{JobError, JobResult, SortOutput, Ticket};
pub use tuning_cache::{CacheEntry, TuningCache};
