//! Layer-3 coordination: the master pipeline (Algorithm 1), the long-running
//! sort service (typed async job API: dtype-generic requests, non-blocking
//! tickets, result streaming, backpressure + metrics), and the tuning cache.

pub mod metrics;
pub mod pipeline;
pub mod request;
pub mod service;
pub mod ticket;
pub mod tuning_cache;

pub use metrics::Metrics;
pub use pipeline::{BatchWorkload, ParamSource, PipelineConfig, PipelineRow};
pub use request::SortRequest;
pub use service::{
    BatchReport, BatchStats, BatchTicket, DtypeStats, ResultStream, ServiceConfig, SortService,
};
pub use ticket::{JobError, JobResult, SortOutput, Ticket};
pub use tuning_cache::TuningCache;

// Deprecated pre-dtype surface — kept re-exported for one release so
// existing `use evosort::coordinator::{SortJob, JobHandle, ...}` call sites
// keep compiling (each use still warns at the caller).
#[allow(deprecated)]
pub use service::{BatchHandle, JobHandle, SortJob, SortOutcome};
