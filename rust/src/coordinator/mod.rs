//! Layer-3 coordination: the master pipeline (Algorithm 1), the long-running
//! sort service (job queue + backpressure + metrics), and the tuning cache.

pub mod metrics;
pub mod pipeline;
pub mod service;
pub mod tuning_cache;

pub use metrics::Metrics;
pub use pipeline::{BatchWorkload, ParamSource, PipelineConfig, PipelineRow};
pub use service::{
    BatchHandle, BatchReport, BatchStats, JobHandle, ServiceConfig, SortJob, SortOutcome,
    SortService,
};
pub use tuning_cache::TuningCache;
