//! Metrics registry for the sort service: lock-free counters, Welford-backed
//! latency series, gauges, and bounded sample windows for percentile queries
//! (p50/p99 batch latency), all `Send + Sync`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::Welford;

/// How many recent samples a percentile window retains per series.
const SAMPLE_WINDOW: usize = 8192;

/// A sliding window of recent f64 observations (ring buffer) supporting
/// percentile queries. Welford summaries cannot answer p99; a bounded window
/// keeps memory O(1) under service-lifetime traffic.
#[derive(Debug, Clone, Default)]
pub struct SampleWindow {
    values: Vec<f64>,
    next: usize,
    total: u64,
}

impl SampleWindow {
    pub fn push(&mut self, x: f64) {
        if self.values.len() < SAMPLE_WINDOW {
            self.values.push(x);
        } else {
            self.values[self.next] = x;
            self.next = (self.next + 1) % SAMPLE_WINDOW;
        }
        self.total += 1;
    }

    /// Observations ever pushed (window holds min(total, capacity)).
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Nearest-rank percentile over the retained window; `None` when empty.
    pub fn percentile(&self, q: f64) -> Option<f64> {
        percentile_of_unsorted(&self.values, q)
    }
}

/// Nearest-rank percentile of an unsorted sample set (`q` in [0, 100]).
pub fn percentile_of_unsorted(samples: &[f64], q: f64) -> Option<f64> {
    if samples.is_empty() {
        return None;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    Some(percentile_of_sorted(&sorted, q))
}

/// Nearest-rank percentile of an already-sorted, non-empty sample set.
pub fn percentile_of_sorted(sorted: &[f64], q: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    let n = sorted.len();
    let rank = ((q / 100.0) * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Registry shared across service workers.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<HashMap<String, AtomicU64>>,
    latencies: Mutex<HashMap<String, Welford>>,
    gauges: Mutex<HashMap<String, f64>>,
    samples: Mutex<HashMap<String, SampleWindow>>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, delta: u64) {
        let mut map = self.counters.lock().unwrap();
        map.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(delta, Ordering::Relaxed);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// `a / (a + b)` over two counters, `None` before any observation —
    /// e.g. the tuning-cache hit rate from `params.cache_hit` /
    /// `params.cache_miss` (the online tuner publishes it as the
    /// `tuner.cache_hit_rate` gauge).
    pub fn counter_ratio(&self, a: &str, b: &str) -> Option<f64> {
        let (a, b) = (self.counter(a), self.counter(b));
        if a + b == 0 {
            None
        } else {
            Some(a as f64 / (a + b) as f64)
        }
    }

    /// Record a latency observation (seconds).
    pub fn observe(&self, name: &str, secs: f64) {
        let mut map = self.latencies.lock().unwrap();
        map.entry(name.to_string()).or_insert_with(Welford::new).push(secs);
    }

    /// Snapshot of one latency series.
    pub fn latency(&self, name: &str) -> Option<Welford> {
        self.latencies.lock().unwrap().get(name).copied()
    }

    /// Set a gauge (latest-value metric, e.g. `batch.jobs_per_sec`).
    pub fn set_gauge(&self, name: &str, value: f64) {
        self.gauges.lock().unwrap().insert(name.to_string(), value);
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.lock().unwrap().get(name).copied()
    }

    /// Record an observation into a bounded percentile window.
    pub fn observe_sample(&self, name: &str, value: f64) {
        self.samples.lock().unwrap().entry(name.to_string()).or_default().push(value);
    }

    /// Nearest-rank percentile (`q` in [0, 100]) over a sample window.
    pub fn percentile(&self, name: &str, q: f64) -> Option<f64> {
        self.samples.lock().unwrap().get(name).and_then(|w| w.percentile(q))
    }

    /// Render a human-readable report (CLI `info`/`serve` output).
    pub fn report(&self) -> String {
        let mut out = String::new();
        let counters = self.counters.lock().unwrap();
        let mut names: Vec<&String> = counters.keys().collect();
        names.sort();
        for name in names {
            out.push_str(&format!(
                "counter {name} = {}\n",
                counters[name].load(Ordering::Relaxed)
            ));
        }
        let lats = self.latencies.lock().unwrap();
        let mut names: Vec<&String> = lats.keys().collect();
        names.sort();
        for name in names {
            let w = &lats[name];
            out.push_str(&format!(
                "latency {name}: n={} mean={:.6}s min={:.6}s max={:.6}s stddev={:.6}s\n",
                w.count(),
                w.mean(),
                w.min(),
                w.max(),
                w.stddev()
            ));
        }
        let gauges = self.gauges.lock().unwrap();
        let mut names: Vec<&String> = gauges.keys().collect();
        names.sort();
        for name in names {
            out.push_str(&format!("gauge {name} = {:.6}\n", gauges[name]));
        }
        let samples = self.samples.lock().unwrap();
        let mut names: Vec<&String> = samples.keys().collect();
        names.sort();
        for name in names {
            let w = &samples[name];
            let (p50, p99) = (w.percentile(50.0).unwrap_or(0.0), w.percentile(99.0).unwrap_or(0.0));
            out.push_str(&format!(
                "samples {name}: n={} p50={p50:.6} p99={p99:.6}\n",
                w.total()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("jobs");
        m.add("jobs", 4);
        assert_eq!(m.counter("jobs"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn counter_ratio_hit_rate() {
        let m = Metrics::new();
        assert_eq!(m.counter_ratio("hit", "miss"), None);
        m.add("hit", 3);
        m.add("miss", 1);
        assert_eq!(m.counter_ratio("hit", "miss"), Some(0.75));
        assert_eq!(m.counter_ratio("miss", "hit"), Some(0.25));
    }

    #[test]
    fn latency_series() {
        let m = Metrics::new();
        m.observe("sort", 0.5);
        m.observe("sort", 1.5);
        let w = m.latency("sort").unwrap();
        assert_eq!(w.count(), 2);
        assert!((w.mean() - 1.0).abs() < 1e-12);
        assert!(m.latency("none").is_none());
    }

    #[test]
    fn concurrent_updates() {
        let m = Arc::new(Metrics::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let m = Arc::clone(&m);
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.incr("hits");
                        m.observe("lat", 0.001);
                    }
                });
            }
        });
        assert_eq!(m.counter("hits"), 8000);
        assert_eq!(m.latency("lat").unwrap().count(), 8000);
    }

    #[test]
    fn report_contains_series() {
        let m = Metrics::new();
        m.incr("a");
        m.observe("b", 2.0);
        m.set_gauge("g", 1.25);
        m.observe_sample("s", 0.5);
        let r = m.report();
        assert!(r.contains("counter a = 1"));
        assert!(r.contains("latency b:"));
        assert!(r.contains("gauge g = 1.250000"));
        assert!(r.contains("samples s: n=1"));
    }

    #[test]
    fn gauges_overwrite() {
        let m = Metrics::new();
        assert!(m.gauge("x").is_none());
        m.set_gauge("x", 1.0);
        m.set_gauge("x", 2.5);
        assert_eq!(m.gauge("x"), Some(2.5));
    }

    #[test]
    fn percentiles_nearest_rank() {
        // 1..=100: p50 = 50, p99 = 99, p100 = 100, p1 = 1.
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe_sample("lat", i as f64);
        }
        assert_eq!(m.percentile("lat", 50.0), Some(50.0));
        assert_eq!(m.percentile("lat", 99.0), Some(99.0));
        assert_eq!(m.percentile("lat", 100.0), Some(100.0));
        assert_eq!(m.percentile("lat", 1.0), Some(1.0));
        assert_eq!(m.percentile("lat", 0.0), Some(1.0));
        assert!(m.percentile("missing", 50.0).is_none());
    }

    #[test]
    fn percentile_single_sample() {
        let m = Metrics::new();
        m.observe_sample("one", 7.5);
        assert_eq!(m.percentile("one", 50.0), Some(7.5));
        assert_eq!(m.percentile("one", 99.0), Some(7.5));
    }

    #[test]
    fn sample_window_slides() {
        let mut w = SampleWindow::default();
        for i in 0..(SAMPLE_WINDOW + 100) {
            w.push(i as f64);
        }
        assert_eq!(w.total(), (SAMPLE_WINDOW + 100) as u64);
        // Oldest 100 samples evicted: the minimum retained value is >= 100.
        assert!(w.percentile(0.0).unwrap() >= 100.0);
    }

    #[test]
    fn percentile_helpers() {
        assert_eq!(percentile_of_unsorted(&[], 50.0), None);
        assert_eq!(percentile_of_unsorted(&[3.0, 1.0, 2.0], 50.0), Some(2.0));
        assert_eq!(percentile_of_sorted(&[1.0, 2.0, 3.0], 100.0), 3.0);
        assert_eq!(percentile_of_sorted(&[42.0], 99.0), 42.0);
    }
}
