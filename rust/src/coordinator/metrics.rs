//! Metrics registry for the sort service: lock-free counters plus
//! Welford-backed latency series, all `Send + Sync`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::Welford;

/// Registry shared across service workers.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<HashMap<String, AtomicU64>>,
    latencies: Mutex<HashMap<String, Welford>>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, delta: u64) {
        let mut map = self.counters.lock().unwrap();
        map.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(delta, Ordering::Relaxed);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Record a latency observation (seconds).
    pub fn observe(&self, name: &str, secs: f64) {
        let mut map = self.latencies.lock().unwrap();
        map.entry(name.to_string()).or_insert_with(Welford::new).push(secs);
    }

    /// Snapshot of one latency series.
    pub fn latency(&self, name: &str) -> Option<Welford> {
        self.latencies.lock().unwrap().get(name).copied()
    }

    /// Render a human-readable report (CLI `info`/`serve` output).
    pub fn report(&self) -> String {
        let mut out = String::new();
        let counters = self.counters.lock().unwrap();
        let mut names: Vec<&String> = counters.keys().collect();
        names.sort();
        for name in names {
            out.push_str(&format!(
                "counter {name} = {}\n",
                counters[name].load(Ordering::Relaxed)
            ));
        }
        let lats = self.latencies.lock().unwrap();
        let mut names: Vec<&String> = lats.keys().collect();
        names.sort();
        for name in names {
            let w = &lats[name];
            out.push_str(&format!(
                "latency {name}: n={} mean={:.6}s min={:.6}s max={:.6}s stddev={:.6}s\n",
                w.count(),
                w.mean(),
                w.min(),
                w.max(),
                w.stddev()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("jobs");
        m.add("jobs", 4);
        assert_eq!(m.counter("jobs"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn latency_series() {
        let m = Metrics::new();
        m.observe("sort", 0.5);
        m.observe("sort", 1.5);
        let w = m.latency("sort").unwrap();
        assert_eq!(w.count(), 2);
        assert!((w.mean() - 1.0).abs() < 1e-12);
        assert!(m.latency("none").is_none());
    }

    #[test]
    fn concurrent_updates() {
        let m = Arc::new(Metrics::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let m = Arc::clone(&m);
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.incr("hits");
                        m.observe("lat", 0.001);
                    }
                });
            }
        });
        assert_eq!(m.counter("hits"), 8000);
        assert_eq!(m.latency("lat").unwrap().count(), 8000);
    }

    #[test]
    fn report_contains_series() {
        let m = Metrics::new();
        m.incr("a");
        m.observe("b", 2.0);
        let r = m.report();
        assert!(r.contains("counter a = 1"));
        assert!(r.contains("latency b:"));
    }
}
