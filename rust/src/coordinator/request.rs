//! [`SortRequest`]: the dtype-erased job description the typed service API
//! accepts.
//!
//! A request wraps a [`SortPayload`] (any supported [`SortKey`] dtype) plus
//! the per-job knobs: a human-readable distribution hint, an optional
//! explicit parameter override, and the validation switch.
//! Construction is typed ([`SortRequest::new`]); everything downstream —
//! queueing, parameter resolution, execution — is dtype-erased, so one
//! service instance serves mixed i64/i32/u64/f64 traffic.

use crate::params::SortParams;
use crate::sort::{Dtype, SortKey, SortPayload};

/// A sorting request for any supported key dtype.
///
/// ```
/// use evosort::coordinator::{ServiceConfig, SortRequest, SortService};
///
/// let svc = SortService::new(ServiceConfig::default());
/// // Typed construction; floats sort in IEEE-754 total_cmp order.
/// let ticket = svc.submit_request(SortRequest::new(vec![2.5f64, f64::NAN, -0.0, 0.0, -7.0]));
/// let out = ticket.wait().expect("job completed");
/// assert!(out.valid);
/// let sorted = out.data::<f64>().unwrap();
/// assert_eq!(sorted[0], -7.0);
/// assert!(sorted[4].is_nan()); // NaN is a key with a defined position, not an error
/// ```
#[derive(Debug)]
pub struct SortRequest {
    pub(crate) payload: SortPayload,
    /// Caller-declared workload tag ("uniform", "zipf", ...). A **hint**
    /// only: parameter resolution keys the tuning cache on a dtype-tagged
    /// fingerprint of the actual data (see
    /// [`crate::autotune::Fingerprint`]), so a mislabeled request cannot
    /// poison the cache for its class.
    pub dist: String,
    /// Explicit parameter override (skips cache + model).
    pub params: Option<SortParams>,
    /// Validate the output before returning (adds one parallel pass).
    pub validate: bool,
    /// Externally assigned trace id. `None` means "trace under the id the
    /// service assigns the job" — the shard workers set this to the router's
    /// job id so one trace spans the whole fleet.
    pub trace_id: Option<u64>,
}

impl SortRequest {
    /// A request over typed data with default knobs (validation on).
    pub fn new<K: SortKey>(data: Vec<K>) -> SortRequest {
        Self::from_payload(K::into_payload(data))
    }

    /// A request over an already-erased payload.
    pub fn from_payload(payload: SortPayload) -> SortRequest {
        SortRequest {
            payload,
            dist: "uniform".into(),
            params: None,
            validate: true,
            trace_id: None,
        }
    }

    pub fn dtype(&self) -> Dtype {
        self.payload.dtype()
    }

    pub fn len(&self) -> usize {
        self.payload.len()
    }

    pub fn is_empty(&self) -> bool {
        self.payload.is_empty()
    }

    pub fn payload(&self) -> &SortPayload {
        &self.payload
    }

    /// Set the workload hint (builder style).
    pub fn with_dist(mut self, dist: &str) -> SortRequest {
        self.dist = dist.to_string();
        self
    }

    /// Set an explicit parameter override (builder style).
    pub fn with_params(mut self, params: SortParams) -> SortRequest {
        self.params = Some(params);
        self
    }

    /// Skip output validation (builder style).
    pub fn without_validation(mut self) -> SortRequest {
        self.validate = false;
        self
    }

    /// Trace this job under an externally assigned id (builder style) —
    /// the shard worker stamps the router's job id here so worker-side
    /// events merge into the router's trace.
    pub fn with_trace_id(mut self, trace_id: u64) -> SortRequest {
        self.trace_id = Some(trace_id);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typed_construction_and_builders() {
        let req = SortRequest::new(vec![3u64, 1, 2])
            .with_dist("zipf")
            .with_params(SortParams::paper_1e7())
            .without_validation();
        assert_eq!(req.dtype(), Dtype::U64);
        assert_eq!(req.len(), 3);
        assert!(!req.is_empty());
        assert_eq!(req.dist, "zipf");
        assert_eq!(req.params, Some(SortParams::paper_1e7()));
        assert!(!req.validate);
        assert_eq!(req.payload().as_slice::<u64>(), Some(&[3u64, 1, 2][..]));
    }

    #[test]
    fn defaults_match_the_old_sortjob_contract() {
        let req = SortRequest::new(Vec::<i64>::new());
        assert_eq!(req.dtype(), Dtype::I64);
        assert!(req.is_empty());
        assert_eq!(req.dist, "uniform");
        assert!(req.params.is_none());
        assert!(req.validate);
        assert!(req.trace_id.is_none());
        assert_eq!(req.with_trace_id(9).trace_id, Some(9));
    }
}
