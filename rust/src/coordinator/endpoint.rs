//! Typed service endpoints: where a shard worker listens or a router dials.
//!
//! The shard fleet speaks one frame protocol over two transports, and every
//! place that used to take a bare socket path (`ShardSpec`, `[service]`
//! config, CLI flags) now takes an [`Endpoint`]:
//!
//! * `unix:///run/evosort/shard.sock` — a Unix-domain socket (single host);
//! * `tcp://10.0.0.7:7001` — a TCP socket (multi-node fleets; also
//!   `tcp://[::1]:7001` for IPv6 literals, `tcp://127.0.0.1:0` to let the
//!   OS pick the port).
//!
//! `FromStr` and `Display` round-trip, so an endpoint printed by one process
//! (`shard-worker --listen` announces its resolved address this way) can be
//! pasted into another's `--connect`. Parse errors say what was wrong and
//! what the accepted forms are — they surface directly to config/CLI users.
//!
//! The type is plain data and compiles everywhere; the unix-only socket
//! machinery lives in `shard::transport`.

use std::fmt;
use std::path::PathBuf;
use std::str::FromStr;

/// Which transport an [`Endpoint`] (or a whole shard fleet) uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportKind {
    /// Unix-domain sockets: single host, no network exposure (the default).
    #[default]
    Unix,
    /// TCP sockets: multi-node, **no auth/encryption** — loopback or
    /// trusted networks only.
    Tcp,
}

impl TransportKind {
    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Unix => "unix",
            TransportKind::Tcp => "tcp",
        }
    }

    /// Parse a CLI/config name.
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s {
            "unix" => Some(TransportKind::Unix),
            "tcp" => Some(TransportKind::Tcp),
            _ => None,
        }
    }
}

impl fmt::Display for TransportKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A typed socket address: `unix:///path` or `tcp://host:port`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Endpoint {
    /// A Unix-domain socket path.
    Unix(PathBuf),
    /// A TCP host + port. `port == 0` means "let the OS pick" (listen side
    /// only — the resolved port is what gets announced/dialed).
    Tcp { host: String, port: u16 },
}

impl Endpoint {
    /// Shorthand for a TCP endpoint.
    pub fn tcp(host: impl Into<String>, port: u16) -> Endpoint {
        Endpoint::Tcp { host: host.into(), port }
    }

    /// Shorthand for a Unix-socket endpoint.
    pub fn unix(path: impl Into<PathBuf>) -> Endpoint {
        Endpoint::Unix(path.into())
    }

    /// The transport this address belongs to.
    pub fn transport(&self) -> TransportKind {
        match self {
            Endpoint::Unix(_) => TransportKind::Unix,
            Endpoint::Tcp { .. } => TransportKind::Tcp,
        }
    }
}

impl fmt::Display for Endpoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Endpoint::Unix(path) => write!(f, "unix://{}", path.display()),
            Endpoint::Tcp { host, port } => {
                // IPv6 literals print bracketed so Display round-trips
                // through FromStr (the last-colon split needs the brackets).
                if host.contains(':') {
                    write!(f, "tcp://[{host}]:{port}")
                } else {
                    write!(f, "tcp://{host}:{port}")
                }
            }
        }
    }
}

/// What went wrong parsing an endpoint, with the accepted forms spelled out
/// (these errors surface verbatim to config/CLI users).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EndpointParseError {
    input: String,
    problem: String,
}

impl fmt::Display for EndpointParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "invalid endpoint {:?}: {} (expected `unix:///path/to.sock` or `tcp://host:port`)",
            self.input, self.problem
        )
    }
}

impl std::error::Error for EndpointParseError {}

fn err(input: &str, problem: impl Into<String>) -> EndpointParseError {
    EndpointParseError { input: input.to_string(), problem: problem.into() }
}

impl FromStr for Endpoint {
    type Err = EndpointParseError;

    fn from_str(s: &str) -> Result<Endpoint, EndpointParseError> {
        let s = s.trim();
        if let Some(path) = s.strip_prefix("unix://") {
            if path.is_empty() {
                return Err(err(s, "empty socket path"));
            }
            return Ok(Endpoint::Unix(PathBuf::from(path)));
        }
        if let Some(addr) = s.strip_prefix("tcp://") {
            let (host, port) = if let Some(rest) = addr.strip_prefix('[') {
                // Bracketed IPv6 literal: `[::1]:7001`.
                let Some((host, tail)) = rest.split_once(']') else {
                    return Err(err(s, "unterminated `[` in IPv6 host"));
                };
                let Some(port) = tail.strip_prefix(':') else {
                    return Err(err(s, "missing `:port` after the IPv6 host"));
                };
                (host, port)
            } else {
                match addr.rsplit_once(':') {
                    Some(split) => split,
                    None => return Err(err(s, "missing `:port` after the host")),
                }
            };
            if host.is_empty() {
                return Err(err(s, "empty host"));
            }
            let port: u16 = port
                .parse()
                .map_err(|_| err(s, format!("port {port:?} is not a number in 0..=65535")))?;
            return Ok(Endpoint::Tcp { host: host.to_string(), port });
        }
        match s.split_once("://") {
            Some((scheme, _)) => Err(err(s, format!("unknown scheme {scheme:?}"))),
            None => Err(err(s, "missing scheme")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_from_str_round_trips() {
        let cases = [
            Endpoint::unix("/run/evosort/shard.sock"),
            Endpoint::tcp("127.0.0.1", 7001),
            Endpoint::tcp("worker-3.internal", 0),
            Endpoint::tcp("::1", 7001), // prints bracketed
        ];
        for ep in cases {
            let text = ep.to_string();
            let back: Endpoint = text.parse().unwrap_or_else(|e| panic!("{text}: {e}"));
            assert_eq!(back, ep, "round-trip through {text}");
        }
        assert_eq!(
            Endpoint::tcp("::1", 7001).to_string(),
            "tcp://[::1]:7001",
            "IPv6 literals print bracketed"
        );
    }

    #[test]
    fn parse_accepts_both_schemes() {
        assert_eq!(
            "unix:///tmp/x.sock".parse::<Endpoint>().unwrap(),
            Endpoint::unix("/tmp/x.sock")
        );
        assert_eq!(
            "tcp://10.0.0.7:7001".parse::<Endpoint>().unwrap(),
            Endpoint::tcp("10.0.0.7", 7001)
        );
        assert_eq!("tcp://[::1]:80".parse::<Endpoint>().unwrap(), Endpoint::tcp("::1", 80));
        // Whitespace from config files is tolerated.
        assert_eq!(" tcp://h:1 ".parse::<Endpoint>().unwrap(), Endpoint::tcp("h", 1));
    }

    #[test]
    fn parse_errors_are_actionable() {
        for (input, needle) in [
            ("tcp://host", "missing `:port`"),
            ("tcp://:7001", "empty host"),
            ("tcp://host:port", "not a number"),
            ("tcp://host:99999", "not a number"),
            ("tcp://[::1", "unterminated"),
            ("tcp://[::1]7001", "missing `:port`"),
            ("unix://", "empty socket path"),
            ("http://x:1", "unknown scheme"),
            ("/tmp/plain.sock", "missing scheme"),
        ] {
            let e = input.parse::<Endpoint>().unwrap_err().to_string();
            assert!(e.contains(needle), "{input:?}: error {e:?} should mention {needle:?}");
            assert!(e.contains("expected"), "{input:?}: error {e:?} should show accepted forms");
        }
    }

    #[test]
    fn transport_kind_parse_and_names() {
        assert_eq!(TransportKind::parse("unix"), Some(TransportKind::Unix));
        assert_eq!(TransportKind::parse("tcp"), Some(TransportKind::Tcp));
        assert_eq!(TransportKind::parse("udp"), None);
        assert_eq!(Endpoint::tcp("h", 1).transport(), TransportKind::Tcp);
        assert_eq!(Endpoint::unix("/x").transport(), TransportKind::Unix);
        assert_eq!(TransportKind::default(), TransportKind::Unix);
    }
}
