//! The shard router: the parent-process half of the cross-process service.
//!
//! A [`ShardRouter`] drives a fleet of `evosort shard-worker` processes —
//! **local** shards it spawns itself (reached over a Unix socket or TCP
//! loopback, the child dialing back) and **remote** shards started
//! externally on other hosts (`shard-worker --listen tcp://…`, the router
//! dialing out) — all speaking the [`protocol`] frame format through the
//! [`transport`](super::transport) seam. Submission mirrors
//! [`SortService`](crate::coordinator::SortService) exactly —
//! [`submit_request`](ShardRouter::submit_request) → `Ticket`,
//! [`submit_batch_requests`](ShardRouter::submit_batch_requests) →
//! `BatchTicket` with unchanged `wait`/`stream` semantics — because the
//! router completes the same `JobSlot`s and feeds the same batch channel
//! the in-process pool does.
//!
//! Traffic hardening, in dispatch order:
//!
//! * **Bounded admission** — the router queue has a capacity
//!   ([`ShardSpec::router_queue_capacity`]); jobs beyond it resolve
//!   `Err(Overloaded)` *at submission* (`shards.shed` counts them) instead
//!   of growing the queue without bound.
//! * **Per-client fairness** — admitted jobs are queued per submitting
//!   client and dispatched round-robin across clients
//!   ([`submit_request_as`](ShardRouter::submit_request_as)), so one hot
//!   tenant's burst cannot starve everyone else; within a client, order is
//!   FIFO. The plain submit methods share client `0`.
//! * **Least-loaded routing** with a bounded per-shard in-flight window:
//!   jobs beyond the window wait in the router queue, which is what makes
//!   them **reroutable** — when a shard dies, only the jobs already on its
//!   socket resolve `Err(WorkerLost)`; everything still queued flows to the
//!   survivors.
//! * **Redial budget** — a dead shard comes back within
//!   [`ShardSpec::max_redials_per_shard`]: local shards are *respawned*
//!   (fresh child process), remote shards are *redialed* with exponential
//!   backoff (the standalone worker re-listens after losing a router).
//!   Either way the shard is re-seeded with the merged tuning cache and
//!   `shards.redials` ticks; past the budget it stays down.
//!
//! Shard cache publications are merged improvement-aware into the router's
//! service-level [`TuningCache`] and re-broadcast, so a fingerprint class
//! tuned on one shard speeds up all shards; telemetry frames aggregate
//! per-shard counters (`tuner.*`, `jobs.*`) into `shard.<i>.*` and
//! `shards.*` gauges.

use std::collections::{HashMap, HashSet, VecDeque};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::autotune::AutotunePolicy;
use crate::coordinator::endpoint::{Endpoint, TransportKind};
use crate::coordinator::metrics::{names, Metrics};
use crate::coordinator::request::SortRequest;
use crate::coordinator::service::{self, fail_reason, BatchTicket};
use crate::coordinator::shard::protocol::{self, Frame};
use crate::coordinator::shard::transport::{Listener, Stream};
use crate::coordinator::ticket::{JobError, JobResult, JobSlot, Ticket};
use crate::coordinator::tuning_cache::TuningCache;
use crate::obs::{EventKind, TraceHub, Tracer, DEFAULT_RING_CAPACITY, ROUTER_SHARD};

/// How long a remote dial (initial or redial) keeps retrying before the
/// shard is declared unreachable for this attempt.
const REMOTE_DIAL_DEADLINE: Duration = Duration::from_secs(8);

/// Configuration for a sharded deployment.
#[derive(Debug, Clone)]
pub struct ShardSpec {
    /// Locally spawned worker processes. With no [`remotes`](Self::remotes),
    /// `<= 1` means "don't shard" — use
    /// [`ShardedService::spawn`](super::ShardedService::spawn), which routes
    /// in-process in that case so the single-process path stays
    /// zero-overhead. May be `0` when remotes carry all the traffic.
    pub shards: usize,
    /// Pool workers inside each shard process.
    pub workers_per_shard: usize,
    /// Threads each sort uses (per shard).
    pub sort_threads: usize,
    /// Each shard's pending-job queue bound.
    pub queue_capacity: usize,
    /// Attach an online autotuner to every shard (the policy is forwarded
    /// on the worker command line; caches sync through the router).
    pub autotune: Option<AutotunePolicy>,
    /// Jobs allowed on a shard's socket at once; `0` derives
    /// `2 × workers_per_shard`. Everything beyond waits in the router queue,
    /// reroutable on shard death.
    pub max_inflight_per_shard: usize,
    /// Redial budget per shard: beyond this many deaths the shard stays
    /// down (a crash-looping worker must not be revived forever). Local
    /// shards are respawned, remote shards redialed — one budget.
    pub max_redials_per_shard: usize,
    /// Shard-side cadence for cache publication / telemetry frames.
    pub publish_interval: Duration,
    /// Kernel execution backend inside every shard (and on the in-process
    /// `shards <= 1` path): the persistent parked executor by default, the
    /// spawn-per-call baseline for A/B runs. Forwarded to worker processes
    /// as `--exec`.
    pub exec: crate::exec::ExecMode,
    /// The `evosort` binary to spawn; defaults to the running executable.
    /// Integration tests pass `env!("CARGO_BIN_EXE_evosort")` (the test
    /// harness binary is not the CLI).
    pub binary: Option<PathBuf>,
    /// Link transport for **local** shards: Unix sockets (default) or TCP
    /// loopback. Remote shards' transports come from their endpoints.
    pub transport: TransportKind,
    /// Listen-address base for local shards, matching `transport`. `None`
    /// derives one: a per-router temp directory of Unix sockets, or
    /// `tcp://127.0.0.1:0` (OS-assigned ports). A TCP base with a non-zero
    /// port assigns `port + shard_index`; a Unix base path gets
    /// `-<shard>-<generation>.sock` appended.
    pub listen: Option<Endpoint>,
    /// Externally started workers to dial (`shard-worker --listen` on other
    /// hosts). These extend the fleet beyond [`shards`](Self::shards); on
    /// death they are redialed (with backoff) rather than respawned.
    pub remotes: Vec<Endpoint>,
    /// Bounded admission: jobs admitted to the router queue at once; `0`
    /// derives `max(256, 8 × in-flight window × fleet size)`. Beyond it,
    /// submissions resolve `Err(Overloaded)` immediately.
    pub router_queue_capacity: usize,
    /// First backoff step when redialing a remote shard (doubles per
    /// attempt, capped at 1s, within an 8s per-death deadline).
    pub redial_backoff: Duration,
    /// End-to-end tracing: the router records span events under
    /// [`ROUTER_SHARD`], workers are spawned with `--trace` and stream
    /// their events back in [`Frame::Trace`] batches, and everything merges
    /// into one fleet-wide timeline keyed by `(shard, trace id)` —
    /// identical over Unix sockets and TCP.
    pub trace: bool,
    /// With [`trace`](Self::trace), also append every event to this
    /// schema-versioned JSONL file (`evosort trace <file>` renders it).
    pub trace_log: Option<PathBuf>,
}

impl Default for ShardSpec {
    fn default() -> Self {
        ShardSpec {
            shards: 2,
            workers_per_shard: 2,
            sort_threads: crate::util::default_threads().div_ceil(2).max(1),
            queue_capacity: 64,
            autotune: None,
            max_inflight_per_shard: 0,
            max_redials_per_shard: 5,
            publish_interval: Duration::from_millis(200),
            exec: crate::exec::ExecMode::Parked,
            binary: None,
            transport: TransportKind::Unix,
            listen: None,
            remotes: Vec::new(),
            router_queue_capacity: 0,
            redial_backoff: Duration::from_millis(50),
            trace: false,
            trace_log: None,
        }
    }
}

/// How a resolved job reaches its caller — the same two delivery contracts
/// the in-process service uses.
enum Completer {
    Slot(Arc<JobSlot>),
    Batch {
        tx: mpsc::Sender<(usize, JobResult)>,
        idx: usize,
        hits: Arc<AtomicU64>,
        misses: Arc<AtomicU64>,
    },
}

/// A job waiting in the router queue (reroutable until dispatched).
struct RoutedJob {
    id: u64,
    client: u64,
    req: SortRequest,
    completer: Completer,
}

/// Admitted jobs, queued per client and dequeued round-robin across
/// clients (FIFO within a client). The `rr` rotation holds exactly the
/// clients with non-empty queues, each once.
#[derive(Default)]
struct ClientQueues {
    queues: HashMap<u64, VecDeque<RoutedJob>>,
    rr: VecDeque<u64>,
    len: usize,
}

impl ClientQueues {
    fn len(&self) -> usize {
        self.len
    }

    fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn push(&mut self, job: RoutedJob) {
        let q = self.queues.entry(job.client).or_default();
        if q.is_empty() {
            self.rr.push_back(job.client);
        }
        q.push_back(job);
        self.len += 1;
    }

    /// Reclaim a job at the head of its client's queue *and* the head of
    /// the rotation (a dispatch that failed to write must retry first, not
    /// wait a full round).
    fn push_front(&mut self, job: RoutedJob) {
        let q = self.queues.entry(job.client).or_default();
        if q.is_empty() {
            self.rr.push_front(job.client);
        } else {
            // Move an already-rotated client to the front.
            self.rr.retain(|c| *c != job.client);
            self.rr.push_front(job.client);
        }
        q.push_front(job);
        self.len += 1;
    }

    /// Next job in round-robin order; the dequeued client rotates to the
    /// back if it still has queued work.
    fn pop(&mut self) -> Option<RoutedJob> {
        let client = self.rr.pop_front()?;
        let Some(q) = self.queues.get_mut(&client) else { return None };
        let job = q.pop_front()?;
        if q.is_empty() {
            self.queues.remove(&client);
        } else {
            self.rr.push_back(client);
        }
        self.len -= 1;
        Some(job)
    }

    fn drain_all(&mut self) -> Vec<RoutedJob> {
        self.rr.clear();
        self.len = 0;
        self.queues.drain().flat_map(|(_, q)| q).collect()
    }
}

/// How shard `idx` comes (back) up: spawned locally or dialed remotely.
#[derive(Debug, Clone)]
enum ShardOrigin {
    Local,
    Remote(Endpoint),
}

struct ShardConn {
    /// The spawned child for local shards; `None` for remote shards (their
    /// process lifecycle is external — force-drop is a socket shutdown).
    child: Option<Child>,
    writer: Arc<Mutex<Stream>>,
}

struct ShardState {
    alive: bool,
    /// Incarnation counter: readers of a dead incarnation must not touch
    /// the state its redial installed.
    generation: u64,
    redials: usize,
    /// Router job ids currently on this shard's socket.
    inflight: HashSet<u64>,
    conn: Option<ShardConn>,
}

struct RouterState {
    queue: ClientQueues,
    /// Dispatched-but-unresolved jobs (completion routes through here).
    pending: HashMap<u64, Completer>,
    shards: Vec<ShardState>,
    /// Latest telemetry snapshot per shard.
    telemetry: Vec<HashMap<String, u64>>,
}

struct RouterInner {
    spec: ShardSpec,
    /// One entry per fleet slot: local slots first, then remotes.
    origins: Vec<ShardOrigin>,
    max_inflight: usize,
    /// Bounded-admission capacity (resolved from the spec).
    admit_capacity: usize,
    socket_dir: PathBuf,
    state: Mutex<RouterState>,
    /// Dispatcher wake-ups: new work, freed capacity, shard (re)spawned.
    work_ready: Condvar,
    /// Drain wake-ups: queue + pending went empty.
    idle: Condvar,
    metrics: Arc<Metrics>,
    cache: Arc<TuningCache>,
    /// The router's own span events (shard id [`ROUTER_SHARD`]); disabled
    /// unless [`ShardSpec::trace`] asked for tracing.
    tracer: Tracer,
    /// Fleet-wide timeline + JSONL sink; `Some` iff tracing is on. Worker
    /// [`Frame::Trace`] batches are ingested here by the reader threads.
    trace_hub: Option<TraceHub>,
    next_id: AtomicU64,
    shutdown: AtomicBool,
    reader_handles: Mutex<Vec<JoinHandle<()>>>,
}

/// Handle to the sharded deployment; dropping it shuts local children down
/// and detaches remote workers (they go back to listening).
pub struct ShardRouter {
    inner: Arc<RouterInner>,
    dispatcher: Option<JoinHandle<()>>,
}

static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

impl ShardRouter {
    /// Spawn `spec.shards` local worker processes, dial every
    /// `spec.remotes` endpoint, and start routing. Fails if any local
    /// worker cannot be spawned (or does not connect back within 10
    /// seconds), or any remote endpoint cannot be dialed within the
    /// backoff deadline — start remote workers before the router.
    pub fn spawn(spec: ShardSpec) -> Result<ShardRouter> {
        let fleet = spec.shards + spec.remotes.len();
        anyhow::ensure!(
            fleet >= 1,
            "a sharded service needs at least one shard (local or remote)"
        );
        if let Some(ep) = &spec.listen {
            anyhow::ensure!(
                ep.transport() == spec.transport,
                "listen endpoint {ep} does not match transport {}",
                spec.transport
            );
        }
        let origins: Vec<ShardOrigin> = (0..spec.shards)
            .map(|_| ShardOrigin::Local)
            .chain(spec.remotes.iter().cloned().map(ShardOrigin::Remote))
            .collect();
        let socket_dir = std::env::temp_dir().join(format!(
            "evosort-shards-{}-{}",
            std::process::id(),
            DIR_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&socket_dir)
            .with_context(|| format!("creating {}", socket_dir.display()))?;
        let max_inflight = if spec.max_inflight_per_shard == 0 {
            (spec.workers_per_shard * 2).max(1)
        } else {
            spec.max_inflight_per_shard
        };
        let admit_capacity = if spec.router_queue_capacity == 0 {
            (max_inflight * fleet * 8).max(256)
        } else {
            spec.router_queue_capacity
        };
        let metrics = Arc::new(Metrics::new());
        let tracer = if spec.trace {
            Tracer::enabled(DEFAULT_RING_CAPACITY, ROUTER_SHARD)
        } else {
            Tracer::disabled()
        };
        let trace_hub = if spec.trace {
            Some(
                TraceHub::new(tracer.clone(), spec.trace_log.as_deref(), Some(Arc::clone(&metrics)))
                    .context("starting the trace hub")?,
            )
        } else {
            None
        };
        let inner = Arc::new(RouterInner {
            spec,
            origins,
            max_inflight,
            admit_capacity,
            socket_dir,
            state: Mutex::new(RouterState {
                queue: ClientQueues::default(),
                pending: HashMap::new(),
                shards: (0..fleet)
                    .map(|_| ShardState {
                        alive: false,
                        generation: 0,
                        redials: 0,
                        inflight: HashSet::new(),
                        conn: None,
                    })
                    .collect(),
                telemetry: vec![HashMap::new(); fleet],
            }),
            work_ready: Condvar::new(),
            idle: Condvar::new(),
            metrics,
            cache: Arc::new(TuningCache::new()),
            tracer,
            trace_hub,
            next_id: AtomicU64::new(1),
            shutdown: AtomicBool::new(false),
            reader_handles: Mutex::new(Vec::new()),
        });
        for idx in 0..fleet {
            if let Err(e) = RouterInner::bring_up_shard(&inner, idx) {
                // Partial start-up: tear down the shards that did come up
                // (no Drop will run — the router was never constructed), so
                // a caller retrying spawn cannot accumulate orphans.
                inner.shutdown.store(true, Ordering::SeqCst);
                {
                    let mut st = inner.state.lock().unwrap();
                    for sh in st.shards.iter_mut() {
                        if let Some(conn) = sh.conn.as_mut() {
                            match conn.child.as_mut() {
                                Some(child) => {
                                    let _ = child.kill();
                                }
                                None => {
                                    let w = conn
                                        .writer
                                        .lock()
                                        .unwrap_or_else(|e| e.into_inner());
                                    let _ = w.shutdown();
                                }
                            }
                        }
                    }
                }
                let readers = std::mem::take(&mut *inner.reader_handles.lock().unwrap());
                for r in readers {
                    let _ = r.join(); // EOF after the teardown; on_shard_down reaps
                }
                let _ = std::fs::remove_dir_all(&inner.socket_dir);
                return Err(e).with_context(|| format!("bringing up shard {idx}"));
            }
        }
        let dispatcher = {
            let inner = Arc::clone(&inner);
            std::thread::Builder::new()
                .name("evosort-shard-router".into())
                .spawn(move || RouterInner::dispatcher_loop(&inner))
                .expect("spawn router dispatcher")
        };
        Ok(ShardRouter { inner, dispatcher: Some(dispatcher) })
    }

    /// Fleet size: local worker processes plus remote endpoints.
    pub fn shards(&self) -> usize {
        self.inner.origins.len()
    }

    /// Service-level metrics: per-job accounting mirrored from shard
    /// replies, `shard.<i>.*` / `shards.*` telemetry aggregation, routing,
    /// admission (`shards.shed`), recovery (`shards.redials`) and
    /// cache-broadcast counters.
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.inner.metrics
    }

    /// The merged service-level tuning cache (improvement-aware union of
    /// every shard's publications).
    pub fn cache(&self) -> &Arc<TuningCache> {
        &self.inner.cache
    }

    /// The fleet-wide trace timeline (`Some` iff [`ShardSpec::trace`]).
    pub fn trace_hub(&self) -> Option<&TraceHub> {
        self.inner.trace_hub.as_ref()
    }

    /// Submit one request; the returned [`Ticket`] behaves exactly as the
    /// in-process service's (poll / park / cancel-before-dispatch; a dead
    /// shard resolves it to `Err(WorkerLost)` instead of hanging; a
    /// saturated router resolves it to `Err(Overloaded)` immediately).
    pub fn submit_request(&self, req: SortRequest) -> Ticket {
        self.submit_request_as(0, req)
    }

    /// [`submit_request`](Self::submit_request) on behalf of `client`.
    /// Clients are fairness domains: dispatch round-robins across clients
    /// with queued work, so one client's burst cannot starve another's
    /// jobs. Client ids are caller-assigned (tenant id, connection id, …).
    pub fn submit_request_as(&self, client: u64, req: SortRequest) -> Ticket {
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        self.inner.metrics.incr(names::JOBS_SUBMITTED);
        // The router traces every job under its router-level id — the same
        // id the worker stamps on its own events, so the two streams merge
        // into one trace.
        self.inner.tracer.emit(id, EventKind::Submitted);
        let slot = JobSlot::pending();
        self.inner.enqueue(RoutedJob {
            id,
            client,
            req,
            completer: Completer::Slot(Arc::clone(&slot)),
        });
        Ticket::new(id, slot)
    }

    /// Submit a batch; the returned [`BatchTicket`] barriers or streams in
    /// submission order exactly as the in-process path does.
    pub fn submit_batch_requests(&self, requests: Vec<SortRequest>) -> BatchTicket {
        self.submit_batch_requests_as(0, requests)
    }

    /// [`submit_batch_requests`](Self::submit_batch_requests) on behalf of
    /// `client` (see [`submit_request_as`](Self::submit_request_as)). Jobs
    /// beyond the admission capacity resolve `Err(Overloaded)` in the
    /// batch's stream/report; the rest are queued normally.
    pub fn submit_batch_requests_as(
        &self,
        client: u64,
        requests: Vec<SortRequest>,
    ) -> BatchTicket {
        let started = Instant::now();
        let total = requests.len();
        let (tx, rx) = mpsc::channel();
        let metrics = Arc::clone(&self.inner.metrics);
        metrics.add(names::JOBS_SUBMITTED, total as u64);
        metrics.add(names::BATCH_JOBS_SUBMITTED, total as u64);
        metrics.incr(names::BATCH_SUBMITTED);
        let hits = Arc::new(AtomicU64::new(0));
        let misses = Arc::new(AtomicU64::new(0));
        let shutting_down = self.inner.shutdown.load(Ordering::SeqCst);
        let mut rejected: Vec<(u64, Completer)> = Vec::new();
        {
            let mut st = self.inner.state.lock().unwrap();
            for (idx, req) in requests.into_iter().enumerate() {
                let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
                self.inner.tracer.emit(id, EventKind::Submitted);
                let completer = Completer::Batch {
                    tx: tx.clone(),
                    idx,
                    hits: Arc::clone(&hits),
                    misses: Arc::clone(&misses),
                };
                if shutting_down {
                    rejected.push((id, completer));
                } else if st.queue.len() >= self.inner.admit_capacity {
                    self.inner.metrics.incr(names::SHARDS_SHED);
                    rejected.push((id, completer));
                } else {
                    self.inner.tracer.emit(id, EventKind::Queued);
                    st.queue.push(RoutedJob { id, client, req, completer });
                }
            }
            self.inner.metrics.set_gauge(names::ROUTER_QUEUE_DEPTH, st.queue.len() as f64);
        }
        for (id, completer) in rejected {
            let err = if shutting_down { JobError::WorkerLost } else { JobError::Overloaded };
            self.inner.tracer.emit(id, EventKind::Failed { reason: fail_reason(&err) });
            self.inner.complete(completer, Err(err), protocol::CACHE_FLAG_NONE);
        }
        self.inner.work_ready.notify_all();
        BatchTicket::from_parts(total, started, rx, metrics, hits, misses)
    }

    /// Park until nothing is queued or in flight (bounded): the sharded
    /// analog of [`SortService::drain_timeout`].
    ///
    /// [`SortService::drain_timeout`]: crate::coordinator::SortService::drain_timeout
    pub fn drain_timeout(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut st = self.inner.state.lock().unwrap();
        while !(st.queue.is_empty() && st.pending.is_empty()) {
            let Some(remaining) = deadline.checked_duration_since(Instant::now()) else {
                return false;
            };
            let (next, _) = self.inner.idle.wait_timeout(st, remaining).unwrap();
            st = next;
        }
        true
    }

    /// Jobs currently on shard `idx`'s socket (diagnostics / tests).
    pub fn inflight(&self, idx: usize) -> usize {
        let st = self.inner.state.lock().unwrap();
        st.shards.get(idx).map(|s| s.inflight.len()).unwrap_or(0)
    }

    /// OS pid of each live **local** shard worker (`None` while a shard is
    /// down, and always `None` for remote shards — their pids belong to
    /// other hosts).
    pub fn shard_pids(&self) -> Vec<Option<u32>> {
        let st = self.inner.state.lock().unwrap();
        st.shards
            .iter()
            .map(|s| s.conn.as_ref().and_then(|c| c.child.as_ref()).map(|c| c.id()))
            .collect()
    }

    /// Chaos helper: force-drop shard `idx` — SIGKILL for a local worker
    /// process, a socket shutdown for a remote one. In-flight jobs on it
    /// resolve `Err(WorkerLost)`; the router revives it (budget permitting)
    /// and reroutes queued work meanwhile. Failover tests use this;
    /// production deaths take the same path.
    pub fn kill_shard(&self, idx: usize) -> bool {
        let mut st = self.inner.state.lock().unwrap();
        match st.shards.get_mut(idx).and_then(|s| s.conn.as_mut()) {
            Some(conn) => match conn.child.as_mut() {
                Some(child) => child.kill().is_ok(),
                None => {
                    let w = conn.writer.lock().unwrap_or_else(|e| e.into_inner());
                    w.shutdown().is_ok()
                }
            },
            None => false,
        }
    }
}

impl Drop for ShardRouter {
    fn drop(&mut self) {
        let inner = &self.inner;
        inner.shutdown.store(true, Ordering::SeqCst);
        inner.work_ready.notify_all();
        // Resolve everything unfinished so no caller can hang on a ticket.
        let (queued, pending) = {
            let mut st = inner.state.lock().unwrap();
            let queued: Vec<RoutedJob> = st.queue.drain_all();
            let pending: Vec<(u64, Completer)> = st.pending.drain().collect();
            (queued, pending)
        };
        for job in queued {
            inner.fail_job(job.id, job.completer);
        }
        for (id, completer) in pending {
            inner.fail_job(id, completer);
        }
        inner.idle.notify_all();
        // Ask every live local shard to exit; *detach* remote shards with a
        // socket shutdown instead — their processes are externally managed
        // and go back to listening for the next router.
        let conns: Vec<(Arc<Mutex<Stream>>, bool)> = {
            let st = inner.state.lock().unwrap();
            st.shards
                .iter()
                .filter_map(|s| {
                    s.conn.as_ref().map(|c| (Arc::clone(&c.writer), c.child.is_some()))
                })
                .collect()
        };
        let shutdown_frame = protocol::encode_shutdown();
        for (w, is_local) in conns {
            let mut w = w.lock().unwrap_or_else(|e| e.into_inner());
            if is_local {
                let _ = protocol::write_frame(&mut *w, &shutdown_frame);
            } else {
                let _ = w.shutdown();
            }
        }
        // …give them a bounded grace period, then hard-kill stragglers. The
        // reader threads reap each child as its connection closes.
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let all_down =
                { inner.state.lock().unwrap().shards.iter().all(|s| s.conn.is_none()) };
            if all_down || Instant::now() > deadline {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        {
            let mut st = inner.state.lock().unwrap();
            for sh in st.shards.iter_mut() {
                if let Some(conn) = sh.conn.as_mut() {
                    match conn.child.as_mut() {
                        Some(child) => {
                            let _ = child.kill();
                        }
                        None => {
                            let w = conn.writer.lock().unwrap_or_else(|e| e.into_inner());
                            let _ = w.shutdown();
                        }
                    }
                }
            }
        }
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        let readers = std::mem::take(&mut *inner.reader_handles.lock().unwrap());
        for r in readers {
            let _ = r.join();
        }
        let _ = std::fs::remove_dir_all(&inner.socket_dir);
    }
}

impl RouterInner {
    /// Bring shard `idx` (back) up — spawn-and-accept for local shards,
    /// dial-with-backoff for remote ones — then seed it with the merged
    /// cache and start its reader thread.
    fn bring_up_shard(inner: &Arc<RouterInner>, idx: usize) -> Result<()> {
        let generation = inner.state.lock().unwrap().shards[idx].generation + 1;
        let (stream, child) = match &inner.origins[idx] {
            ShardOrigin::Local => {
                let (stream, child) = inner.spawn_local_worker(idx, generation)?;
                (stream, Some(child))
            }
            ShardOrigin::Remote(endpoint) => (inner.dial_remote(idx, endpoint)?, None),
        };
        let writer = Arc::new(Mutex::new(stream.try_clone().context("cloning shard stream")?));
        // Re-seed a revived shard with everything the fleet has learned.
        if !inner.cache.is_empty() {
            let bytes = protocol::encode_cache_sync(&inner.cache.to_text());
            let mut w = writer.lock().unwrap_or_else(|e| e.into_inner());
            let _ = protocol::write_frame(&mut *w, &bytes);
        }
        {
            let mut st = inner.state.lock().unwrap();
            let sh = &mut st.shards[idx];
            sh.alive = true;
            sh.generation = generation;
            sh.inflight.clear();
            sh.conn = Some(ShardConn { child, writer });
        }
        let reader_inner = Arc::clone(inner);
        let handle = std::thread::Builder::new()
            .name(format!("evosort-router-read{idx}"))
            .spawn(move || {
                let mut stream = stream;
                while let Ok(frame) = protocol::read_frame(&mut stream) {
                    reader_inner.on_frame(idx, frame);
                }
                RouterInner::on_shard_down(&reader_inner, idx, generation);
            })
            .expect("spawn router reader");
        inner.reader_handles.lock().unwrap().push(handle);
        // A shutdown that raced with this revival: stop the fresh shard
        // immediately so the Drop-side reader join cannot hang on one that
        // never got the broadcast Shutdown/detach.
        if inner.shutdown.load(Ordering::SeqCst) {
            let st = inner.state.lock().unwrap();
            if let Some(conn) = st.shards[idx].conn.as_ref() {
                let mut w = conn.writer.lock().unwrap_or_else(|e| e.into_inner());
                if conn.child.is_some() {
                    let _ = protocol::write_frame(&mut *w, &protocol::encode_shutdown());
                } else {
                    let _ = w.shutdown();
                }
            }
        }
        inner.work_ready.notify_all();
        Ok(())
    }

    /// The listen address for local shard `idx`, incarnation `generation`.
    fn local_listen_endpoint(&self, idx: usize, generation: u64) -> Result<Endpoint> {
        match (&self.spec.listen, self.spec.transport) {
            (None, TransportKind::Unix) => Ok(Endpoint::Unix(
                self.socket_dir.join(format!("shard-{idx}-{generation}.sock")),
            )),
            (None, TransportKind::Tcp) => Ok(Endpoint::tcp("127.0.0.1", 0)),
            (Some(Endpoint::Unix(base)), _) => Ok(Endpoint::Unix(PathBuf::from(format!(
                "{}-{idx}-{generation}.sock",
                base.display()
            )))),
            (Some(Endpoint::Tcp { host, port }), _) => {
                let port = if *port == 0 {
                    0
                } else {
                    port.checked_add(idx as u16)
                        .with_context(|| format!("listen port {port} + shard {idx} overflows"))?
                };
                Ok(Endpoint::tcp(host.clone(), port))
            }
        }
    }

    /// Spawn local shard `idx`: bind a fresh listener, launch the worker
    /// process pointed back at it (`--connect <resolved endpoint>`), wait
    /// for it to connect.
    fn spawn_local_worker(&self, idx: usize, generation: u64) -> Result<(Stream, Child)> {
        let listen = self.local_listen_endpoint(idx, generation)?;
        let listener = Listener::bind(&listen)?;
        listener.set_nonblocking(true).context("non-blocking listener")?;
        // For tcp://…:0 the OS picked the port; the child dials this.
        let resolved = listener.local_endpoint()?;
        let binary = match &self.spec.binary {
            Some(p) => p.clone(),
            None => std::env::current_exe().context("locating the evosort binary")?,
        };
        let mut cmd = Command::new(&binary);
        cmd.arg("shard-worker")
            .arg("--connect")
            .arg(resolved.to_string())
            .arg("--shard-id")
            .arg(idx.to_string())
            .arg("--workers")
            .arg(self.spec.workers_per_shard.to_string())
            .arg("--sort-threads")
            .arg(self.spec.sort_threads.to_string())
            .arg("--queue-capacity")
            .arg(self.spec.queue_capacity.to_string())
            .arg("--publish-ms")
            .arg(self.spec.publish_interval.as_millis().to_string())
            .arg("--exec")
            .arg(self.spec.exec.name())
            .stdin(Stdio::null());
        if self.spec.trace {
            cmd.arg("--trace");
        }
        if let Some(policy) = &self.spec.autotune {
            cmd.arg("--min-obs")
                .arg(policy.min_observations.to_string())
                .arg("--cooldown")
                .arg(policy.cooldown_observations.to_string())
                .arg("--sample-cap")
                .arg(policy.retained_sample_cap.to_string())
                .arg("--tuner-generations")
                .arg(policy.generations_per_cycle.to_string())
                .arg("--tuner-population")
                .arg(policy.population.to_string())
                .arg("--cpu-share")
                .arg(policy.max_cpu_share.to_string())
                .arg("--min-improvement")
                .arg(policy.min_improvement_pct.to_string())
                .arg("--sample-every")
                .arg(policy.sample_every.to_string())
                .arg("--autotune");
        }
        let mut child =
            cmd.spawn().with_context(|| format!("spawning {}", binary.display()))?;
        let deadline = Instant::now() + Duration::from_secs(10);
        let stream = loop {
            match listener.accept() {
                Ok(stream) => break stream,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if let Ok(Some(status)) = child.try_wait() {
                        bail!("shard {idx} worker exited before connecting: {status}");
                    }
                    if Instant::now() > deadline {
                        let _ = child.kill();
                        bail!("shard {idx} worker did not connect within 10s");
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => {
                    let _ = child.kill();
                    return Err(e).context("accepting shard connection");
                }
            }
        };
        stream.set_nonblocking(false).context("blocking shard stream")?;
        if let Some(path) = listener.cleanup_path() {
            let _ = std::fs::remove_file(path);
        }
        Ok((stream, child))
    }

    /// Dial remote shard `idx` with exponential backoff — the redial half
    /// of the recovery contract (the standalone worker re-listens after
    /// losing its router).
    fn dial_remote(&self, idx: usize, endpoint: &Endpoint) -> Result<Stream> {
        let deadline = Instant::now() + REMOTE_DIAL_DEADLINE;
        let mut delay = self.spec.redial_backoff.max(Duration::from_millis(1));
        loop {
            if self.shutdown.load(Ordering::SeqCst) {
                bail!("router shutting down while dialing shard {idx}");
            }
            match Stream::connect(endpoint) {
                Ok(stream) => return Ok(stream),
                Err(e) => {
                    if Instant::now() + delay > deadline {
                        return Err(e).with_context(|| {
                            format!("dialing remote shard {idx} at {endpoint}")
                        });
                    }
                    std::thread::sleep(delay);
                    delay = (delay * 2).min(Duration::from_secs(1));
                }
            }
        }
    }

    /// Admit one job or shed it (`Err(Overloaded)`) if the queue is full.
    fn enqueue(&self, job: RoutedJob) {
        if self.shutdown.load(Ordering::SeqCst) {
            self.fail_job(job.id, job.completer);
            return;
        }
        let rejected = {
            let mut st = self.state.lock().unwrap();
            if st.queue.len() >= self.admit_capacity {
                Some(job)
            } else {
                self.tracer.emit(job.id, EventKind::Queued);
                st.queue.push(job);
                self.metrics.set_gauge(names::ROUTER_QUEUE_DEPTH, st.queue.len() as f64);
                None
            }
        };
        match rejected {
            Some(job) => {
                self.metrics.incr(names::SHARDS_SHED);
                crate::log_debug!(
                    "router queue saturated ({} jobs); shedding job {}",
                    self.admit_capacity,
                    job.id
                );
                self.tracer
                    .emit(job.id, EventKind::Failed { reason: fail_reason(&JobError::Overloaded) });
                self.complete(job.completer, Err(JobError::Overloaded), protocol::CACHE_FLAG_NONE);
            }
            None => self.work_ready.notify_all(),
        }
    }

    /// The routing loop: pick the least-loaded live shard with window
    /// capacity, take the next job in client round-robin order, move it
    /// from the queue to `pending`, write the frame.
    fn dispatcher_loop(inner: &Arc<RouterInner>) {
        loop {
            let (id, client, req, idx, writer) = {
                let mut st = inner.state.lock().unwrap();
                loop {
                    if inner.shutdown.load(Ordering::SeqCst) {
                        return; // Drop resolves whatever is left
                    }
                    if !st.queue.is_empty() {
                        if let Some(idx) = pick_shard(&st, inner.max_inflight) {
                            let RoutedJob { id, client, req, completer } =
                                st.queue.pop().unwrap();
                            // Honour a cancel that landed while the job was
                            // queued — the same dequeue-time check the
                            // in-process worker makes, preserving the
                            // `cancel() == true ⇒ Err(Cancelled)` guarantee.
                            if let Completer::Slot(slot) = &completer {
                                if slot.start() {
                                    inner.tracer.emit(
                                        id,
                                        EventKind::Failed {
                                            reason: fail_reason(&JobError::Cancelled),
                                        },
                                    );
                                    slot.complete(Err(JobError::Cancelled));
                                    if st.queue.is_empty() && st.pending.is_empty() {
                                        inner.idle.notify_all();
                                    }
                                    continue;
                                }
                            }
                            st.pending.insert(id, completer);
                            st.shards[idx].inflight.insert(id);
                            inner.metrics.set_gauge(
                                names::ROUTER_QUEUE_DEPTH,
                                st.queue.len() as f64,
                            );
                            let conn = st.shards[idx].conn.as_ref().expect("picked shard is live");
                            break (id, client, req, idx, Arc::clone(&conn.writer));
                        }
                        // Fail the queue only when every shard is down for
                        // good (budget spent or permanently unrevivable).
                        // Transiently-dead shards revive within seconds —
                        // queued jobs must survive that window: rerouting
                        // them is the whole point of the router queue.
                        let all_permanently_down = st.shards.iter().all(|s| {
                            !s.alive && s.redials >= inner.spec.max_redials_per_shard
                        });
                        if all_permanently_down {
                            let dead: Vec<RoutedJob> = st.queue.drain_all();
                            let idle_now = st.pending.is_empty();
                            drop(st);
                            for job in dead {
                                inner.fail_job(job.id, job.completer);
                            }
                            if idle_now {
                                inner.idle.notify_all();
                            }
                            st = inner.state.lock().unwrap();
                            continue;
                        }
                    }
                    st = inner.work_ready.wait(st).unwrap();
                }
            };
            let bytes = protocol::encode_job(id, &req);
            if bytes.len() as u64 > protocol::MAX_JOB_FRAME_BYTES {
                // An oversized job would be rejected by every shard's
                // receive-side frame bound and, routed job-at-a-time, would
                // serially exhaust the whole fleet's redial budget. Fail
                // its own ticket instead.
                let (completer, idle_now) = {
                    let mut st = inner.state.lock().unwrap();
                    st.shards[idx].inflight.remove(&id);
                    let completer = st.pending.remove(&id);
                    (completer, st.pending.is_empty() && st.queue.is_empty())
                };
                inner.metrics.incr(names::SHARD_JOBS_OVERSIZED);
                crate::log_error!(
                    "job {id} ({} bytes) exceeds the shard frame bound; failing it",
                    bytes.len()
                );
                if let Some(completer) = completer {
                    inner.fail_job(id, completer);
                }
                if idle_now {
                    inner.idle.notify_all();
                }
                continue;
            }
            let sent = {
                let mut w = writer.lock().unwrap_or_else(|e| e.into_inner());
                protocol::write_frame(&mut *w, &bytes).is_ok()
            };
            if sent {
                inner.tracer.emit(id, EventKind::Dispatched { shard: idx as u32 });
                inner.metrics.incr(&names::shard_jobs_routed(idx));
                inner.metrics.incr(&names::client_dispatched(client));
            } else {
                // The shard died between pick and write. Its reader thread
                // handles the death; reclaim the job for rerouting unless
                // that handler already failed it.
                let mut st = inner.state.lock().unwrap();
                if let Some(completer) = st.pending.remove(&id) {
                    st.shards[idx].inflight.remove(&id);
                    st.queue.push_front(RoutedJob { id, client, req, completer });
                }
            }
        }
    }

    fn on_frame(&self, idx: usize, frame: Frame) {
        match frame {
            Frame::JobDone { id, cache_flag, result } => {
                self.on_job_done(idx, id, cache_flag, result)
            }
            Frame::CachePublish { text } => self.on_cache_publish(idx, &text),
            Frame::Telemetry { counters } => self.on_telemetry(idx, counters),
            Frame::Trace { events } => {
                // Worker-side span events stream into the fleet timeline;
                // without a hub (tracing off but a worker sent them anyway)
                // they are dropped.
                if let Some(hub) = &self.trace_hub {
                    hub.ingest(&events);
                }
            }
            _ => {} // frames for the other direction: ignore
        }
    }

    fn on_job_done(&self, idx: usize, id: u64, cache_flag: u8, result: JobResult) {
        let completer = {
            let mut st = self.state.lock().unwrap();
            if let Some(sh) = st.shards.get_mut(idx) {
                sh.inflight.remove(&id);
            }
            let completer = st.pending.remove(&id);
            if completer.is_some() && st.pending.is_empty() && st.queue.is_empty() {
                self.idle.notify_all();
            }
            completer
        };
        // Capacity freed: wake the dispatcher.
        self.work_ready.notify_all();
        let Some(completer) = completer else {
            return; // late reply for a job the death handler already failed
        };
        match &result {
            Ok(out) => self.tracer.emit(id, EventKind::Completed { secs: out.secs }),
            Err(e) => self.tracer.emit(id, EventKind::Failed { reason: fail_reason(e) }),
        }
        // Mirror the in-process service's per-job accounting at the
        // service level (each shard also keeps its own local metrics).
        match &result {
            Ok(out) => {
                self.metrics.incr(names::JOBS_COMPLETED);
                self.metrics.incr(service::dtype_counter(out.dtype()));
                self.metrics.observe(names::SORT_LATENCY, out.secs);
                self.metrics.add(names::ELEMENTS_SORTED, out.len() as u64);
                if !out.valid {
                    self.metrics.incr(names::JOBS_INVALID);
                }
                self.metrics.incr(&names::shard_jobs_completed(idx));
                match cache_flag {
                    protocol::CACHE_FLAG_HIT => self.metrics.incr(names::PARAMS_CACHE_HIT),
                    protocol::CACHE_FLAG_MISS => self.metrics.incr(names::PARAMS_CACHE_MISS),
                    _ => self.metrics.incr(names::PARAMS_OVERRIDE),
                }
            }
            Err(_) => self.metrics.incr(names::SHARD_JOBS_LOST),
        }
        self.complete(completer, result, cache_flag);
    }

    /// A shard's cache changed: merge it (improvement-aware — a worse
    /// incoming entry cannot clobber a better one) and, if the merge
    /// actually changed the service-level cache, broadcast the union back
    /// to every live shard.
    fn on_cache_publish(&self, idx: usize, text: &str) {
        self.metrics.incr(names::SHARD_CACHE_PUBLISHES);
        let absorbed = self.cache.absorb(&TuningCache::from_text(text));
        if absorbed == 0 {
            return;
        }
        self.metrics.add(names::SHARD_CACHE_ENTRIES_ABSORBED, absorbed as u64);
        self.metrics.set_gauge(names::SHARD_CACHE_ENTRIES, self.cache.len() as f64);
        crate::log_debug!("router: absorbed {absorbed} cache entries from shard {idx}");
        let bytes = protocol::encode_cache_sync(&self.cache.to_text());
        let writers: Vec<Arc<Mutex<Stream>>> = {
            let st = self.state.lock().unwrap();
            st.shards
                .iter()
                .filter(|s| s.alive)
                .filter_map(|s| s.conn.as_ref().map(|c| Arc::clone(&c.writer)))
                .collect()
        };
        for w in writers {
            let mut w = w.lock().unwrap_or_else(|e| e.into_inner());
            let _ = protocol::write_frame(&mut *w, &bytes);
        }
        self.metrics.incr(names::SHARD_CACHE_BROADCASTS);
    }

    /// Fold one shard's counter snapshot into per-shard and fleet gauges.
    fn on_telemetry(&self, idx: usize, counters: Vec<(String, u64)>) {
        let (this, totals) = {
            let mut st = self.state.lock().unwrap();
            st.telemetry[idx] = counters.into_iter().collect();
            let mut totals: HashMap<String, u64> = HashMap::new();
            for shard in &st.telemetry {
                for (name, value) in shard {
                    *totals.entry(name.clone()).or_default() += value;
                }
            }
            let this: Vec<(String, u64)> =
                st.telemetry[idx].iter().map(|(k, v)| (k.clone(), *v)).collect();
            (this, totals)
        };
        // The `local` segment separates these process-local mirrors (which
        // reset when a shard revives) from the router's own lifetime
        // counters — `shard.0.jobs.completed` (counter, router-lifetime)
        // and `shard.0.local.jobs.completed` (gauge, child-process view)
        // must not share a name.
        for (name, value) in this {
            self.metrics.set_gauge(&names::shard_local(idx, &name), value as f64);
        }
        for (name, value) in totals {
            self.metrics.set_gauge(&names::shards_total(&name), value as f64);
        }
    }

    /// A shard's connection closed. Fail its in-flight jobs (`WorkerLost` —
    /// the payloads left with the frames, so they cannot be rerouted),
    /// reap the child (local) or drop the socket (remote), and revive
    /// within the redial budget. Queued jobs are untouched: the dispatcher
    /// reroutes them to the survivors.
    fn on_shard_down(inner: &Arc<RouterInner>, idx: usize, generation: u64) {
        let shutting_down = inner.shutdown.load(Ordering::SeqCst);
        let mut lost: Vec<(u64, Completer)> = Vec::new();
        let mut revive = false;
        {
            let mut st = inner.state.lock().unwrap();
            if st.shards[idx].generation != generation {
                return; // a reader from a previous incarnation
            }
            let sh = &mut st.shards[idx];
            sh.alive = false;
            if let Some(mut conn) = sh.conn.take() {
                match conn.child.as_mut() {
                    Some(child) => {
                        let _ = child.kill();
                        let _ = child.wait(); // reap — no zombies
                    }
                    None => {
                        let w = conn.writer.lock().unwrap_or_else(|e| e.into_inner());
                        let _ = w.shutdown();
                    }
                }
            }
            let ids: Vec<u64> = sh.inflight.drain().collect();
            for id in &ids {
                if let Some(completer) = st.pending.remove(id) {
                    lost.push((*id, completer));
                }
            }
            if !shutting_down && st.shards[idx].redials < inner.spec.max_redials_per_shard {
                st.shards[idx].redials += 1;
                revive = true;
            }
            if st.pending.is_empty() && st.queue.is_empty() {
                inner.idle.notify_all();
            }
        }
        for (id, completer) in lost {
            inner.fail_job(id, completer);
        }
        if !shutting_down {
            inner.metrics.incr(names::SHARD_DEATHS);
            if revive {
                match RouterInner::bring_up_shard(inner, idx) {
                    Ok(()) => {
                        // One budget, one counter, both origins; the
                        // legacy per-origin counter keeps older dashboards
                        // (and the PR-4 failover test) working for local
                        // respawns.
                        inner.metrics.incr(names::SHARDS_REDIALS);
                        if matches!(inner.origins[idx], ShardOrigin::Local) {
                            inner.metrics.incr(names::SHARD_RESPAWNS);
                        }
                    }
                    Err(e) => {
                        crate::log_error!("shard {idx} revival failed: {e:#}");
                        // Mark the shard permanently down: there is no retry
                        // loop beyond bring_up_shard's own dial backoff, so
                        // leaving budget on a shard that cannot come back
                        // would strand queued jobs behind the
                        // all-permanently-down check.
                        let mut st = inner.state.lock().unwrap();
                        st.shards[idx].redials = inner.spec.max_redials_per_shard;
                    }
                }
            } else {
                crate::log_error!("shard {idx} exceeded its redial budget and stays down");
            }
        }
        inner.work_ready.notify_all();
    }

    /// Resolve a job the transport lost: `Err(WorkerLost)`, never a hang.
    fn fail_job(&self, id: u64, completer: Completer) {
        self.metrics.incr(names::SHARD_JOBS_LOST);
        self.tracer.emit(
            id,
            EventKind::Failed { reason: fail_reason(&JobError::WorkerLost) },
        );
        self.complete(completer, Err(JobError::WorkerLost), protocol::CACHE_FLAG_NONE);
    }

    fn complete(&self, completer: Completer, result: JobResult, cache_flag: u8) {
        match completer {
            Completer::Slot(slot) => slot.complete(result),
            Completer::Batch { tx, idx, hits, misses } => {
                if let Ok(out) = &result {
                    self.metrics.observe_sample(names::BATCH_JOB_LATENCY, out.secs);
                    match cache_flag {
                        protocol::CACHE_FLAG_HIT => {
                            hits.fetch_add(1, Ordering::Relaxed);
                        }
                        protocol::CACHE_FLAG_MISS => {
                            misses.fetch_add(1, Ordering::Relaxed);
                        }
                        _ => {}
                    }
                }
                let _ = tx.send((idx, result));
            }
        }
    }
}

/// Least-loaded live shard with in-flight window capacity.
fn pick_shard(st: &RouterState, max_inflight: usize) -> Option<usize> {
    st.shards
        .iter()
        .enumerate()
        .filter(|(_, s)| s.alive && s.conn.is_some() && s.inflight.len() < max_inflight)
        .min_by_key(|(_, s)| s.inflight.len())
        .map(|(idx, _)| idx)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64, client: u64) -> RoutedJob {
        RoutedJob {
            id,
            client,
            req: SortRequest::new(vec![1i64]),
            completer: Completer::Slot(JobSlot::pending()),
        }
    }

    #[test]
    fn client_queues_round_robin_across_clients_fifo_within() {
        let mut q = ClientQueues::default();
        // Client 1 bursts first; client 2 trickles in after.
        for id in 0..4 {
            q.push(job(id, 1));
        }
        q.push(job(100, 2));
        q.push(job(101, 2));
        assert_eq!(q.len(), 6);
        let order: Vec<(u64, u64)> = std::iter::from_fn(|| q.pop())
            .map(|j| (j.client, j.id))
            .collect();
        // Round-robin: 1, 2, 1, 2, then 1 drains; FIFO within each client.
        assert_eq!(order, vec![(1, 0), (2, 100), (1, 1), (2, 101), (1, 2), (1, 3)]);
        assert!(q.is_empty());
    }

    #[test]
    fn client_queues_push_front_retries_first() {
        let mut q = ClientQueues::default();
        q.push(job(1, 7));
        q.push(job(2, 8));
        let head = q.pop().unwrap();
        assert_eq!(head.id, 1);
        q.push_front(head); // reclaim (failed write): must come back first
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.pop().unwrap().id, 2);
        assert!(q.pop().is_none());
    }

    #[test]
    fn client_queues_drain_empties_everything() {
        let mut q = ClientQueues::default();
        for id in 0..5 {
            q.push(job(id, id % 2));
        }
        let drained = q.drain_all();
        assert_eq!(drained.len(), 5);
        assert!(q.is_empty());
        assert!(q.pop().is_none());
    }
}
