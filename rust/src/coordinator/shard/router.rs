//! The shard router: the parent-process half of the cross-process service.
//!
//! A [`ShardRouter`] owns N child `evosort shard-worker` processes (spawned
//! from the running binary), each reached over its own Unix-domain socket
//! speaking the [`protocol`] frame format. Submission mirrors
//! [`SortService`](crate::coordinator::SortService) exactly —
//! [`submit_request`](ShardRouter::submit_request) → `Ticket`,
//! [`submit_batch_requests`](ShardRouter::submit_batch_requests) →
//! `BatchTicket` with unchanged `wait`/`stream` semantics — because the
//! router completes the same `JobSlot`s and feeds the same batch channel
//! the in-process pool does.
//!
//! Routing is least-loaded with a bounded per-shard in-flight window: jobs
//! beyond the window wait in a router-side queue, which is what makes them
//! **reroutable** — when a shard dies, only the jobs already on its socket
//! resolve `Err(WorkerLost)`; everything still queued flows to the
//! surviving shards while the dead shard respawns (and is re-seeded with
//! the merged tuning cache). Shard cache publications are merged
//! improvement-aware into the router's service-level [`TuningCache`] and
//! re-broadcast, so a fingerprint class tuned on one shard speeds up all
//! shards; telemetry frames aggregate per-shard counters (`tuner.*`,
//! `jobs.*`) into `shard.<i>.*` and `shards.*` gauges.

use std::collections::{HashMap, HashSet, VecDeque};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::autotune::AutotunePolicy;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::SortRequest;
use crate::coordinator::service::{self, BatchTicket};
use crate::coordinator::shard::protocol::{self, Frame};
use crate::coordinator::ticket::{JobError, JobResult, JobSlot, Ticket};
use crate::coordinator::tuning_cache::TuningCache;

/// Configuration for a sharded deployment.
#[derive(Debug, Clone)]
pub struct ShardSpec {
    /// Worker processes. `<= 1` means "don't shard" — use
    /// [`ShardedService::spawn`](super::ShardedService::spawn), which routes
    /// in-process in that case so the single-process path stays
    /// zero-overhead.
    pub shards: usize,
    /// Pool workers inside each shard process.
    pub workers_per_shard: usize,
    /// Threads each sort uses (per shard).
    pub sort_threads: usize,
    /// Each shard's pending-job queue bound.
    pub queue_capacity: usize,
    /// Attach an online autotuner to every shard (the policy is forwarded
    /// on the worker command line; caches sync through the router).
    pub autotune: Option<AutotunePolicy>,
    /// Jobs allowed on a shard's socket at once; `0` derives
    /// `2 × workers_per_shard`. Everything beyond waits in the router queue,
    /// reroutable on shard death.
    pub max_inflight_per_shard: usize,
    /// Respawn budget per shard: beyond this many deaths the shard stays
    /// down (a crash-looping worker must not respawn forever).
    pub max_respawns_per_shard: usize,
    /// Shard-side cadence for cache publication / telemetry frames.
    pub publish_interval: Duration,
    /// Kernel execution backend inside every shard (and on the in-process
    /// `shards <= 1` path): the persistent parked executor by default, the
    /// spawn-per-call baseline for A/B runs. Forwarded to worker processes
    /// as `--exec`.
    pub exec: crate::exec::ExecMode,
    /// The `evosort` binary to spawn; defaults to the running executable.
    /// Integration tests pass `env!("CARGO_BIN_EXE_evosort")` (the test
    /// harness binary is not the CLI).
    pub binary: Option<PathBuf>,
}

impl Default for ShardSpec {
    fn default() -> Self {
        ShardSpec {
            shards: 2,
            workers_per_shard: 2,
            sort_threads: crate::util::default_threads().div_ceil(2).max(1),
            queue_capacity: 64,
            autotune: None,
            max_inflight_per_shard: 0,
            max_respawns_per_shard: 5,
            publish_interval: Duration::from_millis(200),
            exec: crate::exec::ExecMode::Parked,
            binary: None,
        }
    }
}

/// How a resolved job reaches its caller — the same two delivery contracts
/// the in-process service uses.
enum Completer {
    Slot(Arc<JobSlot>),
    Batch {
        tx: mpsc::Sender<(usize, JobResult)>,
        idx: usize,
        hits: Arc<AtomicU64>,
        misses: Arc<AtomicU64>,
    },
}

/// A job waiting in the router queue (reroutable until dispatched).
struct RoutedJob {
    id: u64,
    req: SortRequest,
    completer: Completer,
}

struct ShardConn {
    child: Child,
    writer: Arc<Mutex<UnixStream>>,
}

struct ShardState {
    alive: bool,
    /// Incarnation counter: readers of a dead incarnation must not touch
    /// the state its respawn installed.
    generation: u64,
    respawns: usize,
    /// Router job ids currently on this shard's socket.
    inflight: HashSet<u64>,
    conn: Option<ShardConn>,
}

struct RouterState {
    queue: VecDeque<RoutedJob>,
    /// Dispatched-but-unresolved jobs (completion routes through here).
    pending: HashMap<u64, Completer>,
    shards: Vec<ShardState>,
    /// Latest telemetry snapshot per shard.
    telemetry: Vec<HashMap<String, u64>>,
}

struct RouterInner {
    spec: ShardSpec,
    max_inflight: usize,
    socket_dir: PathBuf,
    state: Mutex<RouterState>,
    /// Dispatcher wake-ups: new work, freed capacity, shard (re)spawned.
    work_ready: Condvar,
    /// Drain wake-ups: queue + pending went empty.
    idle: Condvar,
    metrics: Arc<Metrics>,
    cache: Arc<TuningCache>,
    next_id: AtomicU64,
    shutdown: AtomicBool,
    reader_handles: Mutex<Vec<JoinHandle<()>>>,
}

/// Handle to the sharded deployment; dropping it shuts the children down.
pub struct ShardRouter {
    inner: Arc<RouterInner>,
    dispatcher: Option<JoinHandle<()>>,
}

static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

impl ShardRouter {
    /// Spawn `spec.shards` worker processes and start routing. Fails if any
    /// worker cannot be spawned or does not connect back within 10 seconds.
    pub fn spawn(spec: ShardSpec) -> Result<ShardRouter> {
        anyhow::ensure!(spec.shards >= 1, "a sharded service needs at least one shard");
        let socket_dir = std::env::temp_dir().join(format!(
            "evosort-shards-{}-{}",
            std::process::id(),
            DIR_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&socket_dir)
            .with_context(|| format!("creating {}", socket_dir.display()))?;
        let max_inflight = if spec.max_inflight_per_shard == 0 {
            (spec.workers_per_shard * 2).max(1)
        } else {
            spec.max_inflight_per_shard
        };
        let shards = spec.shards;
        let inner = Arc::new(RouterInner {
            spec,
            max_inflight,
            socket_dir,
            state: Mutex::new(RouterState {
                queue: VecDeque::new(),
                pending: HashMap::new(),
                shards: (0..shards)
                    .map(|_| ShardState {
                        alive: false,
                        generation: 0,
                        respawns: 0,
                        inflight: HashSet::new(),
                        conn: None,
                    })
                    .collect(),
                telemetry: vec![HashMap::new(); shards],
            }),
            work_ready: Condvar::new(),
            idle: Condvar::new(),
            metrics: Arc::new(Metrics::new()),
            cache: Arc::new(TuningCache::new()),
            next_id: AtomicU64::new(1),
            shutdown: AtomicBool::new(false),
            reader_handles: Mutex::new(Vec::new()),
        });
        for idx in 0..shards {
            if let Err(e) = RouterInner::spawn_shard(&inner, idx) {
                // Partial start-up: kill and reap the shards that did spawn
                // (no Drop will run — the router was never constructed), so
                // a caller retrying spawn cannot accumulate orphans.
                inner.shutdown.store(true, Ordering::SeqCst);
                {
                    let mut st = inner.state.lock().unwrap();
                    for sh in st.shards.iter_mut() {
                        if let Some(conn) = sh.conn.as_mut() {
                            let _ = conn.child.kill();
                        }
                    }
                }
                let readers = std::mem::take(&mut *inner.reader_handles.lock().unwrap());
                for r in readers {
                    let _ = r.join(); // EOF after the kill; on_shard_down reaps
                }
                let _ = std::fs::remove_dir_all(&inner.socket_dir);
                return Err(e).with_context(|| format!("spawning shard {idx}"));
            }
        }
        let dispatcher = {
            let inner = Arc::clone(&inner);
            std::thread::Builder::new()
                .name("evosort-shard-router".into())
                .spawn(move || RouterInner::dispatcher_loop(&inner))
                .expect("spawn router dispatcher")
        };
        Ok(ShardRouter { inner, dispatcher: Some(dispatcher) })
    }

    /// Worker processes this router was configured with.
    pub fn shards(&self) -> usize {
        self.inner.spec.shards
    }

    /// Service-level metrics: per-job accounting mirrored from shard
    /// replies, `shard.<i>.*` / `shards.*` telemetry aggregation, routing
    /// and cache-broadcast counters.
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.inner.metrics
    }

    /// The merged service-level tuning cache (improvement-aware union of
    /// every shard's publications).
    pub fn cache(&self) -> &Arc<TuningCache> {
        &self.inner.cache
    }

    /// Submit one request; the returned [`Ticket`] behaves exactly as the
    /// in-process service's (poll / park / cancel-before-dispatch; a dead
    /// shard resolves it to `Err(WorkerLost)` instead of hanging).
    pub fn submit_request(&self, req: SortRequest) -> Ticket {
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        self.inner.metrics.incr("jobs.submitted");
        let slot = JobSlot::pending();
        self.inner.enqueue(RoutedJob { id, req, completer: Completer::Slot(Arc::clone(&slot)) });
        Ticket::new(id, slot)
    }

    /// Submit a batch; the returned [`BatchTicket`] barriers or streams in
    /// submission order exactly as the in-process path does.
    pub fn submit_batch_requests(&self, requests: Vec<SortRequest>) -> BatchTicket {
        let started = Instant::now();
        let total = requests.len();
        let (tx, rx) = mpsc::channel();
        let metrics = Arc::clone(&self.inner.metrics);
        metrics.add("jobs.submitted", total as u64);
        metrics.add("batch.jobs.submitted", total as u64);
        metrics.incr("batch.submitted");
        let hits = Arc::new(AtomicU64::new(0));
        let misses = Arc::new(AtomicU64::new(0));
        {
            let mut st = self.inner.state.lock().unwrap();
            for (idx, req) in requests.into_iter().enumerate() {
                let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
                let completer = Completer::Batch {
                    tx: tx.clone(),
                    idx,
                    hits: Arc::clone(&hits),
                    misses: Arc::clone(&misses),
                };
                st.queue.push_back(RoutedJob { id, req, completer });
            }
        }
        self.inner.work_ready.notify_all();
        BatchTicket::from_parts(total, started, rx, metrics, hits, misses)
    }

    /// Park until nothing is queued or in flight (bounded): the sharded
    /// analog of [`SortService::drain_timeout`].
    ///
    /// [`SortService::drain_timeout`]: crate::coordinator::SortService::drain_timeout
    pub fn drain_timeout(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut st = self.inner.state.lock().unwrap();
        while !(st.queue.is_empty() && st.pending.is_empty()) {
            let Some(remaining) = deadline.checked_duration_since(Instant::now()) else {
                return false;
            };
            let (next, _) = self.inner.idle.wait_timeout(st, remaining).unwrap();
            st = next;
        }
        true
    }

    /// Jobs currently on shard `idx`'s socket (diagnostics / tests).
    pub fn inflight(&self, idx: usize) -> usize {
        let st = self.inner.state.lock().unwrap();
        st.shards.get(idx).map(|s| s.inflight.len()).unwrap_or(0)
    }

    /// OS pid of each live shard worker (`None` while a shard is down).
    pub fn shard_pids(&self) -> Vec<Option<u32>> {
        let st = self.inner.state.lock().unwrap();
        st.shards.iter().map(|s| s.conn.as_ref().map(|c| c.child.id())).collect()
    }

    /// Chaos helper: SIGKILL shard `idx`'s worker process. In-flight jobs on
    /// it resolve `Err(WorkerLost)`; the router respawns it (budget
    /// permitting) and reroutes queued work meanwhile. Failover tests use
    /// this; production deaths take the same path.
    pub fn kill_shard(&self, idx: usize) -> bool {
        let mut st = self.inner.state.lock().unwrap();
        match st.shards.get_mut(idx).and_then(|s| s.conn.as_mut()) {
            Some(conn) => conn.child.kill().is_ok(),
            None => false,
        }
    }
}

impl Drop for ShardRouter {
    fn drop(&mut self) {
        let inner = &self.inner;
        inner.shutdown.store(true, Ordering::SeqCst);
        inner.work_ready.notify_all();
        // Resolve everything unfinished so no caller can hang on a ticket.
        let (queued, pending) = {
            let mut st = inner.state.lock().unwrap();
            let queued: Vec<RoutedJob> = st.queue.drain(..).collect();
            let pending: Vec<Completer> = st.pending.drain().map(|(_, c)| c).collect();
            (queued, pending)
        };
        for job in queued {
            inner.fail_job(job.completer);
        }
        for completer in pending {
            inner.fail_job(completer);
        }
        inner.idle.notify_all();
        // Ask every live shard to exit…
        let writers: Vec<Arc<Mutex<UnixStream>>> = {
            let st = inner.state.lock().unwrap();
            st.shards
                .iter()
                .filter_map(|s| s.conn.as_ref().map(|c| Arc::clone(&c.writer)))
                .collect()
        };
        let shutdown_frame = protocol::encode_shutdown();
        for w in writers {
            let mut w = w.lock().unwrap_or_else(|e| e.into_inner());
            let _ = protocol::write_frame(&mut *w, &shutdown_frame);
        }
        // …give them a bounded grace period, then hard-kill stragglers. The
        // reader threads reap each child as its connection closes.
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let all_down =
                { inner.state.lock().unwrap().shards.iter().all(|s| s.conn.is_none()) };
            if all_down || Instant::now() > deadline {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        {
            let mut st = inner.state.lock().unwrap();
            for sh in st.shards.iter_mut() {
                if let Some(conn) = sh.conn.as_mut() {
                    let _ = conn.child.kill();
                }
            }
        }
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        let readers = std::mem::take(&mut *inner.reader_handles.lock().unwrap());
        for r in readers {
            let _ = r.join();
        }
        let _ = std::fs::remove_dir_all(&inner.socket_dir);
    }
}

impl RouterInner {
    /// Spawn (or respawn) shard `idx`: bind a fresh socket, launch the
    /// worker process, wait for it to connect, seed it with the merged
    /// cache, and start its reader thread.
    fn spawn_shard(inner: &Arc<RouterInner>, idx: usize) -> Result<()> {
        let generation = inner.state.lock().unwrap().shards[idx].generation + 1;
        let socket = inner.socket_dir.join(format!("shard-{idx}-{generation}.sock"));
        let _ = std::fs::remove_file(&socket);
        let listener = UnixListener::bind(&socket)
            .with_context(|| format!("binding {}", socket.display()))?;
        listener.set_nonblocking(true).context("non-blocking listener")?;
        let binary = match &inner.spec.binary {
            Some(p) => p.clone(),
            None => std::env::current_exe().context("locating the evosort binary")?,
        };
        let mut cmd = Command::new(&binary);
        cmd.arg("shard-worker")
            .arg("--socket")
            .arg(&socket)
            .arg("--shard-id")
            .arg(idx.to_string())
            .arg("--workers")
            .arg(inner.spec.workers_per_shard.to_string())
            .arg("--sort-threads")
            .arg(inner.spec.sort_threads.to_string())
            .arg("--queue-capacity")
            .arg(inner.spec.queue_capacity.to_string())
            .arg("--publish-ms")
            .arg(inner.spec.publish_interval.as_millis().to_string())
            .arg("--exec")
            .arg(inner.spec.exec.name())
            .stdin(Stdio::null());
        if let Some(policy) = &inner.spec.autotune {
            cmd.arg("--min-obs")
                .arg(policy.min_observations.to_string())
                .arg("--cooldown")
                .arg(policy.cooldown_observations.to_string())
                .arg("--sample-cap")
                .arg(policy.retained_sample_cap.to_string())
                .arg("--tuner-generations")
                .arg(policy.generations_per_cycle.to_string())
                .arg("--tuner-population")
                .arg(policy.population.to_string())
                .arg("--cpu-share")
                .arg(policy.max_cpu_share.to_string())
                .arg("--min-improvement")
                .arg(policy.min_improvement_pct.to_string())
                .arg("--sample-every")
                .arg(policy.sample_every.to_string())
                .arg("--autotune");
        }
        let mut child =
            cmd.spawn().with_context(|| format!("spawning {}", binary.display()))?;
        let deadline = Instant::now() + Duration::from_secs(10);
        let stream = loop {
            match listener.accept() {
                Ok((stream, _)) => break stream,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if let Ok(Some(status)) = child.try_wait() {
                        bail!("shard {idx} worker exited before connecting: {status}");
                    }
                    if Instant::now() > deadline {
                        let _ = child.kill();
                        bail!("shard {idx} worker did not connect within 10s");
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => {
                    let _ = child.kill();
                    return Err(e).context("accepting shard connection");
                }
            }
        };
        stream.set_nonblocking(false).context("blocking shard stream")?;
        let _ = std::fs::remove_file(&socket);
        let writer = Arc::new(Mutex::new(stream.try_clone().context("cloning shard stream")?));
        // Re-seed a (re)spawned shard with everything the fleet has learned.
        if !inner.cache.is_empty() {
            let bytes = protocol::encode_cache_sync(&inner.cache.to_text());
            let mut w = writer.lock().unwrap_or_else(|e| e.into_inner());
            let _ = protocol::write_frame(&mut *w, &bytes);
        }
        {
            let mut st = inner.state.lock().unwrap();
            let sh = &mut st.shards[idx];
            sh.alive = true;
            sh.generation = generation;
            sh.inflight.clear();
            sh.conn = Some(ShardConn { child, writer });
        }
        let reader_inner = Arc::clone(inner);
        let handle = std::thread::Builder::new()
            .name(format!("evosort-router-read{idx}"))
            .spawn(move || {
                let mut stream = stream;
                loop {
                    match protocol::read_frame(&mut stream) {
                        Ok(frame) => reader_inner.on_frame(idx, frame),
                        Err(_) => break,
                    }
                }
                RouterInner::on_shard_down(&reader_inner, idx, generation);
            })
            .expect("spawn router reader");
        inner.reader_handles.lock().unwrap().push(handle);
        // A shutdown that raced with this (re)spawn: tell the fresh worker
        // to exit immediately so the Drop-side reader join cannot hang on a
        // shard that never got the broadcast Shutdown frame.
        if inner.shutdown.load(Ordering::SeqCst) {
            let st = inner.state.lock().unwrap();
            if let Some(conn) = st.shards[idx].conn.as_ref() {
                let mut w = conn.writer.lock().unwrap_or_else(|e| e.into_inner());
                let _ = protocol::write_frame(&mut *w, &protocol::encode_shutdown());
            }
        }
        inner.work_ready.notify_all();
        Ok(())
    }

    fn enqueue(&self, job: RoutedJob) {
        if self.shutdown.load(Ordering::SeqCst) {
            self.fail_job(job.completer);
            return;
        }
        let mut st = self.state.lock().unwrap();
        st.queue.push_back(job);
        drop(st);
        self.work_ready.notify_all();
    }

    /// The routing loop: pick the least-loaded live shard with window
    /// capacity, move the job from the queue to `pending`, write the frame.
    fn dispatcher_loop(inner: &Arc<RouterInner>) {
        loop {
            let (id, req, idx, writer) = {
                let mut st = inner.state.lock().unwrap();
                loop {
                    if inner.shutdown.load(Ordering::SeqCst) {
                        return; // Drop resolves whatever is left
                    }
                    if !st.queue.is_empty() {
                        if let Some(idx) = pick_shard(&st, inner.max_inflight) {
                            let RoutedJob { id, req, completer } = st.queue.pop_front().unwrap();
                            // Honour a cancel that landed while the job was
                            // queued — the same dequeue-time check the
                            // in-process worker makes, preserving the
                            // `cancel() == true ⇒ Err(Cancelled)` guarantee.
                            if let Completer::Slot(slot) = &completer {
                                if slot.start() {
                                    slot.complete(Err(JobError::Cancelled));
                                    if st.queue.is_empty() && st.pending.is_empty() {
                                        inner.idle.notify_all();
                                    }
                                    continue;
                                }
                            }
                            st.pending.insert(id, completer);
                            st.shards[idx].inflight.insert(id);
                            let conn = st.shards[idx].conn.as_ref().expect("picked shard is live");
                            break (id, req, idx, Arc::clone(&conn.writer));
                        }
                        // Fail the queue only when every shard is down for
                        // good (budget spent or permanently unspawnable).
                        // Transiently-dead shards respawn within seconds —
                        // queued jobs must survive that window: rerouting
                        // them is the whole point of the router queue.
                        let all_permanently_down = st.shards.iter().all(|s| {
                            !s.alive && s.respawns >= inner.spec.max_respawns_per_shard
                        });
                        if all_permanently_down {
                            let dead: Vec<RoutedJob> = st.queue.drain(..).collect();
                            let idle_now = st.pending.is_empty();
                            drop(st);
                            for job in dead {
                                inner.fail_job(job.completer);
                            }
                            if idle_now {
                                inner.idle.notify_all();
                            }
                            st = inner.state.lock().unwrap();
                            continue;
                        }
                    }
                    st = inner.work_ready.wait(st).unwrap();
                }
            };
            let bytes = protocol::encode_job(id, &req);
            if bytes.len() as u64 > protocol::MAX_JOB_FRAME_BYTES {
                // An oversized job would be rejected by every shard's
                // receive-side frame bound and, routed job-at-a-time, would
                // serially exhaust the whole fleet's respawn budget. Fail
                // its own ticket instead.
                let (completer, idle_now) = {
                    let mut st = inner.state.lock().unwrap();
                    st.shards[idx].inflight.remove(&id);
                    let completer = st.pending.remove(&id);
                    (completer, st.pending.is_empty() && st.queue.is_empty())
                };
                inner.metrics.incr("shard.jobs.oversized");
                crate::log_error!(
                    "job {id} ({} bytes) exceeds the shard frame bound; failing it",
                    bytes.len()
                );
                if let Some(completer) = completer {
                    inner.fail_job(completer);
                }
                if idle_now {
                    inner.idle.notify_all();
                }
                continue;
            }
            let sent = {
                let mut w = writer.lock().unwrap_or_else(|e| e.into_inner());
                protocol::write_frame(&mut *w, &bytes).is_ok()
            };
            if sent {
                inner.metrics.incr(&format!("shard.{idx}.jobs.routed"));
            } else {
                // The shard died between pick and write. Its reader thread
                // handles the death; reclaim the job for rerouting unless
                // that handler already failed it.
                let mut st = inner.state.lock().unwrap();
                if let Some(completer) = st.pending.remove(&id) {
                    st.shards[idx].inflight.remove(&id);
                    st.queue.push_front(RoutedJob { id, req, completer });
                }
            }
        }
    }

    fn on_frame(&self, idx: usize, frame: Frame) {
        match frame {
            Frame::JobDone { id, cache_flag, result } => {
                self.on_job_done(idx, id, cache_flag, result)
            }
            Frame::CachePublish { text } => self.on_cache_publish(idx, &text),
            Frame::Telemetry { counters } => self.on_telemetry(idx, counters),
            _ => {} // frames for the other direction: ignore
        }
    }

    fn on_job_done(&self, idx: usize, id: u64, cache_flag: u8, result: JobResult) {
        let completer = {
            let mut st = self.state.lock().unwrap();
            if let Some(sh) = st.shards.get_mut(idx) {
                sh.inflight.remove(&id);
            }
            let completer = st.pending.remove(&id);
            if completer.is_some() && st.pending.is_empty() && st.queue.is_empty() {
                self.idle.notify_all();
            }
            completer
        };
        // Capacity freed: wake the dispatcher.
        self.work_ready.notify_all();
        let Some(completer) = completer else {
            return; // late reply for a job the death handler already failed
        };
        // Mirror the in-process service's per-job accounting at the
        // service level (each shard also keeps its own local metrics).
        match &result {
            Ok(out) => {
                self.metrics.incr("jobs.completed");
                self.metrics.incr(service::dtype_counter(out.dtype()));
                self.metrics.observe("sort.latency", out.secs);
                self.metrics.add("elements.sorted", out.len() as u64);
                if !out.valid {
                    self.metrics.incr("jobs.invalid");
                }
                self.metrics.incr(&format!("shard.{idx}.jobs.completed"));
                match cache_flag {
                    protocol::CACHE_FLAG_HIT => self.metrics.incr("params.cache_hit"),
                    protocol::CACHE_FLAG_MISS => self.metrics.incr("params.cache_miss"),
                    _ => self.metrics.incr("params.override"),
                }
            }
            Err(_) => self.metrics.incr("shard.jobs.lost"),
        }
        self.complete(completer, result, cache_flag);
    }

    /// A shard's cache changed: merge it (improvement-aware — a worse
    /// incoming entry cannot clobber a better one) and, if the merge
    /// actually changed the service-level cache, broadcast the union back
    /// to every live shard.
    fn on_cache_publish(&self, idx: usize, text: &str) {
        self.metrics.incr("shard.cache.publishes");
        let absorbed = self.cache.absorb(&TuningCache::from_text(text));
        if absorbed == 0 {
            return;
        }
        self.metrics.add("shard.cache.entries_absorbed", absorbed as u64);
        self.metrics.set_gauge("shard.cache.entries", self.cache.len() as f64);
        crate::log_debug!("router: absorbed {absorbed} cache entries from shard {idx}");
        let bytes = protocol::encode_cache_sync(&self.cache.to_text());
        let writers: Vec<Arc<Mutex<UnixStream>>> = {
            let st = self.state.lock().unwrap();
            st.shards
                .iter()
                .filter(|s| s.alive)
                .filter_map(|s| s.conn.as_ref().map(|c| Arc::clone(&c.writer)))
                .collect()
        };
        for w in writers {
            let mut w = w.lock().unwrap_or_else(|e| e.into_inner());
            let _ = protocol::write_frame(&mut *w, &bytes);
        }
        self.metrics.incr("shard.cache.broadcasts");
    }

    /// Fold one shard's counter snapshot into per-shard and fleet gauges.
    fn on_telemetry(&self, idx: usize, counters: Vec<(String, u64)>) {
        let (this, totals) = {
            let mut st = self.state.lock().unwrap();
            st.telemetry[idx] = counters.into_iter().collect();
            let mut totals: HashMap<String, u64> = HashMap::new();
            for shard in &st.telemetry {
                for (name, value) in shard {
                    *totals.entry(name.clone()).or_default() += value;
                }
            }
            let this: Vec<(String, u64)> =
                st.telemetry[idx].iter().map(|(k, v)| (k.clone(), *v)).collect();
            (this, totals)
        };
        // The `local` segment separates these process-local mirrors (which
        // reset when a shard respawns) from the router's own lifetime
        // counters — `shard.0.jobs.completed` (counter, router-lifetime)
        // and `shard.0.local.jobs.completed` (gauge, child-process view)
        // must not share a name.
        for (name, value) in this {
            self.metrics.set_gauge(&format!("shard.{idx}.local.{name}"), value as f64);
        }
        for (name, value) in totals {
            self.metrics.set_gauge(&format!("shards.{name}"), value as f64);
        }
    }

    /// A shard's connection closed. Fail its in-flight jobs (`WorkerLost` —
    /// the payloads left with the frames, so they cannot be rerouted),
    /// reap the child, and respawn within budget. Queued jobs are untouched:
    /// the dispatcher reroutes them to the survivors.
    fn on_shard_down(inner: &Arc<RouterInner>, idx: usize, generation: u64) {
        let shutting_down = inner.shutdown.load(Ordering::SeqCst);
        let mut lost: Vec<Completer> = Vec::new();
        let mut respawn = false;
        {
            let mut st = inner.state.lock().unwrap();
            if st.shards[idx].generation != generation {
                return; // a reader from a previous incarnation
            }
            let sh = &mut st.shards[idx];
            sh.alive = false;
            if let Some(mut conn) = sh.conn.take() {
                let _ = conn.child.kill();
                let _ = conn.child.wait(); // reap — no zombies
            }
            let ids: Vec<u64> = sh.inflight.drain().collect();
            for id in &ids {
                if let Some(completer) = st.pending.remove(id) {
                    lost.push(completer);
                }
            }
            if !shutting_down && st.shards[idx].respawns < inner.spec.max_respawns_per_shard {
                st.shards[idx].respawns += 1;
                respawn = true;
            }
            if st.pending.is_empty() && st.queue.is_empty() {
                inner.idle.notify_all();
            }
        }
        for completer in lost {
            inner.fail_job(completer);
        }
        if !shutting_down {
            inner.metrics.incr("shard.deaths");
            if respawn {
                match RouterInner::spawn_shard(inner, idx) {
                    Ok(()) => inner.metrics.incr("shard.respawns"),
                    Err(e) => {
                        crate::log_error!("shard {idx} respawn failed: {e:#}");
                        // Mark the shard permanently down: there is no retry
                        // loop for failed spawns, so leaving budget on a
                        // shard that cannot come back would strand queued
                        // jobs behind the all-permanently-down check.
                        let mut st = inner.state.lock().unwrap();
                        st.shards[idx].respawns = inner.spec.max_respawns_per_shard;
                    }
                }
            } else {
                crate::log_error!(
                    "shard {idx} exceeded its respawn budget and stays down"
                );
            }
        }
        inner.work_ready.notify_all();
    }

    /// Resolve a job the transport lost: `Err(WorkerLost)`, never a hang.
    fn fail_job(&self, completer: Completer) {
        self.metrics.incr("shard.jobs.lost");
        self.complete(completer, Err(JobError::WorkerLost), protocol::CACHE_FLAG_NONE);
    }

    fn complete(&self, completer: Completer, result: JobResult, cache_flag: u8) {
        match completer {
            Completer::Slot(slot) => slot.complete(result),
            Completer::Batch { tx, idx, hits, misses } => {
                if let Ok(out) = &result {
                    self.metrics.observe_sample("batch.job.latency", out.secs);
                    match cache_flag {
                        protocol::CACHE_FLAG_HIT => {
                            hits.fetch_add(1, Ordering::Relaxed);
                        }
                        protocol::CACHE_FLAG_MISS => {
                            misses.fetch_add(1, Ordering::Relaxed);
                        }
                        _ => {}
                    }
                }
                let _ = tx.send((idx, result));
            }
        }
    }
}

/// Least-loaded live shard with in-flight window capacity.
fn pick_shard(st: &RouterState, max_inflight: usize) -> Option<usize> {
    st.shards
        .iter()
        .enumerate()
        .filter(|(_, s)| s.alive && s.conn.is_some() && s.inflight.len() < max_inflight)
        .min_by_key(|(_, s)| s.inflight.len())
        .map(|(idx, _)| idx)
}
