//! The transport seam beneath the shard router and worker: one [`Listener`]
//! / [`Stream`] pair covering both Unix-domain and TCP sockets, selected by
//! [`Endpoint`].
//!
//! Everything above this module (frame protocol, router, worker) is
//! transport-agnostic: it reads and writes byte streams and never names a
//! socket type. Enums (not trait objects) keep the seam allocation-free and
//! `try_clone`-able — the router's writer mutex and each connection's reader
//! thread hold independent clones of the same underlying socket, for either
//! transport.
//!
//! TCP streams set `TCP_NODELAY`: frames are latency-sensitive
//! (job-done replies unblock the router's in-flight window) and the writer
//! already batches each frame into a single `write_all`.

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::coordinator::endpoint::Endpoint;

/// A listening socket on either transport.
pub enum Listener {
    Unix { listener: UnixListener, path: PathBuf },
    Tcp(TcpListener),
}

impl Listener {
    /// Bind `endpoint`. A stale Unix socket file from a crashed previous
    /// run is removed first; `tcp://host:0` binds an OS-assigned port
    /// (recover it with [`local_endpoint`](Listener::local_endpoint)).
    pub fn bind(endpoint: &Endpoint) -> Result<Listener> {
        match endpoint {
            Endpoint::Unix(path) => {
                let _ = std::fs::remove_file(path);
                let listener = UnixListener::bind(path)
                    .with_context(|| format!("binding {endpoint}"))?;
                Ok(Listener::Unix { listener, path: path.clone() })
            }
            Endpoint::Tcp { host, port } => {
                let listener = TcpListener::bind((host.as_str(), *port))
                    .with_context(|| format!("binding {endpoint}"))?;
                Ok(Listener::Tcp(listener))
            }
        }
    }

    /// The bound address — for `tcp://…:0`, the port the OS actually chose.
    pub fn local_endpoint(&self) -> Result<Endpoint> {
        match self {
            Listener::Unix { path, .. } => Ok(Endpoint::Unix(path.clone())),
            Listener::Tcp(listener) => {
                let addr = listener.local_addr().context("reading the bound TCP address")?;
                Ok(Endpoint::Tcp { host: addr.ip().to_string(), port: addr.port() })
            }
        }
    }

    pub fn set_nonblocking(&self, nonblocking: bool) -> io::Result<()> {
        match self {
            Listener::Unix { listener, .. } => listener.set_nonblocking(nonblocking),
            Listener::Tcp(listener) => listener.set_nonblocking(nonblocking),
        }
    }

    /// Accept one connection (blocking or `WouldBlock`, per the listener's
    /// mode). TCP streams come back with `TCP_NODELAY` set.
    pub fn accept(&self) -> io::Result<Stream> {
        match self {
            Listener::Unix { listener, .. } => {
                let (stream, _) = listener.accept()?;
                Ok(Stream::Unix(stream))
            }
            Listener::Tcp(listener) => {
                let (stream, _) = listener.accept()?;
                let _ = stream.set_nodelay(true);
                Ok(Stream::Tcp(stream))
            }
        }
    }

    /// The socket file to unlink once the connection is up (Unix only).
    pub fn cleanup_path(&self) -> Option<&std::path::Path> {
        match self {
            Listener::Unix { path, .. } => Some(path),
            Listener::Tcp(_) => None,
        }
    }
}

/// A connected byte stream on either transport.
pub enum Stream {
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl Stream {
    /// Dial `endpoint` once (no retries — the router's redial loop owns
    /// backoff policy).
    pub fn connect(endpoint: &Endpoint) -> Result<Stream> {
        match endpoint {
            Endpoint::Unix(path) => {
                let stream = UnixStream::connect(path)
                    .with_context(|| format!("connecting to {endpoint}"))?;
                Ok(Stream::Unix(stream))
            }
            Endpoint::Tcp { host, port } => {
                let stream = TcpStream::connect((host.as_str(), *port))
                    .with_context(|| format!("connecting to {endpoint}"))?;
                let _ = stream.set_nodelay(true);
                Ok(Stream::Tcp(stream))
            }
        }
    }

    /// An independent handle to the same socket (reader/writer split).
    pub fn try_clone(&self) -> io::Result<Stream> {
        match self {
            Stream::Unix(s) => s.try_clone().map(Stream::Unix),
            Stream::Tcp(s) => s.try_clone().map(Stream::Tcp),
        }
    }

    /// Shut down both directions. Every clone of the socket sees EOF — this
    /// is how the router force-drops a remote shard (there is no child
    /// process to kill) and how `Drop` detaches remote workers so they can
    /// go back to listening.
    pub fn shutdown(&self) -> io::Result<()> {
        match self {
            Stream::Unix(s) => s.shutdown(std::net::Shutdown::Both),
            Stream::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
        }
    }

    pub fn set_nonblocking(&self, nonblocking: bool) -> io::Result<()> {
        match self {
            Stream::Unix(s) => s.set_nonblocking(nonblocking),
            Stream::Tcp(s) => s.set_nonblocking(nonblocking),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Stream::Unix(s) => s.read(buf),
            Stream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Stream::Unix(s) => s.write(buf),
            Stream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Stream::Unix(s) => s.flush(),
            Stream::Tcp(s) => s.flush(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    #[test]
    fn tcp_listener_reports_resolved_port_and_carries_bytes() {
        let listener = Listener::bind(&Endpoint::tcp("127.0.0.1", 0)).expect("bind");
        let bound = listener.local_endpoint().expect("local endpoint");
        let Endpoint::Tcp { ref host, port } = bound else { panic!("tcp endpoint") };
        assert_eq!(host, "127.0.0.1");
        assert_ne!(port, 0, "the OS assigned a real port");

        let server = std::thread::spawn(move || {
            let mut conn = listener.accept().expect("accept");
            let mut buf = [0u8; 5];
            conn.read_exact(&mut buf).expect("read");
            conn.write_all(&buf).expect("echo");
            buf
        });
        let mut client = Stream::connect(&bound).expect("dial");
        client.write_all(b"hello").expect("send");
        let mut echo = [0u8; 5];
        client.read_exact(&mut echo).expect("echo back");
        assert_eq!(&echo, b"hello");
        assert_eq!(&server.join().unwrap(), b"hello");
    }

    #[test]
    fn unix_listener_round_trips_and_cleans_up() {
        let dir = std::env::temp_dir()
            .join(format!("evosort-transport-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("tmp dir");
        let ep = Endpoint::unix(dir.join("t.sock"));
        let listener = Listener::bind(&ep).expect("bind");
        assert_eq!(listener.local_endpoint().unwrap(), ep);
        assert!(listener.cleanup_path().is_some());

        let server = {
            let ep = ep.clone();
            std::thread::spawn(move || {
                let mut client = Stream::connect(&ep).expect("dial");
                client.write_all(b"ok").expect("send");
            })
        };
        let mut conn = listener.accept().expect("accept");
        let mut buf = [0u8; 2];
        conn.read_exact(&mut buf).expect("read");
        assert_eq!(&buf, b"ok");
        server.join().unwrap();
        // Re-binding the same path succeeds (stale file removal).
        let _again = Listener::bind(&ep).expect("rebind over stale socket file");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shutdown_unblocks_a_cloned_reader() {
        let listener = Listener::bind(&Endpoint::tcp("127.0.0.1", 0)).expect("bind");
        let bound = listener.local_endpoint().expect("ep");
        let client = std::thread::spawn(move || {
            let stream = Stream::connect(&bound).expect("dial");
            let mut reader = stream.try_clone().expect("clone");
            let blocker = std::thread::spawn(move || {
                let mut buf = [0u8; 1];
                reader.read(&mut buf) // EOF (Ok(0)) once shutdown lands
            });
            std::thread::sleep(std::time::Duration::from_millis(50));
            stream.shutdown().expect("shutdown");
            let read = blocker.join().unwrap().expect("read after shutdown");
            assert_eq!(read, 0, "shutdown surfaces as EOF on the clone");
        });
        let _server_side = listener.accept().expect("accept");
        client.join().unwrap();
    }
}
