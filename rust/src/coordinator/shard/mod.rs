//! Cross-process sharding: the same typed async service API
//! ([`SortRequest`] → [`Ticket`] / [`BatchTicket`]) served by N `evosort
//! shard-worker` OS processes behind a [`ShardRouter`], over a
//! length-prefixed frame protocol on Unix-domain sockets.
//!
//! Layering:
//!
//! * [`protocol`] — the wire format (hand-rolled little-endian frames; the
//!   tuning cache travels as its versioned v2 text interchange);
//! * [`worker`] — the child-process side: one [`SortService`] per shard,
//!   autotuner included, publishing its cache and counter telemetry back;
//! * [`router`] — the parent side: least-loaded dispatch with a bounded
//!   per-shard in-flight window (queued jobs reroute on shard death,
//!   in-flight ones resolve `Err(WorkerLost)`, the shard respawns),
//!   improvement-aware cache merging with re-broadcast, and per-shard →
//!   service-level metrics aggregation;
//! * [`ShardedService`] — the front door: routes in-process when
//!   `shards <= 1` so the single-process path keeps zero sharding overhead.
//!
//! [`SortRequest`]: crate::coordinator::SortRequest
//! [`Ticket`]: crate::coordinator::Ticket
//! [`BatchTicket`]: crate::coordinator::BatchTicket
//! [`SortService`]: crate::coordinator::SortService

pub mod protocol;
pub mod router;
pub mod worker;

pub use router::{ShardRouter, ShardSpec};
pub use worker::ShardWorkerConfig;

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::SortRequest;
use crate::coordinator::service::{BatchTicket, ServiceConfig, SortService};
use crate::coordinator::ticket::Ticket;
use crate::coordinator::tuning_cache::TuningCache;

/// A service that is either in-process ([`SortService`]) or sharded across
/// worker processes ([`ShardRouter`]) behind one submission surface.
/// `Ticket`/`BatchTicket`/`ResultStream` semantics are identical either way.
pub enum ShardedService {
    /// `shards <= 1`: the plain in-process service, zero sharding overhead.
    Local(SortService),
    /// `shards >= 2`: router + child processes.
    Sharded(ShardRouter),
}

impl ShardedService {
    /// Build from a spec: in-process when `spec.shards <= 1`, cross-process
    /// otherwise.
    pub fn spawn(spec: ShardSpec) -> Result<ShardedService> {
        if spec.shards <= 1 {
            Ok(ShardedService::Local(SortService::new(ServiceConfig {
                workers: spec.workers_per_shard,
                sort_threads: spec.sort_threads,
                queue_capacity: spec.queue_capacity,
                autotune: spec.autotune,
                exec: spec.exec,
            })))
        } else {
            Ok(ShardedService::Sharded(ShardRouter::spawn(spec)?))
        }
    }

    /// Worker processes serving traffic (1 for the in-process path).
    pub fn shards(&self) -> usize {
        match self {
            ShardedService::Local(_) => 1,
            ShardedService::Sharded(router) => router.shards(),
        }
    }

    pub fn submit_request(&self, req: SortRequest) -> Ticket {
        match self {
            ShardedService::Local(svc) => svc.submit_request(req),
            ShardedService::Sharded(router) => router.submit_request(req),
        }
    }

    pub fn submit_batch_requests(&self, requests: Vec<SortRequest>) -> BatchTicket {
        match self {
            ShardedService::Local(svc) => svc.submit_batch_requests(requests),
            ShardedService::Sharded(router) => router.submit_batch_requests(requests),
        }
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        match self {
            ShardedService::Local(svc) => svc.metrics(),
            ShardedService::Sharded(router) => router.metrics(),
        }
    }

    /// The service-level tuning cache (the router's merged view when
    /// sharded).
    pub fn cache(&self) -> &Arc<TuningCache> {
        match self {
            ShardedService::Local(svc) => svc.cache(),
            ShardedService::Sharded(router) => router.cache(),
        }
    }

    /// Bounded drain: `true` once nothing is queued or in flight.
    pub fn drain_timeout(&self, timeout: Duration) -> bool {
        match self {
            ShardedService::Local(svc) => svc.drain_timeout(timeout),
            ShardedService::Sharded(router) => router.drain_timeout(timeout),
        }
    }

    /// The router, when sharded (failover tests reach `kill_shard` etc.
    /// through this).
    pub fn router(&self) -> Option<&ShardRouter> {
        match self {
            ShardedService::Local(_) => None,
            ShardedService::Sharded(router) => Some(router),
        }
    }
}
