//! Cross-process sharding: the same typed async service API
//! ([`SortRequest`] → [`Ticket`] / [`BatchTicket`]) served by N `evosort
//! shard-worker` OS processes behind a [`ShardRouter`], over a
//! length-prefixed frame protocol carried by either transport the
//! [`transport`] seam offers — Unix-domain sockets on one host, TCP across
//! hosts.
//!
//! Layering:
//!
//! * [`protocol`] — the wire format (hand-rolled little-endian frames; the
//!   tuning cache travels as its versioned v2 text interchange), identical
//!   on both transports;
//! * [`transport`] — the byte-stream seam: [`Listener`](transport::Listener)
//!   / [`Stream`](transport::Stream) over an [`Endpoint`]
//!   (`unix:///path.sock` or `tcp://host:port`);
//! * [`worker`] — the child-process side: one [`SortService`] per shard,
//!   autotuner included, publishing its cache and counter telemetry back.
//!   Local shards dial the router ([`worker::run`]); standalone remote
//!   workers listen and serve routers one at a time
//!   ([`worker::run_listening`]);
//! * [`router`] — the parent side: bounded admission (`Err(Overloaded)`
//!   past the router-queue capacity), per-client round-robin fairness,
//!   least-loaded dispatch with a bounded per-shard in-flight window
//!   (queued jobs reroute on shard death, in-flight ones resolve
//!   `Err(WorkerLost)`, the shard respawns or is redialed within its
//!   redial budget), improvement-aware cache merging with re-broadcast,
//!   and per-shard → service-level metrics aggregation;
//! * [`ShardedService`] — the front door: routes in-process when the fleet
//!   is a single local shard so that path keeps zero sharding overhead.
//!   [`ShardedService::builder`] is the ergonomic way to describe a fleet.
//!
//! [`SortRequest`]: crate::coordinator::SortRequest
//! [`Ticket`]: crate::coordinator::Ticket
//! [`BatchTicket`]: crate::coordinator::BatchTicket
//! [`SortService`]: crate::coordinator::SortService
//! [`Endpoint`]: crate::coordinator::Endpoint

pub mod protocol;
pub mod router;
pub mod transport;
pub mod worker;

pub use router::{ShardRouter, ShardSpec};
pub use worker::ShardWorkerConfig;

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::autotune::AutotunePolicy;
use crate::coordinator::endpoint::{Endpoint, TransportKind};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::SortRequest;
use crate::coordinator::service::{BatchTicket, ServiceConfig, SortService};
use crate::coordinator::ticket::Ticket;
use crate::coordinator::tuning_cache::TuningCache;
use crate::obs::{TraceHub, Tracer, DEFAULT_RING_CAPACITY};

/// A service that is either in-process ([`SortService`]) or sharded across
/// worker processes ([`ShardRouter`]) behind one submission surface.
/// `Ticket`/`BatchTicket`/`ResultStream` semantics are identical either way.
pub enum ShardedService {
    /// A single local shard: the plain in-process service, zero sharding
    /// overhead. The hub (present when the spec asked for tracing) drains
    /// the service's span events into the timeline / JSONL sink, exactly
    /// like the router-side hub does for a fleet.
    Local {
        svc: SortService,
        trace_hub: Option<TraceHub>,
    },
    /// Two or more fleet slots (local and/or remote): router + worker
    /// processes.
    Sharded(ShardRouter),
}

impl ShardedService {
    /// Build from a spec: in-process when the fleet is at most one local
    /// shard and no remotes, cross-process otherwise.
    pub fn spawn(spec: ShardSpec) -> Result<ShardedService> {
        if spec.shards <= 1 && spec.remotes.is_empty() {
            let tracer = if spec.trace {
                Tracer::enabled(DEFAULT_RING_CAPACITY, 0)
            } else {
                Tracer::disabled()
            };
            let svc = SortService::new_traced(
                ServiceConfig::sized(spec.workers_per_shard, spec.sort_threads, spec.queue_capacity)
                    .with_autotune(spec.autotune)
                    .with_exec(spec.exec),
                tracer.clone(),
            );
            let trace_hub = if spec.trace {
                Some(TraceHub::new(
                    tracer,
                    spec.trace_log.as_deref(),
                    Some(Arc::clone(svc.metrics())),
                )?)
            } else {
                None
            };
            Ok(ShardedService::Local { svc, trace_hub })
        } else {
            Ok(ShardedService::Sharded(ShardRouter::spawn(spec)?))
        }
    }

    /// Fluent fleet description:
    ///
    /// ```no_run
    /// # use evosort::coordinator::shard::ShardedService;
    /// let svc = ShardedService::builder()
    ///     .shards(4)
    ///     .endpoint("tcp://127.0.0.1:0".parse().unwrap())
    ///     .connect("tcp://10.0.0.7:7070".parse().unwrap())
    ///     .exec(evosort::exec::ExecMode::Parked)
    ///     .spawn()
    ///     .unwrap();
    /// # drop(svc);
    /// ```
    pub fn builder() -> ShardedServiceBuilder {
        ShardedServiceBuilder { spec: ShardSpec::default() }
    }

    /// Fleet slots serving traffic (1 for the in-process path).
    pub fn shards(&self) -> usize {
        match self {
            ShardedService::Local { .. } => 1,
            ShardedService::Sharded(router) => router.shards(),
        }
    }

    pub fn submit_request(&self, req: SortRequest) -> Ticket {
        match self {
            ShardedService::Local { svc, .. } => svc.submit_request(req),
            ShardedService::Sharded(router) => router.submit_request(req),
        }
    }

    pub fn submit_batch_requests(&self, requests: Vec<SortRequest>) -> BatchTicket {
        match self {
            ShardedService::Local { svc, .. } => svc.submit_batch_requests(requests),
            ShardedService::Sharded(router) => router.submit_batch_requests(requests),
        }
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        match self {
            ShardedService::Local { svc, .. } => svc.metrics(),
            ShardedService::Sharded(router) => router.metrics(),
        }
    }

    /// The service-level tuning cache (the router's merged view when
    /// sharded).
    pub fn cache(&self) -> &Arc<TuningCache> {
        match self {
            ShardedService::Local { svc, .. } => svc.cache(),
            ShardedService::Sharded(router) => router.cache(),
        }
    }

    /// Bounded drain: `true` once nothing is queued or in flight.
    pub fn drain_timeout(&self, timeout: Duration) -> bool {
        match self {
            ShardedService::Local { svc, .. } => svc.drain_timeout(timeout),
            ShardedService::Sharded(router) => router.drain_timeout(timeout),
        }
    }

    /// The trace hub, when the spec asked for tracing (`None` otherwise):
    /// the merged fleet timeline plus the JSONL sink.
    pub fn trace_hub(&self) -> Option<&TraceHub> {
        match self {
            ShardedService::Local { trace_hub, .. } => trace_hub.as_ref(),
            ShardedService::Sharded(router) => router.trace_hub(),
        }
    }

    /// The router, when sharded (failover tests reach `kill_shard` etc.
    /// through this).
    pub fn router(&self) -> Option<&ShardRouter> {
        match self {
            ShardedService::Local { .. } => None,
            ShardedService::Sharded(router) => Some(router),
        }
    }
}

/// Builder behind [`ShardedService::builder`]: a fluent layer over
/// [`ShardSpec`] so call sites don't have to spell out
/// `..ShardSpec::default()` or know which fields interact.
/// [`ServiceSettings::to_shard_spec`](crate::config::ServiceSettings::to_shard_spec)
/// is a thin shim over this.
#[derive(Debug, Clone)]
pub struct ShardedServiceBuilder {
    spec: ShardSpec,
}

impl ShardedServiceBuilder {
    /// Locally spawned shard processes (may be 0 when remotes carry all
    /// the traffic).
    pub fn shards(mut self, shards: usize) -> Self {
        self.spec.shards = shards;
        self
    }

    /// Pool workers inside each shard process.
    pub fn workers_per_shard(mut self, workers: usize) -> Self {
        self.spec.workers_per_shard = workers;
        self
    }

    /// Threads each sort uses (per shard).
    pub fn sort_threads(mut self, threads: usize) -> Self {
        self.spec.sort_threads = threads;
        self
    }

    /// Each shard's pending-job queue bound.
    pub fn queue_capacity(mut self, capacity: usize) -> Self {
        self.spec.queue_capacity = capacity;
        self
    }

    /// Attach an online autotuner to every shard.
    pub fn autotune(mut self, policy: AutotunePolicy) -> Self {
        self.spec.autotune = Some(policy);
        self
    }

    /// Kernel execution backend inside every shard.
    pub fn exec(mut self, exec: crate::exec::ExecMode) -> Self {
        self.spec.exec = exec;
        self
    }

    /// Link transport for local shards (`unix` default, `tcp` for
    /// loopback-TCP links); [`endpoint`](Self::endpoint) sets this
    /// implicitly from the endpoint's scheme.
    pub fn transport(mut self, transport: TransportKind) -> Self {
        self.spec.transport = transport;
        self
    }

    /// Listen-address base for local shards; also selects the transport
    /// from the endpoint's scheme (so `.endpoint("tcp://0.0.0.0:7100")`
    /// is enough to switch a fleet to TCP).
    pub fn endpoint(mut self, endpoint: Endpoint) -> Self {
        self.spec.transport = endpoint.transport();
        self.spec.listen = Some(endpoint);
        self
    }

    /// Add one externally started worker (`shard-worker --listen …`) to
    /// the fleet; call repeatedly for several.
    pub fn connect(mut self, endpoint: Endpoint) -> Self {
        self.spec.remotes.push(endpoint);
        self
    }

    /// Jobs allowed on one shard's socket at once (`0` derives
    /// `2 × workers_per_shard`).
    pub fn max_inflight_per_shard(mut self, window: usize) -> Self {
        self.spec.max_inflight_per_shard = window;
        self
    }

    /// Redial budget per shard (respawns for local shards, backoff
    /// redials for remote ones).
    pub fn max_redials_per_shard(mut self, budget: usize) -> Self {
        self.spec.max_redials_per_shard = budget;
        self
    }

    /// Bounded-admission capacity for the router queue (`0` derives
    /// `max(256, 8 × window × fleet)`).
    pub fn router_queue_capacity(mut self, capacity: usize) -> Self {
        self.spec.router_queue_capacity = capacity;
        self
    }

    /// Shard-side cadence for cache publication / telemetry frames.
    pub fn publish_interval(mut self, interval: Duration) -> Self {
        self.spec.publish_interval = interval;
        self
    }

    /// The `evosort` binary to spawn for local shards.
    pub fn binary(mut self, path: std::path::PathBuf) -> Self {
        self.spec.binary = Some(path);
        self
    }

    /// Turn on end-to-end tracing: per-job span events on every shard,
    /// streamed to the router and merged into one fleet timeline.
    pub fn trace(mut self, trace: bool) -> Self {
        self.spec.trace = trace;
        self
    }

    /// Append the merged trace timeline to a JSONL file (implies
    /// [`trace`](Self::trace)).
    pub fn trace_log(mut self, path: std::path::PathBuf) -> Self {
        self.spec.trace = true;
        self.spec.trace_log = Some(path);
        self
    }

    /// The assembled [`ShardSpec`] (for callers that want to inspect or
    /// tweak it before spawning).
    pub fn build(self) -> ShardSpec {
        self.spec
    }

    /// [`ShardedService::spawn`] on the assembled spec.
    pub fn spawn(self) -> Result<ShardedService> {
        ShardedService::spawn(self.spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_assembles_a_spec() {
        let spec = ShardedService::builder()
            .shards(3)
            .workers_per_shard(2)
            .sort_threads(4)
            .queue_capacity(32)
            .endpoint("tcp://127.0.0.1:0".parse().unwrap())
            .connect("tcp://10.1.2.3:7070".parse().unwrap())
            .connect("tcp://10.1.2.4:7070".parse().unwrap())
            .max_inflight_per_shard(6)
            .max_redials_per_shard(2)
            .router_queue_capacity(100)
            .publish_interval(Duration::from_millis(50))
            .build();
        assert_eq!(spec.shards, 3);
        assert_eq!(spec.workers_per_shard, 2);
        assert_eq!(spec.sort_threads, 4);
        assert_eq!(spec.queue_capacity, 32);
        assert_eq!(spec.transport, TransportKind::Tcp);
        assert_eq!(spec.listen.as_ref().unwrap().to_string(), "tcp://127.0.0.1:0");
        assert_eq!(spec.remotes.len(), 2);
        assert_eq!(spec.max_inflight_per_shard, 6);
        assert_eq!(spec.max_redials_per_shard, 2);
        assert_eq!(spec.router_queue_capacity, 100);
        assert_eq!(spec.publish_interval, Duration::from_millis(50));
    }

    #[test]
    fn endpoint_scheme_selects_the_transport() {
        let spec = ShardedService::builder()
            .transport(TransportKind::Tcp)
            .endpoint("unix:///tmp/evosort-fleet.sock".parse().unwrap())
            .build();
        // The endpoint's scheme wins over an earlier explicit transport.
        assert_eq!(spec.transport, TransportKind::Unix);
    }
}
