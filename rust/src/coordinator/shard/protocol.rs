//! The shard wire protocol: length-prefixed binary frames over any ordered
//! byte stream (Unix-domain sockets on one host, TCP across hosts — the
//! [`transport`](super::transport) seam picks; the frames are identical).
//!
//! The encoding is hand-rolled little-endian (no serde/bincode in the
//! offline build): every frame is `[tag: u8][len: u64 LE][payload]`, with
//! the payload layouts below. Writers use the `encode_*` helpers (each
//! returns one complete frame, so a single `write_all` under the
//! connection's writer mutex keeps frames from interleaving); readers use
//! [`read_frame`], which treats any I/O error — including EOF from a dead
//! peer — as a broken connection.
//!
//! Frames router → shard: [`Frame::Job`], [`Frame::CacheSync`],
//! [`Frame::Shutdown`]. Frames shard → router: [`Frame::JobDone`],
//! [`Frame::CachePublish`], [`Frame::Telemetry`], [`Frame::Trace`]. Cache
//! frames carry the versioned `# evosort-tuning-cache v4` text interchange
//! format ([`TuningCache::to_text`](crate::coordinator::TuningCache::to_text)),
//! so the wire and the disk speak the same dialect. Trace frames batch
//! [`TraceEvent`]s drained from the worker's ring; the router merges them
//! into its fleet-wide timeline, so one trace id spans every process that
//! touched the job — identically over Unix sockets and TCP.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::coordinator::request::SortRequest;
use crate::coordinator::ticket::{JobError, JobResult, SortOutput};
use crate::obs::{EventKind, FailReason, Phase, TraceEvent};
use crate::params::SortParams;
use crate::sort::{Dtype, SortPayload};

/// Upper bound on one frame's payload. A corrupt or hostile length prefix
/// must not drive a giant allocation; 4 GiB still fits any realistic job
/// this transport is asked to carry.
pub const MAX_FRAME_BYTES: u64 = 1 << 32;

/// Send-side bound for a *job* frame: stricter than [`MAX_FRAME_BYTES`] by a
/// headroom margin so the shard's JobDone reply (same payload plus a few
/// dozen bytes of metadata) can never trip the receive-side limit. The
/// router checks this before dispatch — an oversized job must fail its own
/// ticket, not poison-pill every shard it gets routed to.
pub const MAX_JOB_FRAME_BYTES: u64 = MAX_FRAME_BYTES - 4096;

const TAG_JOB: u8 = 1;
const TAG_JOB_DONE: u8 = 2;
const TAG_CACHE_PUBLISH: u8 = 3;
const TAG_CACHE_SYNC: u8 = 4;
const TAG_TELEMETRY: u8 = 5;
const TAG_SHUTDOWN: u8 = 6;
const TAG_TRACE: u8 = 7;

/// Cache accounting carried per completed job (the router mirrors the
/// in-process `params.*` counters from these).
pub const CACHE_FLAG_NONE: u8 = 0;
pub const CACHE_FLAG_HIT: u8 = 1;
pub const CACHE_FLAG_MISS: u8 = 2;

/// A decoded frame (the read side; the write side uses `encode_*`).
#[derive(Debug)]
pub enum Frame {
    /// Router → shard: execute one job. `id` is the router-level job id; the
    /// decoded [`SortOutput`] in the matching [`Frame::JobDone`] carries it.
    Job { id: u64, req: SortRequest },
    /// Shard → router: one job resolved.
    JobDone { id: u64, cache_flag: u8, result: JobResult },
    /// Shard → router: the shard's tuning cache changed; here is all of it.
    CachePublish { text: String },
    /// Router → shard: the merged service-level cache; absorb it.
    CacheSync { text: String },
    /// Shard → router: counter snapshot for per-shard aggregation.
    Telemetry { counters: Vec<(String, u64)> },
    /// Shard → router: a batch of span events drained from the worker's
    /// trace ring, for the router's fleet-wide timeline.
    Trace { events: Vec<TraceEvent> },
    /// Router → shard: drain and exit.
    Shutdown,
}

// --- primitive writers -----------------------------------------------------

fn put_u8(buf: &mut Vec<u8>, x: u8) {
    buf.push(x);
}

fn put_u32(buf: &mut Vec<u8>, x: u32) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, x: u64) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, x: f64) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u64(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

fn put_genes(buf: &mut Vec<u8>, p: &SortParams) {
    for g in p.to_genes() {
        buf.extend_from_slice(&g.to_le_bytes());
    }
}

fn dtype_code(d: Dtype) -> u8 {
    match d {
        Dtype::I64 => 0,
        Dtype::I32 => 1,
        Dtype::U64 => 2,
        Dtype::F64 => 3,
    }
}

/// Per-event wire layout inside a [`Frame::Trace`]: the fixed header
/// (trace id, shard, timestamp, kind tag) plus the kind's own fields.
/// Kind tags are wire-stable — append-only, like the frame tags.
fn put_event(buf: &mut Vec<u8>, ev: &TraceEvent) {
    put_u64(buf, ev.trace_id);
    put_u32(buf, ev.shard);
    put_u64(buf, ev.ts_micros);
    match &ev.kind {
        EventKind::Submitted => put_u8(buf, 0),
        EventKind::Queued => put_u8(buf, 1),
        EventKind::Dispatched { shard } => {
            put_u8(buf, 2);
            put_u32(buf, *shard);
        }
        EventKind::KernelPhase { phase, dur_secs } => {
            put_u8(buf, 3);
            put_u8(buf, phase.wire());
            put_f64(buf, *dur_secs);
        }
        EventKind::Completed { secs } => {
            put_u8(buf, 4);
            put_f64(buf, *secs);
        }
        EventKind::Failed { reason } => {
            put_u8(buf, 5);
            put_u8(buf, reason.wire());
        }
        EventKind::TunerPublished { fingerprint, params, fitness, improvement_pct } => {
            put_u8(buf, 6);
            put_str(buf, fingerprint);
            put_str(buf, params);
            put_f64(buf, *fitness);
            put_f64(buf, *improvement_pct);
        }
        EventKind::TunerRejected { fingerprint, reason } => {
            put_u8(buf, 7);
            put_str(buf, fingerprint);
            put_str(buf, reason);
        }
    }
}

fn put_payload(buf: &mut Vec<u8>, p: &SortPayload) {
    put_u8(buf, dtype_code(p.dtype()));
    put_u64(buf, p.len() as u64);
    match p {
        SortPayload::I64(v) => {
            buf.reserve(v.len() * 8);
            for &x in v {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        SortPayload::I32(v) => {
            buf.reserve(v.len() * 4);
            for &x in v {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        SortPayload::U64(v) => {
            buf.reserve(v.len() * 8);
            for &x in v {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        SortPayload::F64(v) => {
            buf.reserve(v.len() * 8);
            for &x in v {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
}

// --- primitive reader ------------------------------------------------------

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len());
        let Some(end) = end else { bail!("truncated frame (wanted {n} bytes at {})", self.pos) };
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u64()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).context("non-utf8 string in frame")
    }

    fn genes(&mut self) -> Result<SortParams> {
        let mut genes = [0i64; 6];
        for g in genes.iter_mut() {
            *g = i64::from_le_bytes(self.take(8)?.try_into().unwrap());
        }
        Ok(SortParams::from_genes(&genes))
    }

    fn event(&mut self) -> Result<TraceEvent> {
        let trace_id = self.u64()?;
        let shard = self.u32()?;
        let ts_micros = self.u64()?;
        let kind = match self.u8()? {
            0 => EventKind::Submitted,
            1 => EventKind::Queued,
            2 => EventKind::Dispatched { shard: self.u32()? },
            3 => {
                let phase = Phase::from_wire(self.u8()?).context("unknown kernel phase code")?;
                EventKind::KernelPhase { phase, dur_secs: self.f64()? }
            }
            4 => EventKind::Completed { secs: self.f64()? },
            5 => EventKind::Failed {
                reason: FailReason::from_wire(self.u8()?).context("unknown fail-reason code")?,
            },
            6 => EventKind::TunerPublished {
                fingerprint: self.str()?.into_boxed_str(),
                params: self.str()?.into_boxed_str(),
                fitness: self.f64()?,
                improvement_pct: self.f64()?,
            },
            7 => EventKind::TunerRejected {
                fingerprint: self.str()?.into_boxed_str(),
                reason: self.str()?.into_boxed_str(),
            },
            other => bail!("unknown trace-event kind {other}"),
        };
        Ok(TraceEvent { trace_id, shard, ts_micros, kind })
    }

    fn payload(&mut self) -> Result<SortPayload> {
        let code = self.u8()?;
        let n = self.u64()? as usize;
        let width = if code == 1 { 4 } else { 8 };
        // Validate against the remaining bytes before allocating n elements.
        let raw = self.take(n.checked_mul(width).context("payload length overflow")?)?;
        Ok(match code {
            0 => SortPayload::I64(
                raw.chunks_exact(8).map(|c| i64::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
            1 => SortPayload::I32(
                raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
            2 => SortPayload::U64(
                raw.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
            3 => SortPayload::F64(
                raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
            other => bail!("unknown payload dtype code {other}"),
        })
    }

    fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("{} trailing bytes in frame", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

// --- frame encoders --------------------------------------------------------

fn frame(tag: u8, payload: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(9 + payload.len());
    out.push(tag);
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Encode a [`Frame::Job`].
pub fn encode_job(id: u64, req: &SortRequest) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64 + req.len() * 8);
    put_u64(&mut buf, id);
    put_str(&mut buf, &req.dist);
    match &req.params {
        Some(p) => {
            put_u8(&mut buf, 1);
            put_genes(&mut buf, p);
        }
        None => put_u8(&mut buf, 0),
    }
    put_u8(&mut buf, req.validate as u8);
    put_payload(&mut buf, req.payload());
    frame(TAG_JOB, buf)
}

/// Encode a [`Frame::JobDone`].
pub fn encode_job_done(id: u64, cache_flag: u8, result: &JobResult) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64);
    put_u64(&mut buf, id);
    put_u8(&mut buf, cache_flag);
    match result {
        Ok(out) => {
            put_u8(&mut buf, 0);
            put_f64(&mut buf, out.secs);
            put_u8(&mut buf, out.valid as u8);
            put_genes(&mut buf, &out.params);
            put_payload(&mut buf, &out.payload);
        }
        Err(JobError::Cancelled) => put_u8(&mut buf, 1),
        Err(JobError::WorkerLost) => put_u8(&mut buf, 2),
        Err(JobError::Overloaded) => put_u8(&mut buf, 3),
    }
    frame(TAG_JOB_DONE, buf)
}

/// Encode a [`Frame::CachePublish`] (shard → router).
pub fn encode_cache_publish(text: &str) -> Vec<u8> {
    let mut buf = Vec::with_capacity(16 + text.len());
    put_str(&mut buf, text);
    frame(TAG_CACHE_PUBLISH, buf)
}

/// Encode a [`Frame::CacheSync`] (router → shard).
pub fn encode_cache_sync(text: &str) -> Vec<u8> {
    let mut buf = Vec::with_capacity(16 + text.len());
    put_str(&mut buf, text);
    frame(TAG_CACHE_SYNC, buf)
}

/// Encode a [`Frame::Telemetry`].
pub fn encode_telemetry(counters: &[(String, u64)]) -> Vec<u8> {
    let mut buf = Vec::new();
    put_u64(&mut buf, counters.len() as u64);
    for (name, value) in counters {
        put_str(&mut buf, name);
        put_u64(&mut buf, *value);
    }
    frame(TAG_TELEMETRY, buf)
}

/// Encode a [`Frame::Trace`] (shard → router).
pub fn encode_trace(events: &[TraceEvent]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(8 + events.len() * 32);
    put_u64(&mut buf, events.len() as u64);
    for ev in events {
        put_event(&mut buf, ev);
    }
    frame(TAG_TRACE, buf)
}

/// Encode a [`Frame::Shutdown`].
pub fn encode_shutdown() -> Vec<u8> {
    frame(TAG_SHUTDOWN, Vec::new())
}

/// Write one pre-encoded frame. Callers serialize writes per connection
/// (frames from concurrent writers must not interleave mid-frame).
pub fn write_frame<W: Write>(w: &mut W, bytes: &[u8]) -> std::io::Result<()> {
    w.write_all(bytes)?;
    w.flush()
}

// --- frame decoder ---------------------------------------------------------

/// Read and decode one frame. Any error — I/O (including EOF from a dead
/// peer), a hostile length prefix, or a malformed payload — means the
/// connection is unusable and the caller should treat the peer as lost.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame> {
    let mut head = [0u8; 9];
    r.read_exact(&mut head).context("reading frame header")?;
    let tag = head[0];
    let len = u64::from_le_bytes(head[1..9].try_into().unwrap());
    if len > MAX_FRAME_BYTES {
        bail!("frame payload of {len} bytes exceeds the {MAX_FRAME_BYTES}-byte bound");
    }
    // Grow the buffer as bytes actually arrive instead of trusting the
    // length prefix with one up-front allocation: a hostile-but-in-bounds
    // prefix (say 3 GiB) followed by a closed connection must cost an error,
    // not a 3 GiB allocation. `take` bounds the read; an honest peer's
    // frame reads to exactly `len`.
    let mut payload = Vec::with_capacity((len as usize).min(1 << 20));
    let read = r
        .by_ref()
        .take(len)
        .read_to_end(&mut payload)
        .context("reading frame payload")?;
    if (read as u64) < len {
        bail!("truncated frame payload: got {read} of {len} bytes");
    }
    decode(tag, &payload)
}

fn decode(tag: u8, payload: &[u8]) -> Result<Frame> {
    let mut d = Dec::new(payload);
    let frame = match tag {
        TAG_JOB => {
            let id = d.u64()?;
            let dist = d.str()?;
            let params = match d.u8()? {
                0 => None,
                _ => Some(d.genes()?),
            };
            let validate = d.u8()? != 0;
            let payload = d.payload()?;
            // The wire does not carry a trace id: the worker stamps the
            // frame's router-level `id` as the trace id at execution time.
            Frame::Job { id, req: SortRequest { payload, dist, params, validate, trace_id: None } }
        }
        TAG_JOB_DONE => {
            let id = d.u64()?;
            let cache_flag = d.u8()?;
            let result = match d.u8()? {
                0 => {
                    let secs = d.f64()?;
                    let valid = d.u8()? != 0;
                    let params = d.genes()?;
                    let payload = d.payload()?;
                    Ok(SortOutput { id, payload, params, secs, valid })
                }
                1 => Err(JobError::Cancelled),
                2 => Err(JobError::WorkerLost),
                3 => Err(JobError::Overloaded),
                other => bail!("unknown job status code {other}"),
            };
            Frame::JobDone { id, cache_flag, result }
        }
        TAG_CACHE_PUBLISH => Frame::CachePublish { text: d.str()? },
        TAG_CACHE_SYNC => Frame::CacheSync { text: d.str()? },
        TAG_TELEMETRY => {
            let n = d.u64()? as usize;
            // Every entry takes at least 16 bytes (name length + value), so
            // a count beyond payload/16 is corruption — and the bound keeps
            // the Vec::with_capacity below proportional to the actual frame
            // instead of a hostile 32-bytes-per-claimed-entry reserve.
            if n > payload.len() / 16 {
                bail!("telemetry count {n} exceeds frame size");
            }
            let mut counters = Vec::with_capacity(n);
            for _ in 0..n {
                let name = d.str()?;
                let value = d.u64()?;
                counters.push((name, value));
            }
            Frame::Telemetry { counters }
        }
        TAG_TRACE => {
            let n = d.u64()? as usize;
            // Every event takes at least 21 bytes (header + kind tag), so a
            // count past payload/21 is corruption — same reserve-bounding
            // rationale as the telemetry arm.
            if n > payload.len() / 21 {
                bail!("trace-event count {n} exceeds frame size");
            }
            let mut events = Vec::with_capacity(n);
            for _ in 0..n {
                events.push(d.event()?);
            }
            Frame::Trace { events }
        }
        TAG_SHUTDOWN => Frame::Shutdown,
        other => bail!("unknown frame tag {other}"),
    };
    d.finish()?;
    Ok(frame)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(bytes: Vec<u8>) -> Frame {
        let mut cursor = std::io::Cursor::new(bytes);
        let frame = read_frame(&mut cursor).expect("decode");
        assert_eq!(cursor.position() as usize, cursor.get_ref().len(), "frame fully consumed");
        frame
    }

    #[test]
    fn job_roundtrip_all_dtypes_and_knobs() {
        let payloads = [
            SortPayload::I64(vec![3, -1, i64::MAX, i64::MIN]),
            SortPayload::I32(vec![7, -9, i32::MAX]),
            SortPayload::U64(vec![0, u64::MAX]),
            SortPayload::F64(vec![2.5, -0.0, f64::NAN, f64::NEG_INFINITY]),
        ];
        for payload in payloads {
            let req = SortRequest::from_payload(payload.clone())
                .with_dist("zipf")
                .with_params(SortParams::paper_1e7())
                .without_validation();
            let Frame::Job { id, req: back } = roundtrip(encode_job(42, &req)) else {
                panic!("expected Job frame");
            };
            assert_eq!(id, 42);
            assert_eq!(back.dist, "zipf");
            assert_eq!(back.params, Some(SortParams::paper_1e7()));
            assert!(!back.validate);
            // NaN payloads compare bit-exact through the canonical-bit check
            // below, not PartialEq.
            assert_eq!(back.payload().dtype(), payload.dtype());
            assert_eq!(back.payload().len(), payload.len());
            if let (SortPayload::F64(a), SortPayload::F64(b)) = (back.payload(), &payload) {
                assert!(a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()));
            } else {
                assert_eq!(back.payload(), &payload);
            }
        }
    }

    #[test]
    fn job_roundtrip_default_knobs() {
        let req = SortRequest::new(vec![5i64, 1]);
        let Frame::Job { req: back, .. } = roundtrip(encode_job(1, &req)) else {
            panic!("expected Job frame");
        };
        assert_eq!(back.dist, "uniform");
        assert_eq!(back.params, None);
        assert!(back.validate);
        assert_eq!(back.payload().as_slice::<i64>(), Some(&[5i64, 1][..]));
    }

    #[test]
    fn job_done_roundtrip_rewrites_router_id() {
        let out = SortOutput {
            id: 999, // the shard's local id — the wire carries the router's
            payload: SortPayload::U64(vec![1, 2, 3]),
            params: SortParams::paper_1e8(),
            secs: 0.0125,
            valid: true,
        };
        let bytes = encode_job_done(7, CACHE_FLAG_HIT, &Ok(out));
        let Frame::JobDone { id, cache_flag, result } = roundtrip(bytes) else {
            panic!("expected JobDone");
        };
        assert_eq!(id, 7);
        assert_eq!(cache_flag, CACHE_FLAG_HIT);
        let out = result.expect("ok result");
        assert_eq!(out.id, 7, "decoded output carries the router-level id");
        assert_eq!(out.params, SortParams::paper_1e8());
        assert!((out.secs - 0.0125).abs() < 1e-12);
        assert!(out.valid);
        assert_eq!(out.data::<u64>().unwrap(), &[1, 2, 3]);
    }

    #[test]
    fn job_done_error_roundtrip() {
        for (err, _) in [
            (JobError::Cancelled, 1u8),
            (JobError::WorkerLost, 2u8),
            (JobError::Overloaded, 3u8),
        ] {
            let bytes = encode_job_done(3, CACHE_FLAG_NONE, &Err(err));
            let Frame::JobDone { id, result, .. } = roundtrip(bytes) else {
                panic!("expected JobDone");
            };
            assert_eq!(id, 3);
            assert_eq!(result.unwrap_err(), err);
        }
    }

    #[test]
    fn cache_and_telemetry_and_shutdown_roundtrip() {
        let Frame::CachePublish { text } = roundtrip(encode_cache_publish("v2 body\n")) else {
            panic!("expected CachePublish");
        };
        assert_eq!(text, "v2 body\n");
        let Frame::CacheSync { text } = roundtrip(encode_cache_sync("merged\n")) else {
            panic!("expected CacheSync");
        };
        assert_eq!(text, "merged\n");
        let counters = vec![("tuner.publishes".to_string(), 3u64), ("jobs".to_string(), 17)];
        let Frame::Telemetry { counters: back } = roundtrip(encode_telemetry(&counters)) else {
            panic!("expected Telemetry");
        };
        assert_eq!(back, counters);
        assert!(matches!(roundtrip(encode_shutdown()), Frame::Shutdown));
    }

    #[test]
    fn trace_roundtrip_every_event_kind() {
        let events = vec![
            TraceEvent { trace_id: 1, shard: 0, ts_micros: 100, kind: EventKind::Submitted },
            TraceEvent { trace_id: 1, shard: 0, ts_micros: 101, kind: EventKind::Queued },
            TraceEvent {
                trace_id: 1,
                shard: u32::MAX,
                ts_micros: 102,
                kind: EventKind::Dispatched { shard: 3 },
            },
            TraceEvent {
                trace_id: 1,
                shard: 3,
                ts_micros: 103,
                kind: EventKind::KernelPhase { phase: Phase::RadixScatter, dur_secs: 0.25 },
            },
            TraceEvent {
                trace_id: 1,
                shard: 3,
                ts_micros: 104,
                kind: EventKind::Completed { secs: 0.5 },
            },
            TraceEvent {
                trace_id: 2,
                shard: 3,
                ts_micros: 105,
                kind: EventKind::Failed { reason: FailReason::Overloaded },
            },
            TraceEvent {
                trace_id: 0,
                shard: 3,
                ts_micros: 106,
                kind: EventKind::TunerPublished {
                    fingerprint: "b10:mix:uniq:w8:pm".into(),
                    params: "tile=4096".into(),
                    fitness: 0.004,
                    improvement_pct: 12.5,
                },
            },
            TraceEvent {
                trace_id: 0,
                shard: 3,
                ts_micros: 107,
                kind: EventKind::TunerRejected {
                    fingerprint: "b10:mix:uniq:w8:pm".into(),
                    reason: "below_margin".into(),
                },
            },
        ];
        let Frame::Trace { events: back } = roundtrip(encode_trace(&events)) else {
            panic!("expected Trace frame");
        };
        assert_eq!(back, events);
        // Empty batches are legal (idle ticker flush).
        let Frame::Trace { events: back } = roundtrip(encode_trace(&[])) else {
            panic!("expected Trace frame");
        };
        assert!(back.is_empty());
    }

    #[test]
    fn corrupt_trace_frames_error() {
        // Hostile event count.
        let mut inner = Vec::new();
        put_u64(&mut inner, u64::MAX);
        assert!(read_frame(&mut std::io::Cursor::new(frame(TAG_TRACE, inner))).is_err());
        // Unknown kind tag.
        let mut inner = Vec::new();
        put_u64(&mut inner, 1);
        put_u64(&mut inner, 1); // trace id
        put_u32(&mut inner, 0); // shard
        put_u64(&mut inner, 5); // ts
        put_u8(&mut inner, 99); // bogus kind
        assert!(read_frame(&mut std::io::Cursor::new(frame(TAG_TRACE, inner))).is_err());
        // Unknown phase code inside a kernel-phase event.
        let mut inner = Vec::new();
        put_u64(&mut inner, 1);
        put_u64(&mut inner, 1);
        put_u32(&mut inner, 0);
        put_u64(&mut inner, 5);
        put_u8(&mut inner, 3); // KernelPhase
        put_u8(&mut inner, 200); // bogus phase
        put_f64(&mut inner, 0.1);
        assert!(read_frame(&mut std::io::Cursor::new(frame(TAG_TRACE, inner))).is_err());
    }

    #[test]
    fn corrupt_frames_error_instead_of_allocating() {
        // Hostile length prefix.
        let mut bytes = vec![TAG_SHUTDOWN];
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        assert!(read_frame(&mut std::io::Cursor::new(bytes)).is_err());
        // Unknown tag.
        let mut bytes = vec![250u8];
        bytes.extend_from_slice(&0u64.to_le_bytes());
        assert!(read_frame(&mut std::io::Cursor::new(bytes)).is_err());
        // Truncated payload.
        let good = encode_job(1, &SortRequest::new(vec![1i64, 2, 3]));
        let clipped = good[..good.len() - 4].to_vec();
        assert!(read_frame(&mut std::io::Cursor::new(clipped)).is_err());
        // Trailing garbage inside a frame payload.
        let mut inner = Vec::new();
        put_u64(&mut inner, 0); // telemetry count 0
        put_u8(&mut inner, 99); // trailing byte
        let framed = frame(TAG_TELEMETRY, inner);
        assert!(read_frame(&mut std::io::Cursor::new(framed)).is_err());
        // EOF mid-header.
        assert!(read_frame(&mut std::io::Cursor::new(vec![TAG_JOB])).is_err());
        // In-bounds hostile prefix (2 GiB claimed, nothing sent): the
        // incremental read errors out having allocated only for the bytes
        // that actually arrived, instead of reserving 2 GiB up front.
        let mut bytes = vec![TAG_TELEMETRY];
        bytes.extend_from_slice(&(2u64 << 30).to_le_bytes());
        bytes.extend_from_slice(b"tiny");
        let err = read_frame(&mut std::io::Cursor::new(bytes)).unwrap_err();
        assert!(err.to_string().contains("truncated frame payload"), "{err}");
    }

    #[test]
    fn frames_decode_sequentially_from_one_stream() {
        let mut stream = Vec::new();
        stream.extend_from_slice(&encode_job(1, &SortRequest::new(vec![9i64])));
        stream.extend_from_slice(&encode_telemetry(&[("a".into(), 1)]));
        stream.extend_from_slice(&encode_shutdown());
        let mut cursor = std::io::Cursor::new(stream);
        assert!(matches!(read_frame(&mut cursor).unwrap(), Frame::Job { id: 1, .. }));
        assert!(matches!(read_frame(&mut cursor).unwrap(), Frame::Telemetry { .. }));
        assert!(matches!(read_frame(&mut cursor).unwrap(), Frame::Shutdown));
        assert!(read_frame(&mut cursor).is_err(), "EOF after the last frame");
    }
}
