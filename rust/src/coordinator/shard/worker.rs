//! The shard-worker side of the cross-process service: one OS process, one
//! [`SortService`] (autotuner included), one stream connection back to the
//! router (Unix socket or TCP — see [`transport`](super::transport)).
//!
//! The main thread reads frames off the socket: each [`Frame::Job`] is
//! submitted to the local service (blocking only on the pool's backpressure,
//! which propagates to the router through the socket buffer) and its
//! [`Ticket`] handed to a small pool of collector threads that park on the
//! tickets and write [`Frame::JobDone`] replies back — so a slow job never
//! blocks the read loop and results flow out as they finish. A ticker
//! thread watches the local [`TuningCache`]'s version counter and, whenever
//! it changed from *local* tuning (router-sync absorbs are discounted, so
//! broadcasts are not echoed back), publishes the whole cache (v2 text
//! interchange) to the router, alongside a counter-snapshot telemetry frame
//! each tick; incoming
//! [`Frame::CacheSync`] broadcasts are absorbed improvement-aware, so a
//! class tuned on any shard speeds this one up without ever clobbering a
//! better locally-tuned entry.
//!
//! Entry points, by who owns the connection's lifecycle:
//!
//! * [`run`] — **dial the router** (local shards: the router listens, the
//!   child it spawned connects back — `shard-worker --connect`);
//! * [`run_listening`] — **be dialed** (remote shards: a standalone
//!   `shard-worker --listen` on another host accepts a router, serves it,
//!   and when the router disconnects goes *back to listening* so the
//!   router's redial finds a live worker; only an explicit
//!   [`Frame::Shutdown`] ends the process);
//! * [`run_on_stream`] — an already-connected stream (tests use pairs),
//!   returning [`ExitReason`] so callers can tell a deliberate stop from a
//!   lost router.

use std::io::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::endpoint::Endpoint;
use crate::coordinator::metrics::names;
use crate::coordinator::service::{self, ServiceConfig, SortService};
use crate::coordinator::shard::protocol::{self, Frame};
use crate::coordinator::shard::transport::{Listener, Stream};
use crate::coordinator::ticket::Ticket;
use crate::coordinator::tuning_cache::TuningCache;
use crate::obs::{Tracer, DEFAULT_RING_CAPACITY};

/// Everything a shard-worker process needs besides its socket.
#[derive(Clone)]
pub struct ShardWorkerConfig {
    /// This shard's index (diagnostics only — routing is the router's job).
    pub shard_id: usize,
    /// The local service: workers, sort threads, queue bound, autotuner.
    pub service: ServiceConfig,
    /// How often the ticker checks for cache changes and ships telemetry.
    pub publish_interval: Duration,
    /// Emit per-job trace events and stream them back to the router
    /// ([`Frame::Trace`] batches on the telemetry tick). Off by default:
    /// a disabled tracer is a branch on the sort hot path, not a call.
    pub trace: bool,
}

/// Why [`run_on_stream`] returned: an explicit [`Frame::Shutdown`] from the
/// router, or a lost/poisoned connection (EOF, I/O error, hostile frame).
/// A listening worker re-listens after `Disconnected` and exits only on
/// `Shutdown`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExitReason {
    Shutdown,
    Disconnected,
}

/// Dial the router's listener and serve until it says stop (the spawned
/// child side of a local shard).
pub fn run(endpoint: &Endpoint, config: ShardWorkerConfig) -> Result<()> {
    let id = config.shard_id;
    let stream =
        Stream::connect(endpoint).with_context(|| format!("shard {id} dialing the router"))?;
    run_on_stream(stream, config)?;
    Ok(())
}

/// Listen on `endpoint` and serve routers one at a time (the standalone
/// remote-worker mode: `shard-worker --listen tcp://0.0.0.0:7001`).
///
/// Announces the *resolved* address on stdout (`listening on tcp://…`) so
/// `--listen tcp://127.0.0.1:0` is scriptable; after a router disconnects
/// — crash, network drop, or router restart — the worker returns to
/// `accept`, which is the worker half of the router's redial contract. The
/// local [`SortService`] is rebuilt per connection; the router re-seeds a
/// freshly accepted worker with the fleet's merged tuning cache.
pub fn run_listening(endpoint: &Endpoint, config: ShardWorkerConfig) -> Result<()> {
    let listener =
        Listener::bind(endpoint).with_context(|| format!("shard-worker listening on {endpoint}"))?;
    let bound = listener.local_endpoint()?;
    println!("shard-worker listening on {bound}");
    let _ = std::io::stdout().flush();
    loop {
        let stream = listener.accept().context("accepting a router connection")?;
        crate::log_debug!("shard-worker: router connected on {bound}");
        match run_on_stream(stream, config.clone())? {
            ExitReason::Shutdown => return Ok(()),
            ExitReason::Disconnected => {
                crate::log_debug!("shard-worker: router disconnected; listening again");
            }
        }
    }
}

/// Serve an already-connected router stream (see the module docs).
pub fn run_on_stream(stream: Stream, config: ShardWorkerConfig) -> Result<ExitReason> {
    let ShardWorkerConfig { shard_id, service: svc_config, publish_interval, trace } = config;
    let collector_count = svc_config.workers.max(1);
    let tracer = if trace {
        Tracer::enabled(DEFAULT_RING_CAPACITY, shard_id as u32)
    } else {
        Tracer::disabled()
    };
    let svc = SortService::new_traced(svc_config, tracer.clone());
    let cache = Arc::clone(svc.cache());
    let metrics = Arc::clone(svc.metrics());
    let writer = Arc::new(Mutex::new(stream.try_clone().context("cloning shard socket")?));
    let mut reader = stream;

    // Collectors: park on tickets, forward JobDone frames. Handing tickets
    // through a channel (instead of waiting inline in the read loop) keeps
    // job intake flowing while sorts run, and `collector_count == workers`
    // bounds head-of-line blocking at the service's own concurrency.
    let (ticket_tx, ticket_rx) = mpsc::channel::<(u64, u8, Ticket)>();
    let ticket_rx = Arc::new(Mutex::new(ticket_rx));
    let collectors: Vec<_> = (0..collector_count)
        .map(|i| {
            let ticket_rx = Arc::clone(&ticket_rx);
            let writer = Arc::clone(&writer);
            std::thread::Builder::new()
                .name(format!("evosort-shard{shard_id}-collect{i}"))
                .spawn(move || loop {
                    // The guard is held across recv: collectors hand off
                    // jobs one at a time but wait on their tickets (the slow
                    // part) concurrently.
                    let next = ticket_rx.lock().unwrap_or_else(|e| e.into_inner()).recv();
                    let Ok((id, cache_flag, ticket)) = next else { break };
                    let result = ticket.wait();
                    let bytes = protocol::encode_job_done(id, cache_flag, &result);
                    let mut w = writer.lock().unwrap_or_else(|e| e.into_inner());
                    if protocol::write_frame(&mut *w, &bytes).is_err() {
                        break; // router gone: nothing left to report to
                    }
                })
                .expect("spawn shard collector")
        })
        .collect();

    // Ticker: cache publication (on local change) + telemetry (every tick).
    // Version bumps caused by absorbing a router CacheSync are discounted
    // (`sync_bumps` — each changing absorb bumps the version by exactly 1),
    // so a broadcast does not make every shard echo the merged cache
    // straight back to the router as a no-op publish.
    let stop = Arc::new(AtomicBool::new(false));
    let sync_bumps = Arc::new(AtomicU64::new(0));
    let ticker = {
        let stop = Arc::clone(&stop);
        let sync_bumps = Arc::clone(&sync_bumps);
        let cache = Arc::clone(&cache);
        let metrics = Arc::clone(&metrics);
        let writer = Arc::clone(&writer);
        let tracer = tracer.clone();
        std::thread::Builder::new()
            .name(format!("evosort-shard{shard_id}-ticker"))
            .spawn(move || {
                let mut last_local = cache.version();
                let mut events = Vec::new();
                'ticks: loop {
                    // Sleep in slices so shutdown stays snappy.
                    let mut slept = Duration::ZERO;
                    while slept < publish_interval {
                        if stop.load(Ordering::Relaxed) {
                            break 'ticks;
                        }
                        let slice = (publish_interval - slept).min(Duration::from_millis(25));
                        std::thread::sleep(slice);
                        slept += slice;
                    }
                    let local =
                        cache.version().wrapping_sub(sync_bumps.load(Ordering::Relaxed));
                    if local != last_local {
                        last_local = local;
                        let bytes = protocol::encode_cache_publish(&cache.to_text());
                        let mut w = writer.lock().unwrap_or_else(|e| e.into_inner());
                        if protocol::write_frame(&mut *w, &bytes).is_err() {
                            break;
                        }
                    }
                    // Trace events ride the same tick: drain the ring into a
                    // Frame::Trace batch so the router can merge this shard's
                    // stream into the fleet timeline. Ring-full drops surface
                    // as the trace.dropped counter in the telemetry frame.
                    if tracer.is_enabled() {
                        let dropped = tracer.take_dropped();
                        if dropped > 0 {
                            metrics.add(names::TRACE_DROPPED, dropped);
                        }
                        events.clear();
                        tracer.drain_into(&mut events);
                        if !events.is_empty() {
                            let bytes = protocol::encode_trace(&events);
                            let mut w = writer.lock().unwrap_or_else(|e| e.into_inner());
                            if protocol::write_frame(&mut *w, &bytes).is_err() {
                                break;
                            }
                        }
                    }
                    let mut counters = metrics.counters_snapshot();
                    counters.push((names::CACHE_ENTRIES.to_string(), cache.len() as u64));
                    let bytes = protocol::encode_telemetry(&counters);
                    let mut w = writer.lock().unwrap_or_else(|e| e.into_inner());
                    if protocol::write_frame(&mut *w, &bytes).is_err() {
                        break;
                    }
                }
            })
            .expect("spawn shard ticker")
    };

    // Main loop: intake.
    let reason = loop {
        match protocol::read_frame(&mut reader) {
            Ok(Frame::Job { id, req }) => {
                // Peek the cache outcome before submission so the reply can
                // carry service-level hit/miss accounting. The authoritative
                // resolve happens inside the service on the same label, so
                // the fingerprint sketch runs twice per job — a deliberate
                // trade-off: the sketch samples ≤ 1024 elements (noise next
                // to the sort itself), and the alternative is threading a
                // resolve-outcome field through the public SortOutput. A
                // tuner publish landing between peek and resolve can skew
                // one job's flag; the counters are accounting, not control.
                let cache_flag = if req.params.is_some() {
                    protocol::CACHE_FLAG_NONE
                } else {
                    let label = service::payload_label(req.payload());
                    if cache.get(req.len(), &label).is_some() {
                        protocol::CACHE_FLAG_HIT
                    } else {
                        protocol::CACHE_FLAG_MISS
                    }
                };
                // Stamp the router's frame id as the trace id so this
                // shard's span events merge with the router's under one
                // timeline key.
                let ticket = svc.submit_request(req.with_trace_id(id));
                if ticket_tx.send((id, cache_flag, ticket)).is_err() {
                    break ExitReason::Disconnected; // every collector died (router gone)
                }
            }
            Ok(Frame::CacheSync { text }) => {
                let absorbed = cache.absorb(&TuningCache::from_text(&text));
                if absorbed > 0 {
                    sync_bumps.fetch_add(1, Ordering::Relaxed);
                    metrics.add(names::SHARD_CACHE_ABSORBED, absorbed as u64);
                    crate::log_debug!(
                        "shard {shard_id}: absorbed {absorbed} broadcast cache entries"
                    );
                }
            }
            Ok(Frame::Shutdown) => break ExitReason::Shutdown,
            Ok(_) => {} // frames for the other direction: ignore
            Err(_) => break ExitReason::Disconnected, // router gone or hostile frame
        }
    };

    // Drain: collectors finish the tickets already handed out, then exit on
    // the closed channel; the service drop joins pool + tuner.
    drop(ticket_tx);
    for c in collectors {
        let _ = c.join();
    }
    stop.store(true, Ordering::Relaxed);
    let _ = ticker.join();
    // Final trace drain: terminal events for the last tickets resolved after
    // the ticker's last tick would otherwise strand in the ring. Best-effort
    // — on Disconnected the write just fails.
    if tracer.is_enabled() {
        let mut events = Vec::new();
        tracer.drain_into(&mut events);
        if !events.is_empty() {
            let bytes = protocol::encode_trace(&events);
            let mut w = writer.lock().unwrap_or_else(|e| e.into_inner());
            let _ = protocol::write_frame(&mut *w, &bytes);
        }
    }
    drop(svc);
    Ok(reason)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SortRequest;
    use crate::coordinator::shard::protocol::{
        encode_cache_sync, encode_job, encode_shutdown, read_frame, write_frame,
    };
    use crate::data::{generate_i64, Distribution};
    use crate::params::SortParams;
    use std::collections::HashMap;
    use std::os::unix::net::UnixStream;

    fn quick_config() -> ShardWorkerConfig {
        ShardWorkerConfig {
            shard_id: 0,
            service: ServiceConfig::sized(2, 2, 8),
            publish_interval: Duration::from_millis(30),
            trace: false,
        }
    }

    #[test]
    fn worker_sorts_jobs_and_absorbs_cache_over_a_socketpair() {
        let (router_side, worker_side) = UnixStream::pair().expect("socketpair");
        let worker =
            std::thread::spawn(move || run_on_stream(Stream::Unix(worker_side), quick_config()));
        let mut reader = router_side.try_clone().expect("clone");
        let mut writer = router_side;

        // Two jobs, ids chosen by the "router".
        let data = generate_i64(40_000, Distribution::Uniform, 7, 2);
        let mut expect = data.clone();
        expect.sort_unstable();
        write_frame(&mut writer, &encode_job(10, &SortRequest::new(data))).unwrap();
        write_frame(&mut writer, &encode_job(11, &SortRequest::new(vec![3.5f64, -1.0]))).unwrap();

        let mut done = HashMap::new();
        while done.len() < 2 {
            match read_frame(&mut reader).expect("frame") {
                Frame::JobDone { id, cache_flag, result } => {
                    done.insert(id, (cache_flag, result));
                }
                _ => {} // telemetry ticks interleave freely
            }
        }
        let (flag, result) = done.remove(&10).expect("job 10 reported");
        assert_eq!(flag, protocol::CACHE_FLAG_MISS, "cold cache");
        let out = result.expect("job ok");
        assert_eq!(out.id, 10);
        assert!(out.valid);
        assert_eq!(out.data::<i64>().unwrap(), &expect[..]);
        let (_, result) = done.remove(&11).expect("job 11 reported");
        assert_eq!(result.expect("job ok").data::<f64>().unwrap(), &[-1.0, 3.5]);

        // A CacheSync lands in the worker's live cache, observable through
        // the cache.entries telemetry counter.
        let broadcast = TuningCache::new();
        broadcast.put(40_000, "b9:mix:uniq:w4:pm", SortParams::paper_1e7());
        write_frame(&mut writer, &encode_cache_sync(&broadcast.to_text())).unwrap();
        let mut entries_seen = 0u64;
        for _ in 0..400 {
            if let Frame::Telemetry { counters } = read_frame(&mut reader).expect("frame") {
                if let Some((_, v)) = counters.iter().find(|(k, _)| k == names::CACHE_ENTRIES) {
                    entries_seen = *v;
                    if entries_seen >= 1 {
                        break;
                    }
                }
            }
        }
        assert_eq!(entries_seen, 1, "broadcast entry must land in the shard cache");

        write_frame(&mut writer, &encode_shutdown()).unwrap();
        let reason = worker.join().expect("worker thread").expect("worker run");
        assert_eq!(reason, ExitReason::Shutdown, "an explicit Shutdown frame is deliberate");
    }

    #[test]
    fn traced_worker_streams_span_events_stamped_with_the_frame_id() {
        let (router_side, worker_side) = UnixStream::pair().expect("socketpair");
        let mut config = quick_config();
        config.shard_id = 3;
        config.trace = true;
        let worker = std::thread::spawn(move || run_on_stream(Stream::Unix(worker_side), config));
        let mut reader = router_side.try_clone().expect("clone");
        let mut writer = router_side;

        let data = generate_i64(50_000, Distribution::Uniform, 11, 2);
        write_frame(&mut writer, &encode_job(42, &SortRequest::new(data))).unwrap();

        // Trace batches ride the telemetry tick; collect until the span for
        // frame id 42 is complete (Submitted .. Completed).
        let mut events = Vec::new();
        let mut done = false;
        while !(done
            && events.iter().any(|e: &crate::obs::TraceEvent| {
                e.trace_id == 42 && e.kind.name() == "completed"
            }))
        {
            match read_frame(&mut reader).expect("frame") {
                Frame::JobDone { id, result, .. } => {
                    assert_eq!(id, 42);
                    result.expect("job ok");
                    done = true;
                }
                Frame::Trace { events: batch } => events.extend(batch),
                _ => {}
            }
        }
        for name in ["submitted", "queued", "dispatched", "kernel_phase", "completed"] {
            assert!(
                events.iter().any(|e| e.trace_id == 42 && e.kind.name() == name),
                "span chain for frame 42 is missing a {name} event"
            );
        }
        assert!(
            events.iter().all(|e| e.shard == 3),
            "every event must carry the worker's shard id"
        );

        write_frame(&mut writer, &encode_shutdown()).unwrap();
        let reason = worker.join().expect("worker thread").expect("worker run");
        assert_eq!(reason, ExitReason::Shutdown);
    }

    #[test]
    fn worker_exits_cleanly_when_the_router_vanishes() {
        let (router_side, worker_side) = UnixStream::pair().expect("socketpair");
        let worker =
            std::thread::spawn(move || run_on_stream(Stream::Unix(worker_side), quick_config()));
        drop(router_side); // router dies without a Shutdown frame
        let reason = worker.join().expect("worker thread").expect("worker run");
        assert_eq!(reason, ExitReason::Disconnected, "EOF is a lost router, not a stop order");
    }
}
